"""End-to-end driver: the event-driven DiSCo runtime over REAL JAX engines
with MANY concurrent requests — each user's device is a small transformer;
the server endpoint is a larger model inside a shared continuous-batching
scheduler, so server TTFT tails emerge from slot contention.

    PYTHONPATH=src python examples/serve_disco.py --requests 12

Demonstrates (1) dispatch racing with real prefill wall-times, (2) loser
cancellation (the race loser stops after at most one in-flight decode chunk
— watch the wasted-token column), (3) token-ID migration whose re-prefill
competes with live traffic in the same batched scheduler, and (4) the
delivery buffer keeping TBT smooth, with per-request QoE scored against
each request's SLO contract.

Migration note (old tuple API -> Request): requests are now first-class
``repro.serving.Request`` objects —

    # before:  disco.serve_many([(arrival, prompt, max_new), ...])
    # now:     disco.serve_many([Request(prompt, max_new, arrival=arrival,
    #                                    sampler=..., seed=..., slo=SLO(...)),
    #                            ...])

Every request can carry its own SamplerConfig (heterogeneous greedy/
temperature/top-k/top-p rows share one fused server batch), a sampling seed
(replay/migration bit-identity), an SLO (TTFT deadline + TBT target — the
server's admission queue is deadline-aware), a priority tier, and a cost
weight. Results come back as ``RequestResult`` with an Andes-style
``QoEReport`` attached.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.launch.serve import build_stack
from repro.serving import SLO, Request
from repro.sim.traces import poisson_arrivals


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=20)
    ap.add_argument("--mean-interval", type=float, default=0.03,
                    help="mean Poisson inter-arrival (virtual seconds); "
                         "smaller = heavier server contention")
    ap.add_argument("--no-cancel", action="store_true",
                    help="control mode: race losers run to completion")
    args = ap.parse_args()

    disco, dev_engine, server = build_stack(
        "server", budget=0.5, cancel_losers=not args.no_cancel
    )
    rng = np.random.default_rng(0)

    # --- a Poisson arrival trace through the full stack --------------------
    # every other request carries a tight TTFT deadline: the server's
    # admission queue is deadline-aware (priority-tiered EDF), and the QoE
    # report scores delivery against each request's own contract
    arrivals = poisson_arrivals(rng, args.requests, args.mean_interval)
    requests = [
        Request(
            rng.integers(0, 1024, size=int(n)).astype(np.int32), args.max_new,
            arrival=float(a),
            slo=SLO(ttft_deadline=0.3) if i % 2 == 0 else SLO(ttft_deadline=3.0),
            priority=0 if i % 2 == 0 else 1,
        )
        for i, (a, n) in enumerate(
            zip(arrivals, np.clip(rng.lognormal(2.5, 0.8, args.requests), 2, 64))
        )
    ]
    print(f"DiSCo event-driven runtime: {args.requests} concurrent requests "
          f"(device={dev_engine.cfg.name}, server={server.cfg.name}, "
          f"slots={server.max_slots}, cancel={'off' if args.no_cancel else 'on'})")
    results = disco.serve_many(requests)

    for i, r in enumerate(results):
        tbt_max = max(r.tbt_series) if r.tbt_series else 0.0
        print(f"  req{i:02d} t={r.arrival:6.3f}s ttft={r.ttft*1e3:7.1f}ms "
              f"winner={r.winner.value:6s} migrated={str(r.migrated):5s} "
              f"tokens={len(r.tokens):3d} wasted={r.wasted_tokens:3d} "
              f"max_tbt={tbt_max*1e3:6.1f}ms qoe={r.qoe.qoe_score:5.3f} "
              f"slo={'ok' if r.qoe.slo_attained else 'MISS'}")

    ttfts = np.array([r.ttft for r in results])
    wasted = sum(r.wasted_tokens for r in results)
    generated = sum(r.generated_tokens for r in results)
    attained = sum(r.qoe.slo_attained for r in results)
    print(f"\n  TTFT p50 {np.percentile(ttfts,50)*1e3:.1f}ms | "
          f"p99 {np.percentile(ttfts,99)*1e3:.1f}ms | "
          f"SLO attained {attained}/{len(results)} | "
          f"migrations {sum(r.migrated for r in results)}/{len(results)} | "
          f"wasted tokens {wasted}/{generated} "
          f"({100.0*wasted/max(generated,1):.1f}%)")


if __name__ == "__main__":
    main()
