"""End-to-end driver: DiSCo serving over REAL JAX engines with batched
requests — the device endpoint is a small transformer, the server endpoint a
larger one behind a simulated network + continuous-batching queue.

    PYTHONPATH=src python examples/serve_disco.py --requests 12

Demonstrates (1) dispatch racing with real prefill wall-times, (2) token-ID
migration with re-prefill on the target, (3) the delivery buffer keeping TBT
smooth, and (4) the server-side BatchedServer that creates the queueing
tails DiSCo protects against.
"""
import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import paper_models
from repro.launch.serve import build_stack
from repro.models import init_params
from repro.serving import BatchedServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=20)
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="tokens per fused decode dispatch (host syncs once "
                         "per chunk; larger = higher throughput, coarser "
                         "admission granularity)")
    args = ap.parse_args()

    # --- 1. the server-side reality: continuous batching queues requests ---
    srv_cfg = paper_models.TINY_SERVER
    bs = BatchedServer(srv_cfg, init_params(srv_cfg, jax.random.PRNGKey(1)),
                       max_slots=2, max_len=96, decode_chunk=args.decode_chunk)
    bs.warmup()  # precompile prefill bucket + tail scans outside the timing
    rng = np.random.default_rng(0)
    rids = [bs.submit(rng.integers(0, 1024, size=8).astype(np.int32), 8)
            for _ in range(6)]
    bs.run_to_completion()
    ttfts = sorted(bs.ttft(r) for r in rids)
    print("BatchedServer TTFTs (2 slots, 6 requests) — queueing tail:")
    print("  " + "  ".join(f"{t*1e3:.0f}ms" for t in ttfts))

    # --- 2. DiSCo over device+server engines -------------------------------
    disco, dev_engine, srv_engine = build_stack("server", budget=0.5)
    prompts = [
        rng.integers(0, 1024, size=int(n)).astype(np.int32)
        for n in np.clip(rng.lognormal(2.5, 0.8, args.requests), 2, 64)
    ]
    print(f"\nDiSCo serving {args.requests} requests "
          f"(device={dev_engine.cfg.name}, server={srv_engine.cfg.name}):")
    results = []
    for i, p in enumerate(prompts):
        r = disco.serve(p, args.max_new)
        results.append(r)
        tbt_max = max(r.tbt_series) if r.tbt_series else 0.0
        print(f"  req{i:02d} len={len(p):3d} ttft={r.ttft*1e3:7.1f}ms "
              f"winner={r.winner.value:6s} migrated={str(r.migrated):5s} "
              f"tokens={len(r.tokens):3d} max_tbt={tbt_max*1e3:6.1f}ms")
    ttfts = np.array([r.ttft for r in results])
    print(f"\n  mean TTFT {ttfts.mean()*1e3:.1f}ms | p99 {np.percentile(ttfts,99)*1e3:.1f}ms"
          f" | migrations {sum(r.migrated for r in results)}/{len(results)}")


if __name__ == "__main__":
    main()
