"""Token-level migration walkthrough (Fig. 4): first an analytic timeline of
one request handing off between endpoints, then the same protocol driven
through the REAL event-driven runtime (lazy token streams over JAX engines,
re-prefill submitted into the shared batched scheduler).

    PYTHONPATH=src python examples/migration_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import (
    CostModel,
    Endpoint,
    MigrationConfig,
    MigrationController,
    TokenBuffer,
)


def real_runtime_migration() -> None:
    """Drive an actual migration end-to-end: device wins the prefill race,
    decode migrates onto the (cheaper) server mid-stream."""
    from repro.launch.serve import build_stack
    from repro.serving import Request

    disco, dev_engine, server = build_stack("device", budget=0.5)
    rng = np.random.default_rng(1)
    # short prompt: the device starts immediately (w=0), wins the prefill
    # race, and — being the expensive decoder here — migrates decode onto
    # the server once the delivery buffer can mask the hand-off
    prompt = rng.integers(0, 1024, size=10).astype(np.int32)
    r = disco.serve_many([Request(prompt, 32)])[0]
    print("\n--- same protocol, real engines (event-driven runtime) ---")
    print(f"winner={r.winner.value} migrated={r.migrated} "
          f"tokens={len(r.tokens)} generated={r.generated_tokens} "
          f"wasted={r.wasted_tokens}")
    print(f"ttft={r.ttft*1e3:.1f}ms  max TBT={max(r.tbt_series)*1e3:.1f}ms  "
          f"delayed tokens={r.delayed_tokens}")


def main() -> None:
    # device decode is 10x the server price -> migrate device -> server
    cm = CostModel(
        server_prefill=1.0, server_decode=1.0,
        device_prefill_energy=10.0, device_decode_energy=10.0,
        exchange_rate=1.0,
    )
    cfg = MigrationConfig(consumption_rate=4.8, network_rtt=0.05)
    ctrl = MigrationController(cm, cfg)

    prompt_len, total_tokens = 60, 80
    r_gen_device, r_gen_server = 14.0, 30.0   # tokens/s
    t = 0.42                                  # device won the race at 420 ms
    buf = TokenBuffer(cfg.consumption_rate, t)
    print("Fig.4 walkthrough — device wins prefill, server is the cheap decoder\n")
    print(f"t={t:6.2f}s  first token (device)")

    plan = ctrl.plan(
        current=Endpoint.DEVICE, prompt_len=prompt_len, generated=1,
        expected_total_tokens=total_tokens, target_prefill_rate=400.0,
    )
    assert plan is not None
    print(f"           migration plan: target={plan.target.value}, "
          f"buffer B={plan.buffer_needed} tokens (Eq.5: r_c x t_m="
          f"{cfg.consumption_rate:.1f}x{plan.est_handoff_time:.2f}s), "
          f"projected savings={plan.projected_savings:.1f} units")

    gen, handoff_at = 1, None
    while buf.occupancy(t) < plan.buffer_needed:
        t += 1.0 / r_gen_device
        buf.push(t)
        gen += 1
    handoff_at = t
    print(f"t={t:6.2f}s  buffer holds {buf.occupancy(t)} undelivered tokens "
          f">= B={plan.buffer_needed} -> hand-off starts (token {gen})")

    ready = handoff_at + plan.est_handoff_time
    while t + 1.0 / r_gen_device < ready:       # Row A keeps generating
        t += 1.0 / r_gen_device
        buf.push(t)
        gen += 1
    print(f"t={ready:6.2f}s  server re-prefilled {prompt_len}+{gen} token IDs "
          f"(no KV transfer) -> Row B takes over")
    t = ready
    while gen < total_tokens:
        t += 1.0 / r_gen_server
        buf.push(t)
        gen += 1
    print(f"t={t:6.2f}s  generation done on server\n")

    tbts = buf.tbt_series()
    print(f"delivered {buf.n_tokens} tokens; TBT mean={np.mean(tbts):.3f}s "
          f"max={np.max(tbts):.3f}s (pace 1/r_c={1/cfg.consumption_rate:.3f}s)")
    print(f"tokens delayed by migration: {buf.delayed_tokens()} — "
          "the buffer fully masked the hand-off" if buf.delayed_tokens() == 0
          else f"tokens delayed: {buf.delayed_tokens()}")
    real_runtime_migration()


if __name__ == "__main__":
    main()
