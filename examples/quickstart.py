"""Quickstart: DiSCo's dispatch + migration on calibrated traces in <30 s.

    PYTHONPATH=src python examples/quickstart.py

Walks the whole public API: trace calibration, cost model, Algorithm 1
regime selection, both dispatch policies, the TTFT race, migration and the
delivery buffer — and prints DiSCo vs the paper's baselines.
"""
import numpy as np

from repro.core import (
    Endpoint,
    LengthDistribution,
    MigrationConfig,
    SingleEndpointPolicy,
    StochasticPolicy,
    make_policy,
    simulate_full,
    simulate_ttft,
    summarize,
)
from repro.sim import (
    DEVICE_PROFILES,
    build_cost_model,
    make_requests,
    make_server_model,
    sample_prompt_lengths,
)


def main() -> None:
    rng = np.random.default_rng(0)
    trace, device_name = "gpt", "xiaomi14-qwen05b"
    server = make_server_model(trace, rng)          # profiled server TTFT CDF
    device = DEVICE_PROFILES[device_name]           # measured phone rates
    lengths = sample_prompt_lengths(rng, 2000)      # Alpaca-like workload
    ld = LengthDistribution.from_samples(lengths)

    print(f"=== DiSCo quickstart: {trace} x {device.name}")
    for constraint in ("server", "device"):
        cm = build_cost_model(trace, device_name, constraint)
        print(f"\n--- {constraint}-constrained (Algorithm 1 -> "
              f"{cm.regime().value}); budget sweep, mean/p99 TTFT [s]")
        print(f"{'policy':<12} {'b':>4} {'mean':>8} {'p99':>8}")
        for b in (0.2, 0.5, 0.8):
            disco = make_policy(cm, server.ttft, ld, b)
            cons = Endpoint.SERVER if constraint == "server" else Endpoint.DEVICE
            stoch = StochasticPolicy(cons, b, seed=1)
            for name, pol in (("DiSCo", disco), ("Stoch", stoch)):
                r = simulate_ttft(lengths, pol, server, device,
                                  np.random.default_rng(2))
                print(f"{name:<12} {b:>4.1f} {r['ttft'].mean():>8.3f} "
                      f"{np.percentile(r['ttft'], 99):>8.3f}")
        for name, pol in (
            ("vLLM", SingleEndpointPolicy(Endpoint.SERVER)),
            ("llama.cpp", SingleEndpointPolicy(Endpoint.DEVICE)),
        ):
            r = simulate_ttft(lengths, pol, server, device, np.random.default_rng(2))
            print(f"{name:<12} {'-':>4} {r['ttft'].mean():>8.3f} "
                  f"{np.percentile(r['ttft'], 99):>8.3f}")

    # --- migration: cost with/without (Fig. 7) -----------------------------
    cm = build_cost_model(trace, device_name, "device")
    reqs = make_requests(np.random.default_rng(3), 200)
    pol = SingleEndpointPolicy(Endpoint.DEVICE)
    base = summarize(simulate_full(reqs, pol, cm, server, device,
                                   np.random.default_rng(4), migration=None))
    mig = summarize(simulate_full(reqs, pol, cm, server, device,
                                  np.random.default_rng(4),
                                  migration=MigrationConfig()))
    red = 100 * (base.mean_cost - mig.mean_cost) / base.mean_cost
    print(f"\n--- token-level migration (r_c=4.8 tok/s)")
    print(f"cost/request: {base.mean_cost:.3e} -> {mig.mean_cost:.3e} "
          f"({red:.1f}% saved; paper: up to 72.7%)")
    print(f"p99 TBT: {mig.p99_tbt:.3f}s (pace 1/r_c = 0.208s) — "
          f"delivery uninterrupted, {mig.mean_delayed:.1f} tokens delayed on avg")


if __name__ == "__main__":
    main()
