"""Train a small model end-to-end through the production train step
(microbatched, remat-able, sharded API) on synthetic structured data.

    PYTHONPATH=src python examples/train_small.py --steps 200

Default is CPU-friendly (~3M params); pass ``--arch`` to train any assigned
architecture's smoke variant (e.g. ``--arch mamba2-2.7b`` trains a tiny SSD
stack; ``--arch olmoe-1b-7b`` a tiny MoE with router load-balancing).
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data import lm_batches, masked_audio_batches
from repro.models import init_params
from repro.training import make_optimizer, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="codeqwen1.5-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.2f}M params, "
          f"family={cfg.family}) for {args.steps} steps")
    params = init_params(cfg, jax.random.PRNGKey(0))
    if cfg.family == "audio":
        batches = masked_audio_batches(cfg.d_model, cfg.vocab, args.batch, args.seq)
    else:
        batches = lm_batches(cfg.vocab, args.batch, args.seq)
    opt = make_optimizer(cfg.name, lr=args.lr)

    def log(i, m):
        extra = f" aux={m['aux']:.4f}" if cfg.is_moe else ""
        print(f"  step {i:4d} loss={m['loss']:.4f} gnorm={m['grad_norm']:.2f}{extra}")

    params, history = train(cfg, params, opt, batches, args.steps,
                            log_every=max(args.steps // 10, 1), log_fn=log)
    first, last = history[0][1]["loss"], history[-1][1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
