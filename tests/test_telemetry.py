"""Unified telemetry tests: the metrics registry as the SINGLE backing
store behind every stats dict (``pool_stats()``/``stats()`` are snapshots,
counter attributes are registry views), Chrome-trace schema invariants
(every span closes, spans nest, async instants live inside open spans),
per-request trace lifecycles reconciling exactly against ``RequestResult``
outcomes and registry counters, replay-projection determinism across
same-seed runs, TTFT attribution, and ``QoEReport.from_timeline`` zero- and
one-token edge cases."""
import math
import warnings

import jax
import numpy as np
import pytest

from repro.configs import paper_models
from repro.core import CostModel, DiSCoScheduler, MigrationConfig
from repro.models import init_params
from repro.serving import (
    NULL_TRACER,
    SLO,
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    MetricsRegistry,
    NetworkModel,
    QoEReport,
    Request,
    ServerEndpoint,
    Tracer,
    reconcile_trace,
    replay_projection,
    request_records,
    trace_instants,
    trace_spans,
    ttft_attribution,
    validate_trace,
)
from repro.serving.kv_pool import KVPoolManager
from repro.serving.telemetry import metric_attr

CFG = paper_models.TINY_DEVICE


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def dev_engine(params):
    eng = InferenceEngine(CFG, params, max_len=96)
    eng.warmup(prompt_lens=(12,))
    return eng


def _make_disco(dev_engine, params, tracer=None):
    """Device-constrained pricing so the driver migrates mid-stream: the
    traced lifecycle covers race + cancel + migration, not just a race."""
    server = BatchedServer(CFG, params, max_slots=2, max_len=96,
                           decode_chunk=4)
    server.warmup(prompt_lens=(12,))
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6),
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(
            rng.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.5,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.005),
    )
    return DiSCoServer(
        sched, DeviceEndpoint(dev_engine),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.01, rtt_jitter=0.0)),
        rng=np.random.default_rng(7),
        tracer=tracer,
    )


def _requests(n=3, max_new=16):
    rng = np.random.default_rng(9)
    return [
        Request(rng.integers(0, CFG.vocab, size=12).astype(np.int32),
                max_new, arrival=0.002 * i)
        for i in range(n)
    ]


@pytest.fixture(scope="module")
def traced_runs(dev_engine, params):
    """Two same-seed traced runs of the full disco stack (race + cancel +
    migration), shared by the lifecycle / reconciliation / determinism /
    attribution tests."""
    out = []
    for _ in range(2):
        tracer = Tracer()
        disco = _make_disco(dev_engine, params, tracer=tracer)
        results = disco.serve_many(_requests())
        out.append((tracer.export(), results, disco))
    return out


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram_view():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    reg.gauge("g").set(2.5)
    h = reg.histogram("h")
    for v in (1.0, 3.0):
        h.observe(v)
    state = {"xs": [1, 2, 3]}
    reg.view("derived", lambda: len(state["xs"]))

    assert "c" in reg and "missing" not in reg
    assert reg.value("c") == 5
    assert reg.value("g") == 2.5
    assert reg.value("h") == {"count": 2, "total": 4.0, "mean": 2.0,
                              "min": 1.0, "max": 3.0}
    # views are evaluated at snapshot time — they can never drift
    state["xs"].append(4)
    snap = reg.snapshot()
    assert snap["derived"] == 4
    assert set(snap) == {"c", "g", "h", "derived"}
    # empty histogram renders all-zero, not inf
    assert reg.histogram("h2").summary() == {
        "count": 0, "total": 0.0, "mean": 0.0, "min": 0.0, "max": 0.0}


def test_registry_kind_clash_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="x"):
        reg.gauge("x")
    with pytest.raises(TypeError, match="x"):
        reg.histogram("x")


def test_metric_attr_write_through():
    class Holder:
        hits = metric_attr("hits")

        def __init__(self):
            self.metrics = MetricsRegistry()
            self.hits = 0

    h = Holder()
    h.hits += 1
    h.hits += 2
    # the attribute is a view; the registry is the single backing store
    assert h.hits == 3
    assert h.metrics.counter("hits").value == 3
    h.metrics.counter("hits").inc()
    assert h.hits == 4


# ---------------------------------------------------------------------------
# Tracer / NullTracer
# ---------------------------------------------------------------------------


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.span("t", "n", 0.0, 1.0) is None
    assert NULL_TRACER.instant("t", "n", 0.0) is None
    assert NULL_TRACER.value("t", "n", 0.0, 1) is None
    assert NULL_TRACER.begin_request(0, 0.0) is None
    assert NULL_TRACER.request_instant(0, "e", 0.0) is None
    assert NULL_TRACER.end_request(0, 0.0) is None
    assert NULL_TRACER.export() == {"traceEvents": [], "displayTimeUnit": "ms"}
    with pytest.raises(RuntimeError, match="NullTracer"):
        NULL_TRACER.save("/dev/null")


def test_tracer_tracks_and_async_roundtrip():
    tr = Tracer()
    assert tr.enabled is True
    tr.span("server/row0", "prefill", 0.0, 0.5, cat="server",
            args={"rid": 1})
    tr.span("server/row0", "decode", 0.5, 0.7, cat="server")
    tr.instant("server/queue", "enqueue", 0.1, cat="server")
    tr.value("kv/pool", "blocks_in_use", 0.2, 3)
    tr.begin_request(7, 0.0, args={"prompt_tokens": 12})
    tr.request_instant(7, "first_token", 0.4, args={"ttft_s": 0.4})
    tr.end_request(7, 0.9, args={"outcome": "finished", "tokens": [1, 2]})
    trace = tr.export()

    assert validate_trace(trace) == []
    # "group/lane" naming -> one pid per group, metadata events present
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    procs = {e["args"]["name"] for e in meta if e["name"] == "process_name"}
    assert {"server", "kv", "request"} <= procs
    row0 = trace_spans(trace, cat="server")
    assert {e["name"] for e in row0} == {"prefill", "decode"}
    recs = request_records(trace)
    assert recs[7]["begin"]["args"] == {"prompt_tokens": 12}
    assert [n["args"]["event"] for n in recs[7]["instants"]] == ["first_token"]
    assert replay_projection(trace) == {
        7: {"tokens": [1, 2], "outcome": "finished", "delivered": None}}


def test_validate_trace_catches_violations():
    tr = Tracer()
    tr.begin_request(1, 0.0)                       # never closed
    tr.request_instant(9, "orphan", 0.1)           # instant outside any span
    tr.span("a/b", "outer", 0.0, 1.0)
    tr.span("a/b", "straddles", 0.5, 1.5)          # overlaps, not nested
    problems = validate_trace(tr.export())
    assert any("never closed" in p for p in problems)
    assert any("outside open span" in p for p in problems)
    assert any("overlaps" in p for p in problems)
    # hand-broken event: negative duration
    bad = {"traceEvents": [
        {"ph": "X", "name": "n", "pid": 1, "tid": 1, "ts": 0.0, "dur": -1.0}]}
    assert any("negative dur" in p for p in validate_trace(bad))


# ---------------------------------------------------------------------------
# Registry-backed stats surfaces
# ---------------------------------------------------------------------------


def test_kv_pool_stats_are_registry_views():
    kv = KVPoolManager(num_blocks=9, block_size=8, rows=2,
                       max_blocks_per_row=6, prefix_cache=True)
    tr = Tracer()
    clock = [0.0]
    kv.set_telemetry(tr, lambda: clock[0])
    toks = list(range(1, 17))                     # 2 full blocks
    assert kv.admit(0, 3, num_tokens=16) is not None
    clock[0] = 1.0
    kv.release(0, cache_tokens=toks)              # seed the radix cache
    matched = kv.prefix_match(toks + [99])        # 2-block hit
    assert len(matched) == 2
    assert kv.admit(1, 3 - len(matched), num_tokens=17,
                    prefix_blocks=matched) is not None
    kv.release(1)

    snap = kv.metrics.snapshot()
    # attributes and registry report the same numbers (one backing store)
    assert snap["prefix_hits"] == kv.prefix_hits == 1
    assert snap["blocks_saved"] == kv.blocks_saved == 2
    assert snap["preemptions"] == kv.preemptions == 0
    # the 2 sealed prefix blocks stay referenced by the radix cache
    assert snap["blocks_in_use"] == 2 and snap["blocks_cached"] == 2
    assert snap["num_blocks"] == 9 and snap["block_size"] == 8
    # the trace reconciles against the same registry snapshot
    assert validate_trace(tr.export()) == []
    assert reconcile_trace(tr.export(), snap) == []
    hits = trace_instants(tr.export(), name="prefix_hit")
    assert len(hits) == 1 and hits[0]["args"]["blocks"] == 2


def test_server_pool_stats_is_registry_snapshot(params):
    server = BatchedServer(CFG, params, max_slots=2, max_len=48,
                           block_size=8, num_blocks=9)
    assert server.pool_stats() == server.metrics.snapshot()
    # the descriptor attributes read through to the same counters
    server.slo_misses += 2
    assert server.pool_stats()["server_slo_misses"] == 2
    assert server.metrics.counter("server_slo_misses").value == 2


def test_preemption_trace_reconciles_exactly(params, dev_engine):
    """Two requests outgrow a tiny pool mid-decode: the preemption shows up
    as trace instants whose count equals the registry counter, streams stay
    lossless, and every server-side request span closes as finished."""
    tracer = Tracer()
    server = BatchedServer(CFG, params, max_slots=2, max_len=48,
                           block_size=8, num_blocks=9, tracer=tracer)
    prompts = [np.arange(4, dtype=np.int32),
               np.asarray([7, 3, 11, 2], np.int32)]
    expected = [dev_engine.generate(p, 40).tokens for p in prompts]
    rids = [server.submit(Request(p, 40)) for p in prompts]
    done = server.run_to_completion()
    for rid, exp in zip(rids, expected):
        assert done[rid] == exp

    stats = server.pool_stats()
    trace = tracer.export()
    assert stats["preemptions"] >= 1
    assert validate_trace(trace) == []
    assert reconcile_trace(trace, stats) == []
    assert len(trace_instants(trace, name="preempt")) == stats["preemptions"]
    recs = request_records(trace, cat="server_request")
    assert set(recs) == set(rids)
    for rid in rids:
        assert recs[rid]["end"] is not None
        assert recs[rid]["end"]["args"]["outcome"] == "finished"
    # a preempted request re-prefills: more prefill spans than requests
    assert len(trace_spans(trace, cat="server", name="prefill")) > len(rids)


# ---------------------------------------------------------------------------
# Full driver lifecycle traces
# ---------------------------------------------------------------------------


def test_driver_trace_matches_request_results(traced_runs):
    trace, results, disco = traced_runs[0]
    assert validate_trace(trace) == []
    assert reconcile_trace(trace, disco.stats()) == []
    recs = request_records(trace)
    assert set(recs) == {r.rid for r in results}
    proj = replay_projection(trace)
    for r in results:
        rec = recs[r.rid]
        assert rec["begin"] is not None and rec["end"] is not None
        end_args = rec["end"]["args"]
        assert end_args["outcome"] == "finished"
        assert end_args["migrated"] == r.migrated
        assert end_args["wasted"] == r.wasted_tokens
        assert proj[r.rid]["tokens"] == r.tokens
        assert proj[r.rid]["delivered"] == len(r.tokens)
        events = [n["args"]["event"] for n in rec["instants"]]
        assert events[0] == "dispatch"
        assert "first_token" in events
        # migrated marks hand-off INITIATION; the source may finish before
        # the target takes over, so handoff_done is the stronger signal
        if r.migrated:
            assert "migration_start" in events
        if "handoff_done" in events:
            assert r.migrated


def test_replay_projection_identical_across_same_seed_runs(traced_runs):
    (tr1, run1, _), (tr2, run2, _) = traced_runs
    # timestamps legitimately differ (compute is measured wall-clock);
    # the projection onto delivered streams + outcomes must not
    assert replay_projection(tr1) == replay_projection(tr2)
    assert [r.tokens for r in run1] == [r.tokens for r in run2]


def test_ttft_attribution_rows(traced_runs):
    trace, results, _ = traced_runs[0]
    rows = {row["rid"]: row for row in ttft_attribution(trace)}
    assert set(rows) == {r.rid for r in results}
    for r in results:
        row = rows[r.rid]
        assert row["ttft_s"] == pytest.approx(r.ttft, rel=1e-6)
        assert row["outcome"] == "finished"
        for comp in ("queue_s", "prefill_s", "network_s", "draft_stall_s"):
            assert row[comp] >= 0.0
    # the race always pays real prefill compute somewhere before TTFT
    assert any(row["prefill_s"] > 0 for row in rows.values())


def test_stats_merges_driver_and_server(traced_runs):
    _, _, disco = traced_runs[0]
    stats = disco.stats()
    # one documented surface: server registry + driver ledgers, no double-hop
    assert "slo_dispatch_overrides" in stats
    assert "cancel_lag_tokens" in stats
    assert stats["spec_requests"] == 0
    with pytest.warns(DeprecationWarning, match="stats"):
        legacy = disco.pool_stats()
    assert legacy == stats


# ---------------------------------------------------------------------------
# Speculative draft/verify traces
# ---------------------------------------------------------------------------


def test_speculative_trace_verify_spans(params, dev_engine):
    tracer = Tracer()
    server = BatchedServer(CFG, params, max_slots=2, max_len=96,
                           decode_chunk=4, speculative=True, tracer=tracer)
    server.warmup(prompt_lens=(12,))
    draft = InferenceEngine(CFG, params, max_len=96, paged=True,
                            speculative=True)
    draft.warmup(prompt_lens=(12,))
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12),
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(
            rng.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.9,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )
    disco = DiSCoServer(
        sched, DeviceEndpoint(draft),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.05)),
        rng=np.random.default_rng(7), mode="speculative",
    )
    disco.set_tracer(tracer)                   # post-ctor attach path
    results = disco.serve_many(_requests(n=2, max_new=10))
    assert disco.spec_requests > 0

    stats = disco.stats()
    trace = tracer.export()
    assert validate_trace(trace) == []
    assert reconcile_trace(trace, stats) == []
    verify = trace_spans(trace, name="verify")
    assert len(verify) == stats["verify_rounds"] > 0
    assert sum(s["args"]["accepted"] for s in verify) == \
        stats["accepted_draft_tokens"]
    # device draft spans + spec_round lifecycle instants are present
    assert trace_spans(trace, cat="device", name="draft")
    recs = request_records(trace)
    for r in results:
        events = [n["args"]["event"] for n in recs[r.rid]["instants"]]
        assert "spec_round" in events or "spec_fallback" in events
        assert replay_projection(trace)[r.rid]["tokens"] == r.tokens


# ---------------------------------------------------------------------------
# QoEReport.from_timeline edge cases
# ---------------------------------------------------------------------------


def test_qoe_zero_tokens_delivered():
    q = QoEReport.from_timeline(1.0, [], SLO(ttft_deadline=0.5), rid=3)
    assert q.rid == 3 and q.tokens_delivered == 0
    assert q.ttft == math.inf
    assert q.tbt_mean == 0.0 and q.late_tokens == 0
    assert q.qoe_score == 0.0
    assert not q.slo_attained and not q.ttft_attained


def test_qoe_one_token_has_no_tbt():
    slo = SLO(ttft_deadline=0.5, tbt_target=0.1)
    q = QoEReport.from_timeline(1.0, [1.2], slo)
    assert q.tokens_delivered == 1
    assert q.ttft == pytest.approx(0.2)
    assert q.tbt_mean == 0.0                   # no gaps to average
    assert q.ttft_attained and q.slo_attained
    assert q.qoe_score == pytest.approx(1.0)


def test_null_default_leaves_no_trace(params):
    server = BatchedServer(CFG, params, max_slots=1, max_len=48)
    assert server.tracer is NULL_TRACER
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # no hidden DeprecationWarning
        server.pool_stats()
