"""Device-draft / server-verify speculative decoding.

Covers the tentpole contracts: the rejection-sampling acceptance RATE
matches the overlap integral ``sum(min(p_s, p_d))``, the delivered stream
is bit-identical to same-seed server-only generation at matched models
(temperature > 0), chunking the draft window (k) never changes the stream,
and the waste accounting counts rejected drafts on BOTH endpoints while
crediting accepted ones.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper_models
from repro.core import CostModel, DiSCoScheduler, MigrationConfig
from repro.models import init_params
from repro.models.sampling import (
    SamplerConfig,
    first_rejection,
    request_key,
    sampling_probs,
    speculative_accept,
)
from repro.serving import (
    BatchedServer,
    DeviceEndpoint,
    InferenceEngine,
    NetworkModel,
    Request,
    ServerEndpoint,
)
from repro.serving.disco_driver import DiSCoServer

CFG = paper_models.TINY_SERVER
SAMP = SamplerConfig(temperature=0.8, top_k=0, top_p=1.0)
MAX_NEW = 14
PROMPT = np.arange(9, dtype=np.int32) % CFG.vocab


@pytest.fixture(scope="module")
def srv_params():
    return init_params(CFG, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def spec_server(srv_params):
    srv = BatchedServer(CFG, srv_params, max_slots=2, max_len=96,
                        decode_chunk=4, speculative=True)
    srv.warmup(prompt_len=len(PROMPT))
    return srv


@pytest.fixture(scope="module")
def draft_engine(srv_params):
    dev = InferenceEngine(CFG, srv_params, max_len=96, paged=True,
                          speculative=True)
    dev.warmup(prompt_len=len(PROMPT))
    return dev


def _spec_stream(srv: BatchedServer, dev: InferenceEngine, seed: int,
                 k: int, max_new: int = MAX_NEW):
    """One engine-level draft/verify request; returns (stream, accepted,
    scored)."""
    rid = srv.submit(Request(PROMPT.copy(), max_new, seed=seed, sampler=SAMP),
                     verify=True)
    srv.run_until(srv.clock + 1e-9)
    tok0 = srv.pop_events(rid)[0][0]
    st = dev.open_stream(Request(PROMPT.copy(), max_new, seed=seed,
                                 sampler=SAMP))
    st.draft_prefill()
    st.force_pending(tok0)
    got = [tok0]
    accepted = scored = 0
    while not srv.is_finished(rid):
        w = st.draft_window(k)
        if w is None:
            break
        drafts, dev_probs, _ = w
        res = srv.verify_step(rid, drafts, dev_probs)
        if res is None:
            srv.end_verify(rid)
            srv.run_to_completion()
            got.extend(t for t, _ in srv.pop_events(rid))
            break
        st.draft_rewind(res["accepted"], res["tokens"][-1])
        got.extend(res["tokens"])
        accepted += res["accepted"]
        scored += res["k"]
        srv.pop_events(rid)
    st.cancel()
    return got, accepted, scored


def _server_only_stream(srv_params, seed: int, max_new: int = MAX_NEW):
    srv = BatchedServer(CFG, srv_params, max_slots=2, max_len=96,
                        decode_chunk=4)
    srv.warmup(prompt_len=len(PROMPT))
    rid = srv.submit(Request(PROMPT.copy(), max_new, seed=seed, sampler=SAMP))
    return srv.run_to_completion()[rid]


# ---------------------------------------------------------------------------
# rejection-sampling acceptance math
# ---------------------------------------------------------------------------


def test_statistical_acceptance_matches_overlap():
    """Empirical acceptance over many positions converges to the overlap
    integral ``sum(min(p_s, p_d))`` — the Leviathan et al. rate."""
    v = 8
    n = 4096
    rng = np.random.default_rng(0)
    p_d = rng.dirichlet(np.ones(v)).astype(np.float32)
    p_s = rng.dirichlet(np.ones(v)).astype(np.float32)
    expected = float(np.minimum(p_s, p_d).sum())
    assert 0.05 < expected < 0.95       # a non-degenerate overlap

    key = request_key(123)
    positions = jnp.arange(n, dtype=jnp.int32)
    # drafts drawn from p_d with the device's position-keyed stream — the
    # same draw sample_tokens would make for a device row with these probs
    drafts = jax.vmap(
        lambda p: jax.random.categorical(
            jax.random.fold_in(key, p), jnp.log(jnp.asarray(p_d))
        )
    )(positions).astype(jnp.int32)
    accept, _ = speculative_accept(
        key, positions, drafts,
        jnp.tile(jnp.asarray(p_d), (n, 1)), jnp.tile(jnp.asarray(p_s), (n, 1)),
    )
    rate = float(jnp.mean(accept))
    # 4 sigma of a Bernoulli(expected) mean over n draws
    tol = 4.0 * float(np.sqrt(expected * (1 - expected) / n))
    assert abs(rate - expected) < tol, (rate, expected, tol)


def test_matched_models_accept_everything():
    """p_device == p_server: every coin passes (u * p <= p), bit-exactly."""
    v, k = 16, 32
    rng = np.random.default_rng(3)
    p = jnp.asarray(rng.dirichlet(np.ones(v), size=k).astype(np.float32))
    key = request_key(7)
    positions = jnp.arange(k, dtype=jnp.int32)
    drafts = jnp.asarray(rng.integers(0, v, size=k), jnp.int32)
    accept, _ = speculative_accept(key, positions, drafts, p, p)
    assert bool(jnp.all(accept))
    assert int(first_rejection(accept)) == k


def test_zero_server_prob_never_accepted():
    """A draft the server gives zero mass must be rejected even when the
    accept coin lands exactly on 0.0."""
    v = 4
    p_d = jnp.asarray([[0.25, 0.25, 0.25, 0.25]], jnp.float32)
    p_s = jnp.asarray([[0.0, 0.5, 0.5, 0.0]], jnp.float32)
    key = request_key(11)
    accept, corr = speculative_accept(
        key, jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32), p_d, p_s,
    )
    assert not bool(accept[0])
    assert int(corr[0]) in (1, 2)       # residual only covers server mass


def test_greedy_rows_are_one_hot():
    """sampling_probs for a greedy row is the exact argmax one-hot — the
    distribution speculative verification scores greedy traffic against."""
    logits = jnp.asarray([[0.1, 2.0, -1.0, 0.5]], jnp.float32)
    probs = sampling_probs(None, logits)
    np.testing.assert_allclose(np.asarray(probs), [[0, 1, 0, 0]], atol=1e-7)


# ---------------------------------------------------------------------------
# end-to-end bit-identity + k-invariance (matched models, temperature > 0)
# ---------------------------------------------------------------------------


def test_bit_identical_to_server_only_and_k_invariant(
        spec_server, draft_engine, srv_params):
    """Matched draft/verify models at temperature 0.8: the speculative
    stream equals same-seed server-only generation bit-for-bit, every draft
    is accepted, and the draft-window size k never changes the stream."""
    ref = _server_only_stream(srv_params, seed=21)
    streams = {}
    for k in (1, 2, 4):
        got, accepted, scored = _spec_stream(
            spec_server, draft_engine, seed=21, k=k)
        assert accepted == scored, (k, accepted, scored)
        streams[k] = got
    for k, got in streams.items():
        assert got == ref, f"k={k} diverged from server-only"


def test_rejection_path_stays_server_distributed(spec_server, draft_engine,
                                                 srv_params):
    """Corrupting drafts forces the rejection path; the verify verdict must
    truncate at the first rejection and keep the stream coherent (length,
    dtype, range) — losslessness under corruption is distributional, so no
    bit-identity is asserted here (that contract is the matched path)."""
    srv, dev = spec_server, draft_engine
    rid = srv.submit(Request(PROMPT.copy(), MAX_NEW, seed=33, sampler=SAMP),
                     verify=True)
    srv.run_until(srv.clock + 1e-9)
    tok0 = srv.pop_events(rid)[0][0]
    st = dev.open_stream(Request(PROMPT.copy(), MAX_NEW, seed=33,
                                 sampler=SAMP))
    st.draft_prefill()
    st.force_pending(tok0)
    got = [tok0]
    saw_rejection = False
    while not srv.is_finished(rid):
        w = st.draft_window(4)
        if w is None:
            break
        drafts, dev_probs, _ = w
        drafts = list(drafts)
        if len(drafts) >= 2:
            drafts[1] = int((drafts[1] + 1) % CFG.vocab)  # corrupt draft 2
        res = srv.verify_step(rid, drafts, dev_probs)
        if res is None:
            srv.end_verify(rid)
            srv.run_to_completion()
            break
        if res["accepted"] < res["k"]:
            # matched models accept the corrupt token itself (ratio = 1);
            # the divergence shows up in the positions conditioned on it
            saw_rejection = True
            assert len(res["tokens"]) == res["accepted"] + 1
        st.draft_rewind(res["accepted"], res["tokens"][-1])
        got.extend(res["tokens"])
        srv.pop_events(rid)
    st.cancel()
    assert saw_rejection
    assert all(0 <= t < CFG.vocab for t in got)


# ---------------------------------------------------------------------------
# driver-level waste accounting
# ---------------------------------------------------------------------------


def _make_spec_disco(dev_engine, srv_params, mode="speculative"):
    server = BatchedServer(CFG, srv_params, max_slots=2, max_len=96,
                           decode_chunk=4, speculative=(mode == "speculative"))
    server.warmup(prompt_len=len(PROMPT))
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12),
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(
            rng.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.9,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )
    return DiSCoServer(
        sched, DeviceEndpoint(dev_engine),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.05)),
        rng=np.random.default_rng(7), mode=mode,
    )


def test_wasted_ratio_counts_rejected_drafts(srv_params):
    """Satellite accounting contract, pinned: for a speculative request,
    ``wasted == generated - delivered - accepted_drafts`` — a rejected
    draft is waste TWICE (the device drafted it, the server scored it), an
    accepted draft is waste NEVER (computed on the device, delivered
    through the verify round)."""
    # MISMATCHED drafter (TINY_DEVICE) so rejections actually happen
    dev_cfg = paper_models.TINY_DEVICE
    dev = InferenceEngine(dev_cfg, init_params(dev_cfg, jax.random.PRNGKey(0)),
                          max_len=96, paged=True, speculative=True)
    dev.warmup(prompt_len=len(PROMPT))
    disco = _make_spec_disco(dev, srv_params)
    res = disco.serve_many(
        [Request(PROMPT.copy(), MAX_NEW, arrival=0.0, seed=5, sampler=SAMP)]
    )[0]
    assert disco.spec_requests == 1
    stats = disco.server.server.pool_stats()
    accepted = stats["accepted_draft_tokens"]
    scored = stats["drafts_scored"]
    assert scored > accepted > 0         # rejections happened, so did accepts
    assert res.wasted_tokens == (
        res.generated_tokens - len(res.tokens) - accepted
    )
    assert res.wasted_tokens > 0         # the rejected drafts are in there


def test_race_mode_wasted_accounting_unchanged(draft_engine, srv_params):
    """Race-and-cancel keeps the PR-6 ledger: wasted == generated -
    delivered, no speculative credit."""
    disco = _make_spec_disco(draft_engine, srv_params, mode="race")
    res = disco.serve_many(
        [Request(PROMPT.copy(), MAX_NEW, arrival=0.0, seed=5, sampler=SAMP)]
    )[0]
    assert disco.spec_requests == 0
    assert res.wasted_tokens == res.generated_tokens - len(res.tokens)
