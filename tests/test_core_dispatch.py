"""Unit + property tests for the DiSCo dispatch controller (§4.2, Alg. 1-3)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CostModel,
    DevicePolicy,
    Endpoint,
    EmpiricalCDF,
    LengthDistribution,
    Regime,
    ServerPolicy,
    SingleEndpointPolicy,
    StochasticPolicy,
    make_policy,
)


def _lengths(seed=0, n=4000):
    rng = np.random.default_rng(seed)
    return LengthDistribution.from_samples(
        np.clip(np.round(rng.lognormal(3.3, 0.9, n)), 1, 2048).astype(int)
    )


def _server_cdf(seed=1, n=4000):
    rng = np.random.default_rng(seed)
    return EmpiricalCDF.from_samples(rng.lognormal(np.log(0.4), 0.5, n))


# ---------------------------------------------------------------------------
# Algorithm 1: regime classification
# ---------------------------------------------------------------------------

def test_regime_device_constrained():
    cm = CostModel(1e-7, 6e-7, 800.0, 790.0, exchange_rate=5e-6)
    assert cm.regime() is Regime.DEVICE_CONSTRAINED
    assert cm.constrained_endpoint is Endpoint.DEVICE


def test_regime_server_constrained():
    cm = CostModel(1e-6, 2e-6, 800.0, 790.0, exchange_rate=1e-12)
    assert cm.regime() is Regime.SERVER_CONSTRAINED
    assert cm.constrained_endpoint is Endpoint.SERVER


def test_make_policy_matches_regime():
    lengths, cdf = _lengths(), _server_cdf()
    dev = make_policy(CostModel(1e-7, 6e-7, 800.0, 790.0, 5e-6), cdf, lengths, 0.3)
    srv = make_policy(CostModel(1e-6, 2e-6, 800.0, 790.0, 1e-12), cdf, lengths, 0.3)
    assert isinstance(dev, DevicePolicy)
    assert isinstance(srv, ServerPolicy)


# ---------------------------------------------------------------------------
# Algorithm 3 / Eq. 3: server-constrained length threshold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [0.0, 0.1, 0.3, 0.5, 0.8, 1.0])
def test_server_policy_budget_constraint(budget):
    lengths = _lengths()
    pol = ServerPolicy(lengths, budget)
    used = pol.expected_budget_use()
    # one length-bin of granularity is inherent to the empirical solve
    max_bin = float(np.max(lengths.support() * lengths.probs) / lengths.mean())
    assert used <= budget + max_bin + 1e-9


def test_server_policy_extremes():
    lengths = _lengths()
    assert all(
        ServerPolicy(lengths, 1.0).decide(int(l)).use_server
        for l in lengths.support()
    )
    pol0 = ServerPolicy(lengths, 0.0)
    assert not any(pol0.decide(int(l)).use_server for l in lengths.support())


def test_server_policy_threshold_monotone_in_budget():
    lengths = _lengths()
    ths = [ServerPolicy(lengths, b).l_th for b in (0.1, 0.3, 0.5, 0.7, 0.9)]
    assert all(a >= b for a, b in zip(ths, ths[1:]))  # more budget -> lower l_th


def test_server_policy_routes_short_to_device_only():
    lengths = _lengths()
    pol = ServerPolicy(lengths, 0.5)
    short = pol.decide(1)
    assert short.use_device and not short.use_server
    long = pol.decide(2048)
    assert long.use_device and long.use_server  # race


# ---------------------------------------------------------------------------
# Algorithm 2 / Eq. 1-2: device-constrained wait times
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [0.02, 0.1, 0.3, 0.6, 0.9])
def test_device_policy_budget_constraint(budget):
    pol = DevicePolicy(_server_cdf(), _lengths(), budget, tail_ratio=0.05)
    assert pol.expected_budget_use() <= budget + 0.02  # CDF granularity slack


def test_device_policy_tail_protection():
    cdf = _server_cdf()
    pol = DevicePolicy(cdf, _lengths(), budget=0.3, tail_ratio=0.05)
    # w_tail is the (1 - alpha) server quantile
    assert pol.w_tail == pytest.approx(float(cdf.quantile(0.95)), rel=1e-6)
    # every wait is capped by w_tail
    for l in (1, 10, 100, 1000, 4096):
        assert pol.wait_time(l) <= pol.w_tail + 1e-9


def test_device_policy_wait_monotone_in_length():
    pol = DevicePolicy(_server_cdf(), _lengths(), budget=0.3)
    ls = np.array(sorted(pol.lengths.support()))
    ws = np.array([pol.wait_time(int(l)) for l in ls])
    assert np.all(np.diff(ws) >= -1e-9)  # short prompts start sooner (Eq. 1)


def test_device_policy_low_budget_all_wait_tail():
    # b <= alpha: Algorithm 2 returns w_tail for every length
    pol = DevicePolicy(_server_cdf(), _lengths(), budget=0.03, tail_ratio=0.05)
    for l in pol.lengths.support()[:50]:
        assert pol.wait_time(int(l)) == pytest.approx(pol.w_tail)


def test_device_policy_high_budget_mostly_immediate():
    pol = DevicePolicy(_server_cdf(), _lengths(), budget=0.95, tail_ratio=0.05)
    ls, ps = pol.lengths.support(), pol.lengths.probs
    zero_frac = sum(p for l, p in zip(ls, ps) if pol.wait_time(int(l)) == 0.0)
    assert zero_frac > 0.5


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def test_stochastic_budget():
    rng = np.random.default_rng(0)
    pol = StochasticPolicy(Endpoint.SERVER, budget=0.3, seed=7)
    decisions = [pol.decide(10) for _ in range(20000)]
    frac = np.mean([d.use_server for d in decisions])
    assert frac == pytest.approx(0.3, abs=0.02)
    assert all(d.use_device for d in decisions)


def test_single_endpoint_policies():
    s = SingleEndpointPolicy(Endpoint.SERVER).decide(42)
    d = SingleEndpointPolicy(Endpoint.DEVICE).decide(42)
    assert s.use_server and not s.use_device
    assert d.use_device and not d.use_server


# ---------------------------------------------------------------------------
# Property tests (hypothesis): invariants over random distributions
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    budget=st.floats(0.0, 1.0),
    mu=st.floats(2.0, 5.0),
    sigma=st.floats(0.3, 1.2),
)
def test_prop_server_policy_budget_holds(seed, budget, mu, sigma):
    rng = np.random.default_rng(seed)
    lengths = LengthDistribution.from_samples(
        np.clip(np.round(rng.lognormal(mu, sigma, 600)), 1, 8192).astype(int)
    )
    pol = ServerPolicy(lengths, budget)
    max_bin = float(np.max(lengths.support() * lengths.probs) / lengths.mean())
    assert pol.expected_budget_use() <= budget + max_bin + 1e-9
    # decisions are total and deterministic
    for l in lengths.support()[:10]:
        d1, d2 = pol.decide(int(l)), pol.decide(int(l))
        assert (d1.use_server, d1.use_device) == (d2.use_server, d2.use_device)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    budget=st.floats(0.01, 1.0),
    alpha=st.floats(0.01, 0.5),
)
def test_prop_device_policy_budget_and_cap(seed, budget, alpha):
    rng = np.random.default_rng(seed)
    lengths = LengthDistribution.from_samples(
        np.clip(np.round(rng.lognormal(3.0, 0.8, 500)), 1, 4096).astype(int)
    )
    cdf = EmpiricalCDF.from_samples(rng.lognormal(-0.5, 0.6, 500))
    pol = DevicePolicy(cdf, lengths, budget, tail_ratio=alpha)
    # budget holds up to empirical-CDF granularity
    assert pol.expected_budget_use() <= budget + alpha + 5e-3
    # waits in [0, w_tail]
    for l in lengths.support()[::37]:
        w = pol.wait_time(int(l))
        assert 0.0 <= w <= pol.w_tail + 1e-9


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), q=st.floats(0.01, 0.99))
def test_prop_empirical_cdf_quantile_roundtrip(seed, q):
    rng = np.random.default_rng(seed)
    cdf = EmpiricalCDF.from_samples(rng.lognormal(0.0, 1.0, 400))
    t = float(cdf.quantile(q))
    assert cdf.cdf(t) >= q - 1e-9  # F(F^{-1}(q)) >= q
    # monotonicity
    assert cdf.quantile(min(q + 0.01, 1.0)) >= t - 1e-12
