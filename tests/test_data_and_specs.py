"""Data pipeline determinism/learnability-structure + input_specs shapes."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, input_specs, shape_supported
from repro.data import lm_batches, masked_audio_batches, zipf_prompt


def test_lm_batches_deterministic_and_structured():
    a = next(lm_batches(64, 4, 32, seed=5))
    b = next(lm_batches(64, 4, 32, seed=5))
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    # next-token structure: targets mostly follow the fixed permutation
    x, y = a["inputs"], a["targets"]
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])  # shifted by one
    assert x.dtype == np.int32 and x.min() >= 0 and x.max() < 64


def test_masked_audio_batches_shapes():
    b = next(masked_audio_batches(32, 16, 4, 24, seed=1))
    assert b["inputs"].shape == (4, 24, 32)
    assert b["targets"].shape == (4, 24)
    assert b["loss_mask"].shape == (4, 24)
    assert 0.05 < b["loss_mask"].mean() < 0.6


def test_zipf_prompt_bounds():
    rng = np.random.default_rng(0)
    p = zipf_prompt(rng, 100, 50)
    assert p.shape == (50,) and p.min() >= 0 and p.max() < 100


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, _ = shape_supported(cfg, shape)
    if not ok:
        pytest.skip("documented skip")
    specs = input_specs(cfg, shape)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        exp = (b, s) if cfg.embed_inputs else (b, s, cfg.d_model)
        assert specs["inputs"].shape == exp
        assert specs["targets"].shape == (b, s)
    elif shape.kind == "prefill":
        exp = (b, s) if cfg.embed_inputs else (b, s, cfg.d_model)
        assert specs["inputs"].shape == exp
    else:
        assert specs["token"].shape == (b,)
        cache = specs["cache"]
        assert cache["lengths"].shape == (b,)
        if cfg.has_attention and not cfg.use_mla:
            assert cache["k"].shape == (
                cfg.n_layers, b, cfg.n_kv_heads, s, cfg.resolved_head_dim
            )
        if cfg.use_mla:
            assert cache["ckv"].shape == (cfg.n_layers, b, s, cfg.kv_lora_rank)
        if cfg.has_ssm:
            assert cache["ssm_state"].shape == (
                cfg.n_layers, b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            )
