"""Attention-mode equivalences across the three implementations and the
window/pattern/bidirectional variants, plus SSD chunk invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    attention_blockwise,
    attention_dense,
    decode_attention,
)
from repro.models.ssm import ssd_chunked
from repro.kernels.ref import ssd_reference


def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@pytest.mark.parametrize("window", [0, 32, 128])
@pytest.mark.parametrize("causal", [True, False])
def test_blockwise_equals_dense(window, causal):
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 256, 4, 32))
    k = _rand(rng, (2, 256, 2, 32))
    v = _rand(rng, (2, 256, 2, 32))
    a = attention_dense(q, k, v, causal=causal, window=window)
    b = attention_blockwise(q, k, v, causal=causal, window=window,
                            block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)


def test_blockwise_traced_window():
    """window as a traced scalar (the per-layer scanned window vector)."""
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 128, 2, 16))
    k = _rand(rng, (1, 128, 1, 16))
    v = _rand(rng, (1, 128, 1, 16))

    def f(w):
        return attention_blockwise(q, k, v, window=w, block_q=64, block_k=64)

    out = jax.jit(f)(jnp.asarray(16, jnp.int32))
    ref = attention_dense(q, k, v, window=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_decode_attention_equals_full_last_row():
    """decode_attention(q_last) == the last row of full causal attention."""
    rng = np.random.default_rng(2)
    s = 64
    q = _rand(rng, (2, s, 4, 16))
    k = _rand(rng, (2, s, 2, 16))
    v = _rand(rng, (2, s, 2, 16))
    full = attention_dense(q, k, v, causal=True)
    lengths = jnp.full((2,), s, jnp.int32)
    # decode_attention consumes the head-major (B, K, S, D) cache layout
    dec = decode_attention(
        q[:, -1], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), lengths
    )
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full[:, -1]), rtol=3e-5, atol=3e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    sq=st.sampled_from([64, 128]),
    window=st.sampled_from([0, 16, 48]),
)
def test_prop_blockwise_dense_agree(seed, sq, window):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (1, sq, 2, 16))
    k = _rand(rng, (1, sq, 2, 16))
    v = _rand(rng, (1, sq, 2, 16))
    a = attention_dense(q, k, v, window=window)
    b = attention_blockwise(q, k, v, window=window, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-5, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([8, 16, 32, 64]))
def test_prop_ssd_chunk_invariance(seed, chunk):
    """SSD output must not depend on the chunk size (math identity)."""
    rng = np.random.default_rng(seed)
    b, t, h, p, n = 1, 64, 2, 8, 4
    x = _rand(rng, (b, t, h, p))
    dt = jnp.asarray(rng.uniform(0.001, 0.2, (b, t, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    Bm = _rand(rng, (b, t, 1, n))
    Cm = _rand(rng, (b, t, 1, n))
    y, st_ = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(sr), rtol=2e-4, atol=2e-4)


def test_ssd_initial_state_threading():
    """Splitting a sequence in half and threading the state equals one pass."""
    rng = np.random.default_rng(3)
    b, t, h, p, n = 1, 64, 2, 8, 4
    x = _rand(rng, (b, t, h, p))
    dt = jnp.asarray(rng.uniform(0.001, 0.2, (b, t, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    Bm = _rand(rng, (b, t, 1, n))
    Cm = _rand(rng, (b, t, 1, n))
    y_full, s_full = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y1, s1 = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], chunk=16)
    y2, s2 = ssd_chunked(
        x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:], chunk=16,
        initial_state=s1,
    )
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=3e-4, atol=3e-4)
