"""Numerical correctness of the shard_map distributed decode paths against
the single-device references, on an 8-host-device mesh (subprocess: the
device count must be fixed before jax initializes).
"""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.distributed import (
    decode_context, distributed_attn_decode, distributed_mla_decode_absorbed,
)
from repro.kernels.ref import decode_reference

_axis_type = getattr(jax.sharding, "AxisType", None)
_mesh_kwargs = {"axis_types": (_axis_type.Auto,) * 2} if _axis_type else {}
mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices()[:8],
                     **_mesh_kwargs)

rng = np.random.default_rng(0)
B, S, H, K, D = 4, 64, 8, 2, 16
q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
k_new = jnp.asarray(rng.normal(size=(B, 1, K, D)), jnp.float32)
v_new = jnp.asarray(rng.normal(size=(B, 1, K, D)), jnp.float32)
kc = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
vc = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
lengths = jnp.asarray([17, 33, 64, 50], jnp.int32)  # includes the new token

# reference: insert new kv at lengths-1 then plain decode (seq-major oracle)
idx = lengths - 1
kc_ref = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))(kc, k_new, idx)
vc_ref = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))(vc, v_new, idx)
ref = decode_reference(q, kc_ref, vc_ref, lengths)

# the production cache (and the distributed path) is head-major (B, K, S, D)
kn_h, vn_h = k_new.transpose(0, 2, 1, 3), v_new.transpose(0, 2, 1, 3)
kc_h, vc_h = kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3)
kc_ref_h = kc_ref.transpose(0, 2, 1, 3)

with mesh:
    from repro.models.distributed import _DecodeCtx
    ctx = _DecodeCtx(mesh, "model", ("data",))
    shard = NamedSharding(mesh, P("data", None, "model", None))
    kc_s = jax.device_put(kc_h, shard)
    vc_s = jax.device_put(vc_h, shard)
    out, kc2, vc2 = jax.jit(
        lambda *a: distributed_attn_decode(*a, window=0, ctx=ctx)
    )(q, kn_h, vn_h, kc_s, vc_s, lengths)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc_ref_h), rtol=1e-6, atol=1e-6)
print("distributed_attn_decode OK")

# windowed
ref_w = decode_reference(q, kc_ref, vc_ref, lengths, window=16)
with mesh:
    out_w, _, _ = jax.jit(
        lambda *a: distributed_attn_decode(*a, window=16, ctx=ctx)
    )(q, kn_h, vn_h, kc_s, vc_s, lengths)
np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref_w), rtol=2e-5, atol=2e-5)
print("distributed_attn_decode window OK")

# ---- MLA: full decode_step equivalence, plain vs shmap variant -------------
from repro.configs import get_config
from repro.models import init_params, prefill, decode_step

cfg = dataclasses.replace(get_config("minicpm3-4b", smoke=True),
                          dtype="float32", mla_absorb=True)
params = init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0, cfg.vocab)
_, cache = prefill(params, cfg, toks[:, :20], 32)
l_ref, _ = decode_step(params, cfg, cache, toks[:, 20])

with mesh:
    cache_s = dict(cache)
    csh = NamedSharding(mesh, P("data", "model", None))
    cache_s["ckv"] = jax.device_put(cache["ckv"], NamedSharding(mesh, P(None, "data", "model", None)))
    cache_s["krope"] = jax.device_put(cache["krope"], NamedSharding(mesh, P(None, "data", "model", None)))
    with decode_context(mesh, seq_axis="model", batch_axes=("data",)):
        l_dist, _ = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))(
            params, cache_s, toks[:, 20]
        )
np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_dist), rtol=3e-4, atol=3e-4)
print("distributed MLA decode OK")
"""


def test_distributed_decode_matches_reference(tmp_path):
    script = tmp_path / "dist_check.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script)],
        env=env, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    assert "distributed_attn_decode OK" in out.stdout
    assert "distributed MLA decode OK" in out.stdout


def test_mla_absorbed_equals_expanded():
    """Weight absorption is a pure linear-algebra identity."""
    import dataclasses
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import decode_step, init_params, prefill

    cfg = dataclasses.replace(get_config("minicpm3-4b", smoke=True), dtype="float32")
    cfg_abs = dataclasses.replace(cfg, mla_absorb=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    _, cache = prefill(params, cfg, toks[:, :20], 32)
    l1, c1 = decode_step(params, cfg, cache, toks[:, 20])
    l2, c2 = decode_step(params, cfg_abs, cache, toks[:, 20])
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)
    # identical math, but XLA fusion reorders float ops -> small wobble
    np.testing.assert_allclose(
        np.asarray(c1["ckv"]), np.asarray(c2["ckv"]), rtol=1e-4, atol=1e-4
    )
