"""Tests for the migration controller + token buffer (§4.3, Eq. 4-5, Fig. 4)."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CostModel,
    Endpoint,
    MigrationConfig,
    MigrationController,
    TokenBuffer,
)

# device decode much more expensive than server decode -> migrate device->server
DEVICE_EXPENSIVE = CostModel(1e-7, 2e-7, 100.0, 100.0, exchange_rate=1e-5)
# server decode more expensive -> migrate server->device
SERVER_EXPENSIVE = CostModel(1e-3, 5e-3, 100.0, 100.0, exchange_rate=1e-9)


def test_buffer_size_eq5():
    cfg = MigrationConfig(consumption_rate=4.8)
    assert cfg.buffer_tokens(1.0) == 5           # ceil(4.8)
    assert cfg.buffer_tokens(2.5) == 12          # ceil(12.0)
    assert cfg.buffer_tokens(0.0) == 0


def test_migration_triggers_when_savings_exceed_overhead():
    ctrl = MigrationController(DEVICE_EXPENSIVE, MigrationConfig())
    plan = ctrl.plan(
        current=Endpoint.DEVICE, prompt_len=50, generated=10,
        expected_total_tokens=200.0, target_prefill_rate=500.0,
    )
    assert plan is not None
    assert plan.target is Endpoint.SERVER
    assert plan.projected_savings > 0
    assert plan.buffer_needed == math.ceil(
        MigrationConfig().consumption_rate * plan.est_handoff_time
    )


def test_no_migration_when_already_on_cheap_endpoint():
    ctrl = MigrationController(DEVICE_EXPENSIVE, MigrationConfig())
    assert ctrl.plan(
        current=Endpoint.SERVER, prompt_len=50, generated=10,
        expected_total_tokens=200.0, target_prefill_rate=500.0,
    ) is None


def test_no_migration_when_nearly_done():
    ctrl = MigrationController(DEVICE_EXPENSIVE, MigrationConfig(min_remaining_tokens=4))
    assert ctrl.plan(
        current=Endpoint.DEVICE, prompt_len=50, generated=198,
        expected_total_tokens=200.0, target_prefill_rate=500.0,
    ) is None


def test_no_migration_when_overhead_dominates():
    # tiny decode delta, huge target prefill price -> Eq. 4 fails
    cm = CostModel(5e-3, 1.01e-7, 100.0, 100.0, exchange_rate=1e-9)
    ctrl = MigrationController(cm, MigrationConfig())
    plan = ctrl.plan(
        current=Endpoint.DEVICE, prompt_len=5000, generated=2,
        expected_total_tokens=20.0, target_prefill_rate=100.0,
    )
    assert plan is None


def test_server_to_device_direction():
    ctrl = MigrationController(SERVER_EXPENSIVE, MigrationConfig())
    plan = ctrl.plan(
        current=Endpoint.SERVER, prompt_len=30, generated=5,
        expected_total_tokens=150.0, target_prefill_rate=50.0,
    )
    assert plan is not None and plan.target is Endpoint.DEVICE


# ---------------------------------------------------------------------------
# TokenBuffer: delivery pacing invariants (Fig. 4)
# ---------------------------------------------------------------------------

def test_buffer_paces_at_consumption_rate():
    buf = TokenBuffer(consumption_rate=5.0, first_token_time=0.0)
    for i in range(1, 20):
        buf.push(i * 0.05)  # generation at 20 tok/s > r_c = 5 tok/s
    tbts = buf.tbt_series()
    assert all(abs(t - 0.2) < 1e-9 for t in tbts)  # delivered exactly at 1/r_c
    assert buf.delayed_tokens() == 0


def test_buffer_stall_counts_delayed_tokens():
    buf = TokenBuffer(consumption_rate=5.0, first_token_time=0.0)
    buf.push(0.05)
    buf.push(1.0)   # a 0.95 s generation gap > 0.2 s pace -> stall
    buf.push(1.05)
    assert buf.delayed_tokens() == 1
    assert max(buf.tbt_series()) > 0.2


def test_buffer_occupancy():
    buf = TokenBuffer(consumption_rate=2.0, first_token_time=0.0)
    for i in range(1, 11):
        buf.push(i * 0.1)  # 10 tok/s gen vs 2 tok/s delivery
    # at t=1.0 all 11 tokens generated; delivered: t0 + every 0.5 s -> 3
    assert buf.occupancy(1.0) == 11 - 3


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    r_c=st.floats(1.0, 10.0),
    r_g=st.floats(10.0, 50.0),
    n=st.integers(5, 80),
)
def test_prop_buffer_never_delivers_before_generation(seed, r_c, r_g, n):
    rng = np.random.default_rng(seed)
    buf = TokenBuffer(r_c, 0.0)
    t = 0.0
    for _ in range(n):
        t += rng.exponential(1.0 / r_g)
        buf.push(t)
    for g, d in zip(buf.generated_at, buf.delivered_at):
        assert d >= g - 1e-12
    # delivery gaps never beat the consumption pace
    assert all(dt >= 1.0 / r_c - 1e-9 for dt in buf.tbt_series())


@settings(max_examples=50, deadline=None)
@given(
    rc=st.floats(0.5, 20.0),
    tm=st.floats(0.0, 30.0),
)
def test_prop_buffer_size_masks_handoff(rc, tm):
    """Eq. 5 invariant: B tokens at pace 1/r_c cover at least t_m seconds."""
    B = MigrationConfig(consumption_rate=rc).buffer_tokens(tm)
    assert B / rc >= tm - 1e-9
    assert (B - 1) / rc < tm + 1.0 / rc  # and B is not wastefully large
