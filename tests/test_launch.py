"""Launch-layer tests: sharding rules, HLO collective parsing with loop
trip-count correction, the analytic cost model, and a real (subprocess)
dry-run compile.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.analytic import step_flops, analytic_costs
from repro.launch.dryrun import (
    _line_output_bytes,
    collective_stats,
    depth_multipliers,
)
from repro.launch.sharding import (
    cache_pspecs,
    opt_state_pspecs,
    param_pspecs,
)
from repro.models import param_shapes
from repro.training import make_optimizer


@dataclasses.dataclass(frozen=True)
class FakeMesh:
    """Duck-typed mesh exposing .shape / .axis_names (the only attributes the
    pure sharding-rule functions use)."""
    shape: dict
    axis_names: tuple


SINGLE = FakeMesh({"data": 16, "model": 16}, ("data", "model"))
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16}, ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_divisibility(arch, mesh):
    """Every sharded dim must divide evenly by its mesh axes."""
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    specs = param_pspecs(cfg, mesh, shapes)

    def check(shape, spec, name):
        assert len(spec) <= len(shape), name
        for dim, ax in zip(shape, list(spec) + [None] * len(shape)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % n == 0, f"{name}: {dim} % {n}"

    for name, shape in shapes.items():
        if name == "layers":
            for k, s in shape.items():
                check(s, specs["layers"][k], f"{arch}.{k}")
        else:
            check(shape, specs[name], f"{arch}.{name}")


@pytest.mark.parametrize("arch", ["nemotron-4-340b", "arctic-480b"])
def test_giant_params_are_model_sharded(arch):
    """The big tensors must actually shard (memory fit depends on it)."""
    cfg = get_config(arch)
    specs = param_pspecs(cfg, SINGLE, param_shapes(cfg))
    layer = specs["layers"]
    big_keys = [k for k in layer if k.startswith(("w_up", "w_down", "moe_"))]
    assert big_keys
    for k in big_keys:
        assert any(ax == "model" for ax in layer[k] if ax), f"{k} not sharded"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_cover_all_entries(arch):
    cfg = get_config(arch)
    if cfg.is_encoder:
        pytest.skip("no decode cache")
    from repro.models import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 1024))
    specs = cache_pspecs(cfg, SINGLE, cache)
    assert set(specs) == set(cache)
    for k, leaf in cache.items():
        spec = specs[k]
        if k == "lengths":
            continue
        for dim, ax in zip(leaf.shape, list(spec) + [None] * len(leaf.shape)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([SINGLE.shape[a] for a in axes]))
            assert dim % n == 0, f"{arch}.{k}"


def test_nemotron_kv8_cache_shards_sequence():
    """kv=8 < model=16 -> the sequence axis must take the model shards."""
    cfg = get_config("nemotron-4-340b")
    from repro.models import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 32768))
    specs = cache_pspecs(cfg, SINGLE, cache)
    # head-major (L, B, K, S, hd) cache layout
    assert specs["k"][2] is None           # kv heads unsharded
    assert specs["k"][3] == "model"        # sequence takes model axis


def test_long500k_batch1_cache_uses_all_axes():
    cfg = get_config("gemma3-1b")
    from repro.models import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, 1, 524288))
    specs = cache_pspecs(cfg, MULTI, cache)
    k = specs["k"]
    assert k[1] is None                    # batch=1 unshardable
    seq_ax = k[3]                          # head-major: seq is axis 3
    assert seq_ax is not None              # sequence sharded over free axes


def test_opt_state_specs_follow_params():
    cfg = get_config("nemotron-4-340b")
    shapes = param_shapes(cfg)
    pspecs = param_pspecs(cfg, SINGLE, shapes)
    opt = make_optimizer(cfg.name)  # adafactor

    import functools
    from repro.models import init_params
    params_s = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0)
    )
    opt_s = jax.eval_shape(opt.init, params_s)
    from repro.launch.dryrun import pshapes_tree
    ospecs = opt_state_pspecs(opt_s, pspecs, pshapes_tree(shapes))
    # w_up (L, d, f) sharded (None, None, "model") -> vr drops last dim
    assert ospecs["layers"]["w_up"]["vr"] == P(None, None)
    assert ospecs["layers"]["w_up"]["vc"] == P(None, "model")


# ---------------------------------------------------------------------------
# HLO collective parsing + loop-depth correction
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %all-gather = f32[512,512]{0,1} all-gather(%copy), channel_id=1, metadata={op_name="jit(f)/while/body/dot_general" stack_frame_id=3}
  %all-reduce.1 = bf16[16,128]{1,0} all-reduce(%x), channel_id=2, metadata={op_name="jit(f)/transpose"}
  %ar-done = f32[8]{0} all-reduce-done(%start)
  %rs = f32[4,4]{1,0} reduce-scatter(%y), channel_id=3, metadata={op_name="jit(f)/while/body/while/body/foo"}
"""


def test_line_output_bytes():
    assert _line_output_bytes("f32[512,512]{0,1}") == 512 * 512 * 4
    assert _line_output_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _line_output_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_collective_stats_depth_correction():
    stats = collective_stats(HLO_SAMPLE, multipliers=[10.0, 40.0])
    # all-gather at depth 1 -> x10; all-reduce at depth 0 -> x1;
    # reduce-scatter at depth 2 -> x40; -done line skipped
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 512 * 512 * 4 * 10
    assert stats["all-reduce"]["count"] == 1
    assert stats["all-reduce"]["bytes"] == 16 * 128 * 2
    assert stats["reduce-scatter"]["bytes"] == 4 * 4 * 4 * 40
    assert stats["total_count"] == 3


def test_depth_multipliers_structure():
    cfg = get_config("nemotron-4-340b")
    m = depth_multipliers(cfg, "train", 4096)
    assert m == [16.0, 16.0 * 96]
    m = depth_multipliers(cfg, "decode", 32768)
    assert m == [96.0]
    cfg2 = get_config("mamba2-2.7b")
    m2 = depth_multipliers(cfg2, "train", 4096)
    assert m2 == [64.0, 64.0 * (4096 // cfg2.ssm_chunk)]


# ---------------------------------------------------------------------------
# analytic cost model
# ---------------------------------------------------------------------------

def test_analytic_flops_close_to_6nd_for_dense_train():
    cfg = get_config("codeqwen1.5-7b")
    fl = step_flops(cfg, "train", 256, 4096)
    six_nd = 6.0 * cfg.param_count() * 256 * 4096
    # remat adds ~1 forward (x4/3), attention adds the quadratic term;
    # embeddings don't matmul. Expect within a factor ~[0.8, 2.2].
    assert 0.8 * six_nd < fl < 2.2 * six_nd


def test_analytic_decode_flops_linear_in_batch():
    cfg = get_config("gemma3-1b")
    f1 = step_flops(cfg, "decode", 1, 32768)
    f128 = step_flops(cfg, "decode", 128, 32768)
    assert 100 < f128 / f1 <= 128.5


def test_analytic_moe_counts_active_only():
    cfg = get_config("arctic-480b")
    fl = step_flops(cfg, "prefill", 1, 4096)
    dense_equiv = 2.0 * cfg.param_count() * 4096
    active_equiv = 2.0 * cfg.active_param_count() * 4096
    assert fl < 0.5 * dense_equiv
    assert fl > 0.5 * active_equiv


def test_analytic_memory_decode_dominated_by_cache_and_params():
    cfg = get_config("nemotron-4-340b")
    ac = analytic_costs(cfg, "decode", 128, 32768, 256, model_shard=16)
    # per-device param shard is 340e9*2/16 = 42.5 GB read once
    assert ac.bytes_per_device > 340e9 * 2 / 16


# ---------------------------------------------------------------------------
# real dry-run compile (subprocess — needs fresh XLA_FLAGS)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,shape", [
    ("mamba2-2.7b", "long_500k"),
    ("olmoe-1b-7b", "decode_32k"),
])
def test_dryrun_compiles_in_subprocess(arch, shape, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", "single",
         "--out-dir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.load(open(tmp_path / f"{arch}__{shape}__single.json"))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["flops_per_device"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
