"""End-to-end behaviour tests for the QoE simulator + DiSCo scheduler facade.

These validate the paper's *claims* hold in our reproduction:
  - DiSCo beats stochastic dispatch on mean and tail TTFT (Fig. 6 / Table 2)
  - migration reduces cost without breaking TBT (Fig. 7 / Table 3)
  - server TTFT ~ length uncorrelated; device strongly correlated (Table 1)
"""
import numpy as np
import pytest

from repro.core import (
    DiSCoScheduler,
    Endpoint,
    MigrationConfig,
    ServerPolicy,
    SingleEndpointPolicy,
    StochasticPolicy,
    make_policy,
    simulate_full,
    simulate_ttft,
    summarize,
)
from repro.sim import (
    DEVICE_PROFILES,
    build_cost_model,
    make_requests,
    make_server_model,
    sample_prompt_lengths,
)


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(42)
    server = make_server_model("gpt", rng)
    device = DEVICE_PROFILES["xiaomi14-qwen05b"]
    lengths = sample_prompt_lengths(rng, 3000)
    return rng, server, device, lengths


def test_table1_correlation_structure(setup):
    rng, server, device, lengths = setup
    server_ttft = server.sample_ttft(rng, lengths.size)
    dev_ttft = device.ttft(lengths) + rng.normal(0, 0.02, lengths.size)
    r_server = np.corrcoef(lengths, server_ttft)[0, 1]
    r_device = np.corrcoef(lengths, dev_ttft)[0, 1]
    assert abs(r_server) < 0.1          # Table 1: |rho| <= 0.04 for servers
    assert r_device > 0.8               # Table 1: 0.84 on-device


def test_disco_beats_stochastic_server_constrained(setup):
    rng, server, device, lengths = setup
    from repro.core.distributions import LengthDistribution

    ld = LengthDistribution.from_samples(lengths)
    cm = build_cost_model("gpt", "xiaomi14-qwen05b", "server")
    for budget in (0.2, 0.5, 0.8):
        disco = make_policy(cm, server.ttft, ld, budget)
        stoch = StochasticPolicy(Endpoint.SERVER, budget, seed=1)
        r_d = simulate_ttft(lengths, disco, server, device, np.random.default_rng(0))
        r_s = simulate_ttft(lengths, stoch, server, device, np.random.default_rng(0))
        assert r_d["ttft"].mean() <= r_s["ttft"].mean() * 1.02
        p99_d, p99_s = np.percentile(r_d["ttft"], 99), np.percentile(r_s["ttft"], 99)
        assert p99_d <= p99_s * 1.05


def test_disco_beats_stochastic_device_constrained(setup):
    rng, server, device, lengths = setup
    from repro.core.distributions import LengthDistribution

    ld = LengthDistribution.from_samples(lengths)
    cm = build_cost_model("gpt", "xiaomi14-qwen05b", "device")
    for budget in (0.2, 0.5):
        disco = make_policy(cm, server.ttft, ld, budget)
        stoch = StochasticPolicy(Endpoint.DEVICE, budget, seed=1)
        r_d = simulate_ttft(lengths, disco, server, device, np.random.default_rng(0))
        r_s = simulate_ttft(lengths, stoch, server, device, np.random.default_rng(0))
        # tail is the paper's headline metric in the device-constrained setting
        p99_d, p99_s = np.percentile(r_d["ttft"], 99), np.percentile(r_s["ttft"], 99)
        assert p99_d <= p99_s * 1.05


def test_budget_respected_in_simulation(setup):
    """E[I_s(l)·l] <= b·E[l] measured on simulated executions."""
    rng, server, device, lengths = setup
    from repro.core.distributions import LengthDistribution

    ld = LengthDistribution.from_samples(lengths)
    budget = 0.3
    pol = ServerPolicy(ld, budget)
    r = simulate_ttft(lengths, pol, server, device, np.random.default_rng(0))
    spent = lengths[r["server_started"]].sum() / lengths.sum()
    max_bin = float(np.max(ld.support() * ld.probs) / ld.mean())
    assert spent <= budget + max_bin + 0.02


def test_migration_cuts_cost_keeps_tbt(setup):
    rng, server, device, lengths = setup
    cm = build_cost_model("gpt", "xiaomi14-qwen05b", "device")
    reqs = make_requests(np.random.default_rng(3), 150)
    pol = SingleEndpointPolicy(Endpoint.DEVICE)  # isolate migration effect
    base = simulate_full(reqs, pol, cm, server, device, np.random.default_rng(5), migration=None)
    mig = simulate_full(
        reqs, pol, cm, server, device, np.random.default_rng(5),
        migration=MigrationConfig(),
    )
    s_base, s_mig = summarize(base), summarize(mig)
    assert s_mig.migration_rate > 0.5            # expensive decoder -> migrate
    assert s_mig.mean_cost < s_base.mean_cost    # Fig. 7
    # Table 3: delivery pace preserved; P99 TBT ~ 1/r_c
    assert s_mig.p99_tbt <= 1.0 / MigrationConfig().consumption_rate + 0.15
    assert s_mig.mean_delayed < 20               # "negligible number of tokens"


def test_scheduler_facade_end_to_end(setup):
    rng, server, device, lengths = setup
    cm = build_cost_model("gpt", "xiaomi14-qwen05b", "server")
    sched = DiSCoScheduler(
        cm,
        server_ttft_samples=server.ttft.sorted_samples[:500],
        prompt_length_samples=lengths[:500],
        budget=0.4,
    )
    d = sched.plan_request(10)
    assert d.use_device  # short prompt -> device involved
    # online refresh does not crash and rebuilds the policy
    for t in server.ttft.sorted_samples[500:700]:
        sched.observe_server_ttft(float(t))
    d2 = sched.plan_request(2000)
    assert d2.use_server  # long prompt races in server-constrained regime
    plan = sched.plan_migration(
        current=Endpoint.SERVER, prompt_len=10, generated=4,
        expected_total_tokens=120.0, target_prefill_rate=80.0,
    )
    # server-constrained: cheaper decoder is the device -> migrate off server
    assert plan is None or plan.target is Endpoint.DEVICE


def test_all_server_vs_all_device_tradeoff(setup):
    """Fig. 2/6 sanity: device is better for short prompts, server for long."""
    rng, server, device, lengths = setup
    short = np.full(500, 8)
    long = np.full(500, 1500)
    r = np.random.default_rng(0)
    dev_pol, srv_pol = SingleEndpointPolicy(Endpoint.DEVICE), SingleEndpointPolicy(Endpoint.SERVER)
    assert (
        simulate_ttft(short, dev_pol, server, device, r)["ttft"].mean()
        < simulate_ttft(short, srv_pol, server, device, np.random.default_rng(0))["ttft"].mean()
    )
    assert (
        simulate_ttft(long, srv_pol, server, device, np.random.default_rng(1))["ttft"].mean()
        < simulate_ttft(long, dev_pol, server, device, np.random.default_rng(1))["ttft"].mean()
    )
