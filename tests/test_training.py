"""Training substrate tests: loss decreases, microbatching is exact,
optimizers behave, checkpoints roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import lm_batches, masked_audio_batches
from repro.models import init_params
from repro.training import (
    adafactor,
    adamw,
    load_checkpoint,
    make_optimizer,
    make_train_step,
    save_checkpoint,
    train,
)


def test_loss_decreases_tiny_lm():
    cfg = get_config("codeqwen1.5-7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batches = lm_batches(cfg.vocab, batch=16, seq=64, seed=0)
    params, history = train(cfg, params, adamw(lr=3e-3, warmup=10), batches, n_steps=60)
    first, last = history[0][1]["loss"], history[-1][1]["loss"]
    assert last < first - 1.5, f"loss did not decrease: {first} -> {last}"


def test_loss_decreases_masked_audio():
    cfg = get_config("hubert-xlarge", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batches = masked_audio_batches(cfg.d_model, cfg.vocab, batch=16, frames=64, seed=0)
    params, history = train(cfg, params, adamw(lr=3e-3, warmup=10), batches, n_steps=100)
    first, last = history[0][1]["loss"], history[-1][1]["loss"]
    assert last < first - 0.4, f"masked loss did not decrease: {first} -> {last}"


def test_microbatching_matches_full_batch():
    """Gradient accumulation must be numerically equivalent (fp32, dense —
    the MoE router aux loss is batch-nonlinear by construction)."""
    cfg = get_config("codeqwen1.5-7b", smoke=True)
    cfg = type(cfg)(**{**cfg.__dict__, "dtype": "float32"})
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = next(lm_batches(cfg.vocab, batch=8, seq=16, seed=1))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    opt = adamw(lr=1e-3)
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)

    s1 = make_train_step(cfg, opt, num_microbatches=1)
    s4 = make_train_step(cfg, opt, num_microbatches=4)
    p1, _, _, m1 = jax.jit(s1)(params, opt_state, step, batch)
    p4, _, _, m4 = jax.jit(s4)(params, opt_state, step, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-6)


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizer_steps_reduce_quadratic(opt_name):
    """Both optimizers minimize a simple quadratic."""
    opt = adamw(lr=0.05, warmup=1) if opt_name == "adamw" else adafactor(lr=0.5, warmup=1)
    params = {"w": jnp.ones((4, 8)) * 3.0, "b": jnp.ones((8,)) * -2.0}
    state = opt.init(params)
    step = jnp.zeros((), jnp.int32)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, step)
        step = step + 1
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 128)), "b": jnp.zeros((128,))}
    st = opt.init(params)
    assert st["w"]["vr"].shape == (64,) and st["w"]["vc"].shape == (128,)
    assert st["b"]["v"].shape == (128,)
    # factored state is ~0.2% of Adam's m+v for this matrix
    adam_bytes = 2 * 64 * 128 * 4
    fact_bytes = (64 + 128) * 4
    assert fact_bytes < 0.03 * adam_bytes


def test_make_optimizer_selects_adafactor_for_giants():
    assert make_optimizer("arctic-480b").init.__qualname__.startswith("adafactor")
    assert make_optimizer("nemotron-4-340b").init.__qualname__.startswith("adafactor")
    assert make_optimizer("gemma3-1b").init.__qualname__.startswith("adamw")


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("hymba-1.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw()
    opt_state = opt.init(params)
    save_checkpoint(str(tmp_path), 7, params, opt_state, meta={"arch": cfg.name})
    p2, o2, manifest = load_checkpoint(str(tmp_path), 7, params, opt_state)
    assert manifest["step"] == 7 and manifest["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    for a, b in zip(jax.tree.leaves(opt_state), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
