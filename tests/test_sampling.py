"""Replayable-sampling invariants: temperature -> 0 recovers greedy argmax,
top-k/top-p masks on hand-built logits, and — the property the serving stack
stands on — same-seed replay is bit-identical across chunk sizes, recompute
preemption, migration hand-off, and ``fork_stream``."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import paper_models
from repro.core import CostModel, DiSCoScheduler, Endpoint, MigrationConfig
from repro.models import init_params, request_key, sample_tokens
from repro.models.sampling import GREEDY, SamplerConfig, mask_top_k, mask_top_p
from repro.serving import (
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    Request,
    ServerEndpoint,
)

CFG = paper_models.TINY_DEVICE
SAMPLER = SamplerConfig(temperature=0.8, top_p=0.95)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sampled_engine(params):
    return InferenceEngine(CFG, params, max_len=96, sampler=SAMPLER)


# ---------------------------------------------------------------------------
# SamplerConfig + mask primitives (pure, hand-built logits)
# ---------------------------------------------------------------------------


def test_sampler_config_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplerConfig(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplerConfig(top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        SamplerConfig(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplerConfig(top_p=1.5)
    assert GREEDY.greedy and not SamplerConfig(temperature=0.5).greedy
    # stochastic sampling without keys/positions fails loudly, not deep in jit
    with pytest.raises(ValueError, match="requires per-row keys"):
        sample_tokens(SamplerConfig(temperature=1.0),
                      jnp.zeros((1, 8), jnp.float32), None, None)


def test_temperature_zero_recovers_greedy():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(5, 64)).astype(np.float32))
    keys = jnp.stack([request_key(i) for i in range(5)])
    pos = jnp.arange(5, dtype=jnp.int32)
    argmax = np.argmax(np.asarray(logits), axis=-1)
    # exact greedy: temperature == 0 and sampler=None take the argmax branch
    np.testing.assert_array_equal(sample_tokens(GREEDY, logits, keys, pos), argmax)
    np.testing.assert_array_equal(sample_tokens(None, logits, None, None), argmax)
    # the limit: a vanishing temperature scales the argmax gap far beyond any
    # Gumbel perturbation, so the draw is argmax for every key/position
    tiny = SamplerConfig(temperature=1e-4)
    for p in range(20):
        got = sample_tokens(tiny, logits, keys, jnp.full((5,), p, jnp.int32))
        np.testing.assert_array_equal(got, argmax)


def test_top_k_mask_hand_built():
    logits = jnp.asarray(np.log(np.array(
        [[0.4, 0.3, 0.2, 0.1], [0.1, 0.2, 0.3, 0.4]], np.float32)))
    m = np.asarray(mask_top_k(logits, 2))
    assert np.isfinite(m[0, :2]).all() and np.isinf(m[0, 2:]).all()
    assert np.isfinite(m[1, 2:]).all() and np.isinf(m[1, :2]).all()
    # no-ops: k disabled or covering the whole vocab
    np.testing.assert_array_equal(np.asarray(mask_top_k(logits, 0)), logits)
    np.testing.assert_array_equal(np.asarray(mask_top_k(logits, 4)), logits)
    # draws restricted to the kept set at every position
    s = SamplerConfig(temperature=1.5, top_k=2)
    keys = jnp.stack([request_key(7)] * 2)
    for p in range(50):
        toks = np.asarray(
            sample_tokens(s, logits, keys, jnp.full((2,), p, jnp.int32))
        )
        assert toks[0] in (0, 1) and toks[1] in (2, 3)


def test_top_p_mask_hand_built():
    probs = np.array([0.5, 0.3, 0.15, 0.05], np.float32)
    logits = jnp.asarray(np.log(probs))[None, :]
    # exclusive-cumsum rule: token joins while the mass BEFORE it is < p
    m70 = np.asarray(mask_top_p(logits, 0.7))[0]     # 0.5 + 0.3 crosses 0.7
    assert np.isfinite(m70[:2]).all() and np.isinf(m70[2:]).all()
    m50 = np.asarray(mask_top_p(logits, 0.5))[0]     # 0.5 alone reaches it
    assert np.isfinite(m50[0]) and np.isinf(m50[1:]).all()
    m_tiny = np.asarray(mask_top_p(logits, 1e-6))[0]  # argmax always survives
    assert np.isfinite(m_tiny[0]) and np.isinf(m_tiny[1:]).all()
    np.testing.assert_array_equal(np.asarray(mask_top_p(logits, 1.0)), logits)


def test_fused_rowwise_mask_matches_sequential():
    """The serving path's single-sort fused top-k+top-p mask must be
    bit-equivalent to composing the public per-row masks (and hence to the
    static per-config rules they share)."""
    from repro.models.sampling import _mask_top_k_p_rows

    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(6, 64)).astype(np.float32))
    top_k = jnp.asarray([0, 2, 64, 7, 1, 13], jnp.int32)    # incl. no-ops
    top_p = jnp.asarray([1.0, 0.5, 0.9, 1e-6, 0.7, 1.0], jnp.float32)
    fused = np.asarray(_mask_top_k_p_rows(logits, top_k, top_p))
    sequential = np.asarray(mask_top_p(mask_top_k(logits, top_k), top_p))
    np.testing.assert_array_equal(fused, sequential)


def test_sampling_pure_in_key_position_logits():
    """The token is a pure function of (key, position, logits): batch order,
    batch size, and neighbours are irrelevant — the property that makes a
    frozen row's discarded draw consume nothing from anyone's stream."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    keys = jnp.stack([request_key(i) for i in (3, 1, 4, 1)])
    pos = jnp.asarray([5, 9, 2, 6], jnp.int32)
    s = SamplerConfig(temperature=1.0)
    full = np.asarray(sample_tokens(s, logits, keys, pos))
    flipped = np.asarray(sample_tokens(s, logits[::-1], keys[::-1], pos[::-1]))
    np.testing.assert_array_equal(full, flipped[::-1])
    for i in range(4):
        solo = np.asarray(
            sample_tokens(s, logits[i:i + 1], keys[i:i + 1], pos[i:i + 1])
        )
        assert solo[0] == full[i]
    # rows with the same key draw identically iff positions also match
    same = np.asarray(sample_tokens(
        s, jnp.tile(logits[:1], (2, 1)), keys[1::2], jnp.asarray([7, 7])
    ))
    assert same[0] == same[1]


# ---------------------------------------------------------------------------
# Engine-level replay invariants (real tiny model)
# ---------------------------------------------------------------------------


def test_same_seed_bit_identical_and_chunk_invariant(params, sampled_engine):
    prompt = np.arange(10, dtype=np.int32)
    a = sampled_engine.generate(prompt, 16, seed=5).tokens
    assert a == sampled_engine.generate(prompt, 16, seed=5).tokens
    assert a != sampled_engine.generate(prompt, 16, seed=6).tokens
    greedy = InferenceEngine(CFG, params, max_len=96)
    assert a != greedy.generate(prompt, 16).tokens
    # chunking must not move the position counter: 1-token scans == fused 8s
    by_one = InferenceEngine(CFG, params, max_len=96, decode_chunk=1,
                             sampler=SAMPLER)
    for max_new in (1, 7, 9, 16):
        assert (by_one.generate(prompt, max_new, seed=5).tokens
                == sampled_engine.generate(prompt, max_new, seed=5).tokens)


def test_replay_then_continue_sampled(sampled_engine):
    """Migration-target invariant under temperature > 0: re-prefilling
    prompt + delivered tokens with the request seed resumes the exact
    per-position stream (the replay prefill samples at position
    len(prompt) + len(delivered))."""
    prompt = np.arange(6, dtype=np.int32)
    direct = sampled_engine.generate(prompt, 16, seed=11).tokens
    for cut in (1, 5, 15):
        _, cont = sampled_engine.replay_then_continue(
            prompt, direct[:cut], max_new=16 - cut, seed=11
        )
        assert direct[cut:] == list(cont)


def test_fork_stream_sampled(params):
    """Device-local hand-off under temperature > 0: the fork inherits the
    source's seed and continues its exact stream."""
    eng = InferenceEngine(CFG, params, max_len=96, paged=True,
                          block_size=8, kv_rows=3, sampler=SAMPLER)
    prompt = np.arange(8, dtype=np.int32)
    expected = eng.generate(prompt, 24, seed=9).tokens
    src = eng.open_stream(Request(prompt, 24, seed=9))
    head = list(src.next_chunk()[0])
    head += src.next_chunk()[0]
    fork = eng.fork_stream(src, 24 - len(head))
    fork_tokens = []
    while (c := fork.next_chunk()) is not None:
        fork_tokens += c[0]
    src.cancel()
    assert head + fork_tokens == expected
    assert eng.kv.blocks_in_use == 0


def test_paged_engine_matches_dense_sampled(params, sampled_engine):
    """The paged scatter/gather path and the dense cache draw identical
    streams (frozen-row trash-block routing consumes no randomness)."""
    eng = InferenceEngine(CFG, params, max_len=96, paged=True,
                          block_size=8, kv_rows=3, sampler=SAMPLER)
    prompt = np.arange(10, dtype=np.int32)
    assert (eng.generate(prompt, 20, seed=3).tokens
            == sampled_engine.generate(prompt, 20, seed=3).tokens)


# ---------------------------------------------------------------------------
# BatchedServer: batching, preemption, and the DiSCo hand-off under sampling
# ---------------------------------------------------------------------------


def test_batched_server_matches_single_engine_sampled(params, sampled_engine):
    """Batch composition must not perturb any request's draws: per-row keys,
    not a shared stream. Seeds default to the rid."""
    server = BatchedServer(CFG, params, max_slots=2, max_len=96,
                           sampler=SAMPLER)
    prompts = [np.arange(7, dtype=np.int32),
               (np.arange(11, dtype=np.int32) * 3) % CFG.vocab,
               np.asarray([5, 2, 9], np.int32)]
    rids = [server.submit(Request(p, 9)) for p in prompts]
    expected = [sampled_engine.generate(p, 9, seed=r).tokens
                for p, r in zip(prompts, rids)]
    done = server.run_to_completion()
    for rid, exp in zip(rids, expected):
        assert done[rid] == exp


def test_preemption_replay_bit_identical_sampled(params):
    """Acceptance: a preempted-then-replayed row regenerates exactly its
    pre-preemption tokens under temperature > 0 — the requeued entry carries
    the seed and the replay prefill resumes the position counter."""
    server = BatchedServer(CFG, params, max_slots=2, max_len=48,
                           block_size=8, num_blocks=9, sampler=SAMPLER)
    engine = InferenceEngine(CFG, params, max_len=48, sampler=SAMPLER)
    prompts = [np.arange(4, dtype=np.int32),
               np.asarray([7, 3, 11, 2], np.int32)]
    rids = [server.submit(Request(p, 40)) for p in prompts]
    expected = [engine.generate(p, 40, seed=r).tokens
                for p, r in zip(prompts, rids)]
    done = server.run_to_completion()
    assert server.pool_stats()["preemptions"] >= 1
    for rid, exp in zip(rids, expected):
        assert done[rid] == exp
    assert server.kv.blocks_in_use == 0


def test_migration_under_load_sampled_bit_identical(params):
    """Acceptance: with identical endpoint models and temperature > 0, the
    delivered stream of a migrated request equals the no-migration stream —
    the driver shares one seed across the race and the hand-off replay."""
    dev = InferenceEngine(CFG, params, max_len=96, sampler=SAMPLER)
    server = BatchedServer(CFG, params, max_slots=2, max_len=96,
                           sampler=SAMPLER)
    server.warmup(prompt_lens=(16,))
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6),
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(
            rng.lognormal(2.5, 0.8, 400), 1, 64
        ).astype(int),
        budget=0.5,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.005),
    )
    disco = DiSCoServer(
        sched, DeviceEndpoint(dev),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.01, rtt_jitter=0.0)),
        rng=np.random.default_rng(7),
    )
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, CFG.vocab, size=12).astype(np.int32)
               for _ in range(4)]
    # driver seeds requests by rid = arrival index
    baseline = [dev.generate(p, 40, seed=i).tokens
                for i, p in enumerate(prompts)]
    results = disco.serve_many(
        [Request(p, 40, arrival=0.002 * i) for i, p in enumerate(prompts)]
    )
    assert any(r.migrated for r in results)
    for r, base in zip(results, baseline):
        assert r.winner is Endpoint.DEVICE
        assert r.tokens == base
