"""Per-architecture smoke tests: instantiate the REDUCED variant of each
assigned family, run one forward (train-style) step and — where applicable —
a prefill + decode step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_supported
from repro.models import decode_step, forward, init_params, prefill

BATCH, SEQ = 2, 32


def _inputs(cfg, key):
    if cfg.embed_inputs:
        return jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)
    return jax.random.normal(key, (BATCH, SEQ, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    logits, aux = forward(params, cfg, _inputs(cfg, jax.random.PRNGKey(1)))
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"
    if cfg.is_moe:
        assert float(aux) > 0.0  # load-balance loss engaged


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    inputs = _inputs(cfg, jax.random.PRNGKey(1))
    targets = jax.random.randint(jax.random.PRNGKey(2), (BATCH, SEQ), 0, cfg.vocab)

    def loss_fn(p):
        logits, aux = forward(p, cfg, inputs)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), f"{arch}: NaN grads"
    # at least the lm head must receive gradient signal
    assert float(jnp.abs(grads["lm_head"]).max()) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must match the teacher-forced forward pass."""
    cfg = get_config(arch, smoke=True)
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode phase")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0, cfg.vocab)
    max_len = SEQ + 8

    full_logits, _ = forward(params, cfg, tokens)

    n_prompt = SEQ - 4
    last, cache = prefill(params, cfg, tokens[:, :n_prompt], max_len)
    assert cache["lengths"].tolist() == [n_prompt] * BATCH
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, n_prompt - 1]),
        rtol=5e-2, atol=5e-2,
    )
    # feed the true next tokens one at a time; logits must track teacher forcing
    logits = last
    for t in range(n_prompt, SEQ):
        logits, cache = decode_step(params, cfg, cache, tokens[:, t])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=5e-2, atol=5e-2,
            err_msg=f"{arch}: decode diverges from forward at position {t}",
        )
    assert cache["lengths"].tolist() == [SEQ] * BATCH


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_constructs_and_counts(arch):
    """FULL configs build (no allocation) and match their billed sizes."""
    cfg = get_config(arch, smoke=False)
    n = cfg.param_count()
    expected = {
        "arctic-480b": (400e9, 560e9),
        "chameleon-34b": (30e9, 40e9),
        "gemma3-1b": (0.7e9, 1.6e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "olmoe-1b-7b": (5.5e9, 8e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
        "nemotron-4-340b": (300e9, 380e9),
        "minicpm3-4b": (3.2e9, 5e9),
        "codeqwen1.5-7b": (6e9, 8.5e9),
        "hymba-1.5b": (1.2e9, 2.2e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: param count {n/1e9:.2f}B"
    if cfg.is_moe:
        assert cfg.active_param_count() < n / 4


def test_shape_applicability_matrix():
    """The documented 32-runnable / 8-skip split (DESIGN.md)."""
    runnable = skipped = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            ok, reason = shape_supported(cfg, shape)
            runnable += ok
            skipped += not ok
            if not ok:
                assert reason
    assert runnable == 32 and skipped == 8
