"""Pallas kernel validation: shape/dtype sweeps, allclose vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU), plus cross-checks against
the model-side jnp implementations.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import decode_attention_op, flash_prefill_op, ssd_scan_op
from repro.kernels.ref import decode_reference, mha_reference, ssd_reference
from repro.models.attention import attention_blockwise, attention_dense
from repro.models.ssm import ssd_chunked

_TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kh,d,window,causal",
    [
        (1, 128, 4, 4, 64, 0, True),      # MHA causal
        (2, 256, 8, 2, 64, 0, True),      # GQA 4:1
        (2, 256, 4, 1, 128, 0, True),     # MQA, 128 head_dim (gemma3-like)
        (1, 256, 4, 2, 64, 64, True),     # sliding window
        (1, 128, 4, 4, 32, 0, False),     # bidirectional (hubert)
    ],
)
def test_flash_prefill_matches_ref(dtype, b, s, h, kh, d, window, causal):
    rng = np.random.default_rng(0)
    q = _rand(rng, (b, s, h, d), dtype)
    k = _rand(rng, (b, s, kh, d), dtype)
    v = _rand(rng, (b, s, kh, d), dtype)
    out = flash_prefill_op(q, k, v, causal=causal, window=window,
                           block_q=64, block_k=64, interpret=True)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_TOL[dtype]
    )


def test_flash_prefill_matches_model_blockwise():
    """Kernel, XLA-blockwise, and dense paths agree (3-way)."""
    rng = np.random.default_rng(1)
    q = _rand(rng, (2, 256, 4, 64), jnp.float32)
    k = _rand(rng, (2, 256, 2, 64), jnp.float32)
    v = _rand(rng, (2, 256, 2, 64), jnp.float32)
    a = flash_prefill_op(q, k, v, block_q=64, block_k=64, interpret=True)
    b_ = attention_blockwise(q, k, v, block_q=64, block_k=64)
    c = attention_dense(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kh,d,window",
    [
        (2, 256, 8, 2, 64, 0),
        (1, 512, 4, 1, 128, 0),          # MQA long cache
        (2, 256, 8, 8, 64, 0),           # MHA
        (2, 512, 8, 2, 64, 128),         # sliding window decode
    ],
)
def test_decode_attention_matches_ref(dtype, b, s, h, kh, d, window):
    rng = np.random.default_rng(2)
    q = _rand(rng, (b, h, d), dtype)
    kc = _rand(rng, (b, s, kh, d), dtype)          # seq-major for the oracle
    vc = _rand(rng, (b, s, kh, d), dtype)
    lengths = jnp.asarray(rng.integers(window + 2 if window else 1, s + 1, size=b), jnp.int32)
    # the kernel consumes the head-major (B, K, S, D) storage layout directly
    out = decode_attention_op(
        q, kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3), lengths,
        window=window, block_k=128, interpret=True,
    )
    ref = decode_reference(q, kc, vc, lengths, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_TOL[dtype]
    )


def test_decode_attention_ragged_lengths():
    """Per-row valid lengths mask correctly (padded cache entries ignored)."""
    rng = np.random.default_rng(3)
    q = _rand(rng, (3, 4, 64), jnp.float32)
    kc = _rand(rng, (3, 2, 256, 64), jnp.float32)  # head-major (B, K, S, D)
    vc = _rand(rng, (3, 2, 256, 64), jnp.float32)
    lengths = jnp.asarray([1, 100, 256], jnp.int32)
    out = decode_attention_op(q, kc, vc, lengths, block_k=64, interpret=True)
    # row 0 attends only position 0 -> output = v[:, :, 0] repeated per group
    expected0 = np.repeat(np.asarray(vc[0, :, 0]), 2, axis=0)
    np.testing.assert_allclose(np.asarray(out[0]), expected0, rtol=1e-5, atol=1e-5)
    # corrupting entries beyond the valid length must not change outputs
    kc2 = kc.at[1, :, 100:].set(99.0)
    out2 = decode_attention_op(q, kc2, vc, lengths, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(out2[1]), rtol=1e-6)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,t,h,p,g,n,chunk",
    [
        (2, 128, 4, 32, 1, 16, 32),
        (1, 256, 8, 64, 1, 128, 64),     # mamba2-2.7b-like head
        (2, 64, 4, 16, 2, 8, 16),        # grouped B/C
    ],
)
def test_ssd_scan_matches_sequential_ref(dtype, b, t, h, p, g, n, chunk):
    rng = np.random.default_rng(4)
    x = _rand(rng, (b, t, h, p), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, t, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 4.0, size=(h,)), jnp.float32)
    Bm = _rand(rng, (b, t, g, n), dtype)
    Cm = _rand(rng, (b, t, g, n), dtype)
    y, state = ssd_scan_op(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, sr = ssd_reference(x, dt, A, Bm, Cm)
    tol = dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else dict(rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(state), np.asarray(sr), rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_model_chunked():
    """Kernel vs the model-side XLA chunked implementation (different chunk
    sizes must agree — chunking is math-invariant)."""
    rng = np.random.default_rng(5)
    b, t, h, p, g, n = 2, 128, 4, 32, 1, 16
    x = _rand(rng, (b, t, h, p), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, t, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 4.0, size=(h,)), jnp.float32)
    Bm = _rand(rng, (b, t, g, n), jnp.float32)
    Cm = _rand(rng, (b, t, g, n), jnp.float32)
    yk, sk = ssd_scan_op(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    ym, sm = ssd_chunked(x, dt, A, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ym), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sm), rtol=3e-4, atol=3e-4)


def test_ssd_decay_bounds():
    """Property: with dt*A << 0 the state forgets; with dt -> 0 it persists."""
    b, t, h, p, g, n = 1, 64, 2, 8, 1, 4
    rng = np.random.default_rng(6)
    x = _rand(rng, (b, t, h, p), jnp.float32)
    Bm = _rand(rng, (b, t, g, n), jnp.float32)
    Cm = _rand(rng, (b, t, g, n), jnp.float32)
    A = jnp.asarray([-100.0, -100.0])
    dt_large = jnp.full((b, t, h), 1.0)
    _, state_forget = ssd_scan_op(x, dt_large, A, Bm, Cm, chunk=16, interpret=True)
    # forgetting: state ~ contribution of the last token only
    last = jnp.einsum("bhp,bhn->bhpn", x[:, -1].transpose(0, 1, 2) * 1.0,
                      jnp.repeat(Bm[:, -1], h // g, axis=1))
    np.testing.assert_allclose(
        np.asarray(state_forget), np.asarray(last), rtol=1e-3, atol=1e-3
    )
    dt_zero = jnp.full((b, t, h), 1e-8)
    _, state_keep = ssd_scan_op(x, dt_zero, A, Bm, Cm, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(state_keep), 0.0, atol=1e-4)
