"""First-class Request/QoE API tests: per-request SamplerConfig threaded as
per-row runtime operands (heterogeneous configs in ONE batch, bit-identical
to solo runs, across preemption and migration replay), deadline-aware (EDF)
admission under memory pressure, Andes-style QoE scoring on hand-built
delivery timelines, and the serve() monotonic-frontier shim."""
import dataclasses
import inspect
import math

import numpy as np
import pytest
import jax

from repro.configs import paper_models
from repro.core import CostModel, DiSCoScheduler, Endpoint, MigrationConfig
from repro.models import init_params
from repro.serving import (
    NO_SLO,
    SLO,
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    QoEReport,
    Request,
    RequestResult,
    SamplerConfig,
    ServerEndpoint,
)

CFG = paper_models.TINY_DEVICE

# a heterogeneous trio: greedy + temperature/top-p + temperature/top-k
HETERO = [
    None,
    SamplerConfig(temperature=0.8, top_p=0.9),
    SamplerConfig(temperature=1.0, top_k=20),
]


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def engine(params):
    return InferenceEngine(CFG, params, max_len=48)


# ---------------------------------------------------------------------------
# Request / SLO contract validation
# ---------------------------------------------------------------------------


def test_request_validation():
    with pytest.raises(ValueError, match="max_new"):
        Request(np.arange(4, dtype=np.int32), 0)
    with pytest.raises(ValueError, match="prompt"):
        Request(np.zeros((2, 2), np.int32), 4)
    with pytest.raises(ValueError, match="arrival"):
        Request(np.arange(4, dtype=np.int32), 4, arrival=-1.0)
    with pytest.raises(ValueError, match="cost_weight"):
        Request(np.arange(4, dtype=np.int32), 4, cost_weight=0.0)
    with pytest.raises(ValueError, match="ttft_deadline"):
        SLO(ttft_deadline=0.0)
    with pytest.raises(ValueError, match="tbt_target"):
        SLO(tbt_target=-1.0)
    r = Request([1, 2, 3], 4)          # list prompt is coerced
    assert r.prompt.dtype == np.int32 and r.prompt_len == 3
    assert not NO_SLO.constrained
    assert SLO(ttft_deadline=0.5).constrained


def test_old_tuple_apis_rejected(engine, params):
    server = BatchedServer(CFG, params, max_slots=1, max_len=48)
    with pytest.raises(TypeError, match="Request"):
        server.submit(np.arange(4, dtype=np.int32), 8)
    with pytest.raises(TypeError):
        engine.open_stream(np.arange(4, dtype=np.int32), 8)
    with pytest.raises(TypeError, match="Request"):
        engine.open_stream(np.arange(4, dtype=np.int32))


def test_endpoint_signatures_unified(engine, params):
    """Satellite: both endpoints share ONE open_stream/open_replay_stream
    signature — (req, rng, start_at) — so the driver never special-cases
    argument lists per endpoint."""
    for method in ("open_stream", "open_replay_stream"):
        dev = inspect.signature(getattr(DeviceEndpoint, method))
        srv = inspect.signature(getattr(ServerEndpoint, method))
        assert list(dev.parameters) == list(srv.parameters), method


# ---------------------------------------------------------------------------
# QoE scoring on hand-built timelines (deadline hit/miss edge cases)
# ---------------------------------------------------------------------------


def test_qoe_all_on_time_scores_one():
    slo = SLO(ttft_deadline=0.5, tbt_target=0.1)
    # arrival 1.0; tokens exactly at/before their expected times
    times = [1.4, 1.55, 1.65, 1.75]
    q = QoEReport.from_timeline(1.0, times, slo)
    assert q.qoe_score == pytest.approx(1.0)
    assert q.ttft_attained and q.slo_attained and q.late_tokens == 0
    assert q.ttft == pytest.approx(0.4)
    assert q.tbt_mean == pytest.approx((0.75 - 0.4) / 3)


def test_qoe_ttft_miss_degrades_smoothly():
    slo = SLO(ttft_deadline=0.2, tbt_target=math.inf)
    # first token 2x late -> its credit is 0.5; later tokens unconstrained
    q = QoEReport.from_timeline(0.0, [0.4, 0.5], slo)
    assert not q.ttft_attained and not q.slo_attained
    assert q.late_tokens == 1
    assert q.qoe_score == pytest.approx((0.5 + 1.0) / 2)


def test_qoe_boundary_hit_is_attained():
    slo = SLO(ttft_deadline=0.25)
    q = QoEReport.from_timeline(0.0, [0.25], slo)
    assert q.ttft_attained and q.slo_attained and q.qoe_score == pytest.approx(1.0)


def test_qoe_tbt_target_misses_count_late_tokens():
    slo = SLO(ttft_deadline=1.0, tbt_target=0.1)
    # token 2 expected by 1.2 but lands at 1.8: TTFT held, contract not
    q = QoEReport.from_timeline(0.0, [0.5, 1.05, 1.8], slo)
    assert q.ttft_attained and not q.slo_attained
    assert q.late_tokens == 1
    assert q.qoe_score < 1.0


def test_qoe_tbt_only_contract_not_inert():
    """A TBT-only SLO (infinite TTFT deadline) paces from the ACTUAL first
    token — huge inter-token gaps must be scored, not silently excused."""
    slo = SLO(tbt_target=0.1)
    ok = QoEReport.from_timeline(0.0, [0.5, 0.58, 0.66], slo)
    assert ok.slo_attained and ok.qoe_score == pytest.approx(1.0)
    bad = QoEReport.from_timeline(0.0, [0.5, 5.0, 50.0], slo)
    assert bad.ttft_attained                  # no TTFT constraint
    assert bad.late_tokens == 2 and not bad.slo_attained
    assert bad.qoe_score < 1.0


def test_qoe_no_slo_and_no_tokens():
    assert QoEReport.from_timeline(0.0, [5.0, 9.0], NO_SLO).qoe_score == 1.0
    empty = QoEReport.from_timeline(0.0, [], SLO(ttft_deadline=1.0))
    assert empty.qoe_score == 0.0 and not empty.slo_attained
    assert math.isinf(empty.ttft)


# ---------------------------------------------------------------------------
# Heterogeneous per-request samplers in ONE batch (dense + paged)
# ---------------------------------------------------------------------------


def _hetero_requests():
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, CFG.vocab, size=n).astype(np.int32)
               for n in (7, 3, 11)]
    return [Request(p, 9, sampler=s, seed=40 + i)
            for i, (p, s) in enumerate(zip(prompts, HETERO))]


@pytest.mark.parametrize("paged", [True, False])
def test_heterogeneous_batch_matches_solo_runs(params, engine, paged):
    """Acceptance: one BatchedServer batch holding greedy + top-p + top-k
    requests emits per-row streams bit-identical to running each request
    alone — on both the paged and the dense cache path."""
    server = BatchedServer(CFG, params, max_slots=3, max_len=48, paged=paged)
    reqs = _hetero_requests()
    rids = [server.submit(q) for q in reqs]
    done = server.run_to_completion()
    for rid, q in zip(rids, reqs):
        solo = engine.generate(q.prompt, q.max_new, seed=q.seed,
                               sampler=q.sampler).tokens
        assert done[rid] == solo, f"row with sampler {q.sampler} diverged"


def test_heterogeneous_batch_survives_preemption(params, engine):
    """Acceptance: recompute preemption replays a row bit-identically even
    when the batch mixes sampler configs (the resume entry carries seed AND
    sampler)."""
    server = BatchedServer(CFG, params, max_slots=2, max_len=48,
                           block_size=8, num_blocks=9)   # 8 usable: preempts
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, CFG.vocab, size=4).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(p, 40, sampler=s, seed=7 + i)
            for i, (p, s) in enumerate(zip(prompts, HETERO[1:]))]
    rids = [server.submit(q) for q in reqs]
    done = server.run_to_completion()
    assert server.pool_stats()["preemptions"] >= 1
    for rid, q in zip(rids, reqs):
        solo = engine.generate(q.prompt, q.max_new, seed=q.seed,
                               sampler=q.sampler).tokens
        assert done[rid] == solo
    assert server.kv.blocks_in_use == 0


def test_migration_replay_bit_identical_with_custom_sampler(params):
    """Acceptance: with identical endpoint models, a migrated request with a
    NON-default per-request SamplerConfig delivers the no-migration stream
    (the replay request carries the sampler across the hand-off)."""
    dev = InferenceEngine(CFG, params, max_len=96)
    server = BatchedServer(CFG, params, max_slots=2, max_len=96)
    server.warmup(prompt_lens=(16,))
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6),
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(
            rng.lognormal(2.5, 0.8, 400), 1, 64
        ).astype(int),
        budget=0.5,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.005),
    )
    disco = DiSCoServer(
        sched, DeviceEndpoint(dev),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.01, rtt_jitter=0.0)),
        rng=np.random.default_rng(7),
    )
    rng = np.random.default_rng(9)
    samplers = [SamplerConfig(temperature=0.9, top_p=0.92), None,
                SamplerConfig(temperature=0.7, top_k=32), None]
    prompts = [rng.integers(0, CFG.vocab, size=12).astype(np.int32)
               for _ in range(4)]
    baseline = [dev.generate(p, 40, seed=i, sampler=s).tokens
                for i, (p, s) in enumerate(zip(prompts, samplers))]
    results = disco.serve_many([
        Request(p, 40, arrival=0.002 * i, sampler=s)
        for i, (p, s) in enumerate(zip(prompts, samplers))
    ])
    assert any(r.migrated for r in results)
    for r, base in zip(results, baseline):
        assert r.winner is Endpoint.DEVICE
        assert r.tokens == base


# ---------------------------------------------------------------------------
# Deadline-aware (EDF) admission
# ---------------------------------------------------------------------------


def _queue_pressure_server(params, admission):
    """One row: the running request serializes admissions, so queued order
    is exactly what the admission policy decides."""
    return BatchedServer(CFG, params, max_slots=1, max_len=48,
                         block_size=8, admission=admission)


def test_edf_admits_tight_deadline_first(params):
    """A tight-deadline request arriving BEHIND two relaxed ones is admitted
    first once a row frees (EDF by absolute TTFT deadline), and the reorder
    is counted; FIFO admits in arrival order."""
    order = {}
    for admission in ("edf", "fifo"):
        server = _queue_pressure_server(params, admission)
        running = server.submit(Request(np.arange(6, dtype=np.int32), 12))
        while not server.events[running]:
            server.step()                     # occupy the single row
        loose1 = server.submit(Request(
            np.arange(6, dtype=np.int32), 4, slo=SLO(ttft_deadline=100.0)))
        loose2 = server.submit(Request(
            np.arange(6, dtype=np.int32), 4, slo=SLO(ttft_deadline=100.0)))
        tight = server.submit(Request(
            np.arange(6, dtype=np.int32), 4, slo=SLO(ttft_deadline=5.0)))
        server.run_to_completion()
        order[admission] = sorted(
            [loose1, loose2, tight], key=lambda r: server.first_token_time[r]
        )
        if admission == "edf":
            assert server.deadline_reorders >= 1
            assert order[admission][0] == tight
        else:
            assert server.deadline_reorders == 0
            assert order[admission] == [loose1, loose2, tight]


def test_expired_deadline_demoted_to_fifo(params):
    """EDF overload safety: a TTFT deadline that has ALREADY passed cannot
    be saved, so the entry loses its urgency (sorts as if deadline-free)
    instead of dominoing salvageable requests behind a lost cause."""
    server = _queue_pressure_server(params, "edf")
    running = server.submit(Request(np.arange(6, dtype=np.int32), 12))
    while not server.events[running]:
        server.step()
    # doomed arrives FIRST with an immediately-expired deadline; salvageable
    # arrives second with a real (unexpired) deadline
    doomed = server.submit(Request(
        np.arange(6, dtype=np.int32), 4, slo=SLO(ttft_deadline=1e-9)))
    salvageable = server.submit(Request(
        np.arange(6, dtype=np.int32), 4, slo=SLO(ttft_deadline=50.0)))
    server.run_to_completion()
    assert (server.first_token_time[salvageable]
            < server.first_token_time[doomed])


def test_priority_tier_outranks_deadline(params):
    """Priority-tiered EDF: a tier-0 request beats a tier-1 request with an
    earlier deadline; within a tier, EDF orders by deadline."""
    server = _queue_pressure_server(params, "edf")
    running = server.submit(Request(np.arange(6, dtype=np.int32), 12))
    while not server.events[running]:
        server.step()
    low_pri_early = server.submit(Request(
        np.arange(6, dtype=np.int32), 4,
        slo=SLO(ttft_deadline=0.01), priority=1))
    hi_pri_late = server.submit(Request(
        np.arange(6, dtype=np.int32), 4,
        slo=SLO(ttft_deadline=50.0), priority=0))
    server.run_to_completion()
    assert (server.first_token_time[hi_pri_late]
            < server.first_token_time[low_pri_early])


def test_edf_under_memory_pressure_improves_attainment(params):
    """EDF reordering under MEMORY-pressure queueing: with the pool (not the
    row count) as the binding constraint and tight/loose deadline mixes,
    deadline-aware admission attains at least as many TTFT deadlines as
    FIFO, and strictly helps the tight request stuck behind loose arrivals."""
    def run(admission):
        server = BatchedServer(CFG, params, max_slots=3, max_len=48,
                               block_size=8, num_blocks=8,   # 7 usable blocks
                               admission=admission)
        running = server.submit(Request(np.arange(20, dtype=np.int32), 10))
        while not server.events[running]:
            server.step()                  # 4+ blocks held: memory pressure
        loose = [server.submit(Request(
            np.arange(20, dtype=np.int32), 4, slo=SLO(ttft_deadline=1e4)))
            for _ in range(2)]
        tight = server.submit(Request(
            np.arange(6, dtype=np.int32), 4, slo=SLO(ttft_deadline=2.0)))
        server.run_to_completion()
        assert server.pool_stats()["queued_on_memory"] >= 1
        misses = server.pool_stats()["server_slo_misses"]
        tight_ttft = server.ttft(tight)
        return misses, tight_ttft, loose

    misses_fifo, tight_fifo, _ = run("fifo")
    misses_edf, tight_edf, _ = run("edf")
    assert misses_edf <= misses_fifo
    assert tight_edf < tight_fifo      # the tight request jumped the queue


def test_server_deadline_anchors_at_client_arrival(params):
    """With an explicit network-adjusted submit time (`at` = arrival +
    uplink, the endpoint path), the TTFT deadline anchors at the CLIENT
    arrival — not the uplink-delayed submit — so EDF slack and slo_misses
    are not inflated by the uplink."""
    server = BatchedServer(CFG, params, max_slots=1, max_len=48)
    req = Request(np.arange(6, dtype=np.int32), 4, arrival=1.0,
                  slo=SLO(ttft_deadline=0.5))
    rid = server.submit(req, at=1.2)          # 0.2s uplink
    entry = next(q for q in server.queue if q.rid == rid)
    assert entry.deadline == pytest.approx(1.5)   # 1.0 + 0.5, NOT 1.7
    # without `at`, the resolved arrival anchors (standalone server use)
    server2 = BatchedServer(CFG, params, max_slots=1, max_len=48)
    rid2 = server2.submit(Request(np.arange(6, dtype=np.int32), 4,
                                  slo=SLO(ttft_deadline=0.5)))
    entry2 = next(q for q in server2.queue if q.rid == rid2)
    assert entry2.deadline == pytest.approx(server2.clock + 0.5)


def test_slo_misses_counted(params):
    """A first token landing past its (tiny) deadline increments the
    server's slo_misses counter."""
    server = BatchedServer(CFG, params, max_slots=1, max_len=48)
    a = server.submit(Request(np.arange(6, dtype=np.int32), 4,
                              slo=SLO(ttft_deadline=1e-9)))
    b = server.submit(Request(np.arange(6, dtype=np.int32), 4))  # no SLO
    server.run_to_completion()
    assert server.ttft(a) > 1e-9 and server.ttft(b) > 0
    assert server.pool_stats()["server_slo_misses"] == 1


# ---------------------------------------------------------------------------
# DiSCo driver: serve() shim, SLO-aware dispatch, QoE-carrying results
# ---------------------------------------------------------------------------


def _make_disco(params, **kw):
    dev = InferenceEngine(CFG, params, max_len=96)
    server = BatchedServer(CFG, params, max_slots=2, max_len=96)
    server.warmup(prompt_lens=(16,))
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12),
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(
            rng.lognormal(2.5, 0.8, 400), 1, 64
        ).astype(int),
        budget=0.5,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )
    return DiSCoServer(
        sched, DeviceEndpoint(dev),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.05)),
        rng=np.random.default_rng(7), **kw,
    )


def _serve_now(disco, prompt, max_new, **req_kwargs):
    """First-class stand-in for the deprecated ``serve()`` shim: one request
    arriving at the runtime frontier."""
    at = max(disco._frontier, disco.server.server.clock)
    return disco.serve_many([Request(prompt, max_new, arrival=at,
                                     **req_kwargs)])[0]


def test_serve_shim_and_alias_warn_deprecation(params):
    """The PR-5 migration note, enforced: the positional ``serve()`` shim
    and the ``ServedRequest`` alias both emit DeprecationWarning; the
    first-class path (``serve_many`` + ``RequestResult``) stays silent."""
    disco = _make_disco(params)
    with pytest.warns(DeprecationWarning, match="serve_many"):
        r = disco.serve(np.arange(8, dtype=np.int32), 4)
    assert len(r.tokens) == 4                # the shim still works
    with pytest.warns(DeprecationWarning, match="ServedRequest"):
        import repro.serving
        assert repro.serving.ServedRequest is RequestResult
    with pytest.warns(DeprecationWarning, match="ServedRequest"):
        import repro.serving.disco_driver as dd
        assert dd.ServedRequest is RequestResult
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        res = disco.serve_many([Request(np.arange(8, dtype=np.int32), 4)])
    assert isinstance(res[0], RequestResult)


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_serve_monotonic_frontier_arrivals(params):
    """Satellite bugfix pin: repeated serve() calls stamp arrivals at
    max(frontier, server clock) — a monotonic timeline identical to the old
    tuple API's internal `at` computation — through Request.arrival.  (The
    test exercises the deprecated shim on purpose; the warning is filtered,
    tier-1 otherwise runs with ``-W error::DeprecationWarning``.)"""
    disco = _make_disco(params)
    rng = np.random.default_rng(5)
    arrivals, results = [], []
    for _ in range(4):
        expected_at = max(disco._frontier, disco.server.server.clock)
        r = disco.serve(rng.integers(0, CFG.vocab, size=10).astype(np.int32), 6)
        arrivals.append(expected_at)
        results.append(r)
        assert r.arrival == expected_at      # stamped exactly, not re-derived
    assert all(b >= a for a, b in zip(arrivals, arrivals[1:]))
    # a ready-built Request keeps the same frontier semantics
    req = Request(np.arange(8, dtype=np.int32), 6)
    r = disco.serve(req)
    assert r.arrival >= arrivals[-1]
    assert r.request.slo is NO_SLO
    # extra args next to a Request would be silently shadowed: rejected
    with pytest.raises(TypeError, match="no extra arguments"):
        disco.serve(req, 64)
    with pytest.raises(TypeError, match="no extra arguments"):
        disco.serve(req, slo=SLO(ttft_deadline=0.2))


def test_results_carry_request_and_qoe(params):
    disco = _make_disco(params)
    slo = SLO(ttft_deadline=30.0, tbt_target=10.0)   # generous: attained
    r = _serve_now(disco, np.arange(12, dtype=np.int32), 8, slo=slo,
                   cost_weight=2.0)
    assert isinstance(r, RequestResult)
    with pytest.warns(DeprecationWarning, match="ServedRequest"):
        from repro.serving import ServedRequest
    assert ServedRequest is RequestResult            # deprecated alias
    assert r.request.slo == slo
    assert r.qoe.tokens_delivered == len(r.tokens) == 8
    assert r.qoe.slo_attained and r.slo_attained
    assert r.qoe.ttft == pytest.approx(r.ttft, abs=1e-6)
    # cost_weight scales the unified cost: same request at weight 1 is half
    r1 = _serve_now(disco, np.arange(12, dtype=np.int32), 8, slo=slo)
    assert r.cost == pytest.approx(2.0 * r1.cost, rel=0.2)


def test_slo_aware_dispatch_pulls_device_into_race(params):
    """Driver dispatch consults req.slo: with a TTFT deadline the profiled
    server tail cannot meet, the device joins the race (overriding a
    server-leaning decision); with slo_aware_dispatch=False the pure cost
    policy stands."""
    from repro.core.dispatch import SingleEndpointPolicy

    tight = SLO(ttft_deadline=0.05)    # server CDF ~lognormal(log .3): miss
    aware = _make_disco(params)
    aware.sched.policy = SingleEndpointPolicy(Endpoint.SERVER)
    r = _serve_now(aware, np.arange(24, dtype=np.int32), 4, slo=tight)
    assert aware.slo_dispatch_overrides >= 1
    assert r.winner is Endpoint.DEVICE           # local prefill beats RTT
    pinned = _make_disco(params, slo_aware_dispatch=False)
    pinned.sched.policy = SingleEndpointPolicy(Endpoint.SERVER)
    r2 = _serve_now(pinned, np.arange(24, dtype=np.int32), 4, slo=tight)
    assert pinned.slo_dispatch_overrides == 0
    assert r2.winner is Endpoint.SERVER          # baseline stayed pure


def test_serve_many_rejects_tuples(params):
    disco = _make_disco(params)
    with pytest.raises(TypeError, match="tuple API was removed"):
        disco.serve_many([(0.0, np.arange(4, dtype=np.int32), 4)])


def test_request_replace_is_nonmutating(params):
    """The runtime resolves rid/seed on a COPY: the caller's Request object
    is never mutated by serving it."""
    disco = _make_disco(params)
    req = Request(np.arange(10, dtype=np.int32), 5)
    disco.serve_many([req])
    assert req.seed is None and req.rid is None
    frozen = dataclasses.replace(req, seed=3)
    assert frozen.seed == 3 and req.seed is None
