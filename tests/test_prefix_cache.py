"""Prefix-sharing paged KV cache: radix prefix index hits at admission,
copy-on-write clone/fork, SLO-aware preemption victims, graceful fork
fallback, and the refcount conservation invariant.

The load-bearing acceptance properties live here:
  * a prefix HIT changes only WHAT is computed (the unmatched suffix), never
    the delivered stream — warm and cold runs are bitwise-identical under
    mixed temperature > 0 samplers;
  * full (sealed) shared blocks are aliased with ZERO device copies
    (``kv.copy_ops`` counts the pool's actual copy pairs);
  * admission counts shared blocks once (no phantom ``queued_on_memory``);
  * every allocation path — admit, extend, clone, prefix insert, eviction —
    conserves blocks: after all releases + a cache flush the free list is
    exactly the initial pool.
"""
import numpy as np
import pytest
import jax

from repro.configs import paper_models
from repro.models import init_params
from repro.models.sampling import SamplerConfig
from repro.serving import (
    BatchedServer,
    InferenceEngine,
    Request,
    SLO,
)
from repro.serving.kv_pool import BlockPool, KVPoolManager, blocks_for_tokens

CFG = paper_models.TINY_DEVICE
SAMPLER = SamplerConfig(temperature=0.8, top_p=0.95)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Tentpole: prefix hits are compute-only — streams stay bitwise-identical
# ---------------------------------------------------------------------------


def _two_wave_run(params, prefix_cache: bool):
    """Wave 1 populates (or not) the cache; wave 2 reuses the shared system
    prompt. Mixed samplers with temperature > 0 so any numeric drift in the
    suffix-prefill path would surface as a different sampled token."""
    rng = np.random.default_rng(7)
    system = rng.integers(1, CFG.vocab, size=37).tolist()
    samplers = [SamplerConfig(temperature=0.8, top_k=20),
                SamplerConfig(temperature=0.7, top_p=0.9),
                SamplerConfig()]                      # greedy in the mix
    srv = BatchedServer(CFG, params, max_slots=4, max_len=128, paged=True,
                        block_size=16, num_blocks=24,
                        prefix_cache=prefix_cache)
    prompts = [np.asarray(system + rng.integers(1, CFG.vocab, size=n).tolist(),
                          np.int32) for n in (9, 14, 5)]
    rids = [srv.submit(Request(p, 8, arrival=float(i), sampler=samplers[i],
                               seed=100 + i))
            for i, p in enumerate(prompts)]
    done = dict(srv.run_to_completion())
    rng2 = np.random.default_rng(99)
    wave2 = [np.asarray(system + rng2.integers(1, CFG.vocab, size=n).tolist(),
                        np.int32) for n in (11, 6)]
    rids += [srv.submit(Request(p, 8, sampler=samplers[i], seed=200 + i))
             for i, p in enumerate(wave2)]
    done.update(srv.run_to_completion())
    return [done[r] for r in rids], srv.pool_stats()


def test_prefix_hit_streams_bitwise_identical(params):
    cold, cold_stats = _two_wave_run(params, prefix_cache=False)
    warm, warm_stats = _two_wave_run(params, prefix_cache=True)
    assert warm == cold                              # bitwise, sampled rows too
    assert cold_stats["prefix_cache"] is False
    assert warm_stats["prefix_hits"] >= 2            # both wave-2 requests hit
    assert warm_stats["prefix_hit_rate"] > 0
    assert warm_stats["blocks_saved"] >= 4           # 37-token system = 2 blocks
    assert warm_stats["copy_ops"] == 0               # aliasing, never copying
    # the saved blocks are real compute savings, not bookkeeping:
    assert (warm_stats["prefill_tokens_computed"]
            < cold_stats["prefill_tokens_computed"])
    assert (warm_stats["prefill_compute_per_admitted_token"]
            < cold_stats["prefill_compute_per_admitted_token"])


def test_admission_counts_shared_blocks_once():
    """Shared blocks are demanded ONCE: a prefix-hit admission allocates only
    the unmatched suffix, fits where a fresh full-prompt allocation would
    not, and never evicts the very prefix it just matched."""
    kv = KVPoolManager(num_blocks=7, block_size=8, rows=3,
                       max_blocks_per_row=6, prefix_cache=True)
    toks = list(range(1, 33))                        # 4 full blocks
    t = kv.admit(1, 5, num_tokens=32)                # 4 sealed + decode room
    kv.release(1, cache_tokens=toks)                 # register 4 blocks
    assert kv.blocks_cached == 4
    matched = kv.prefix_match(toks + [77, 78])       # 5-block prompt, 4 hit
    assert len(matched) == 4 and matched == t.blocks[:4]
    full_demand = kv.prefill_demand(40, 34)
    assert full_demand > kv.pool.num_free            # fresh alloc can't fit...
    t2 = kv.admit(2, full_demand - len(matched), num_tokens=34,
                  prefix_blocks=matched)             # ...but the suffix can
    assert t2 is not None and t2.blocks[:4] == matched
    assert t2.num_prefix == 4
    assert kv.prefix_evictions == 0                  # matched prefix untouched
    assert kv.blocks_cached == 4
    assert kv.blocks_in_use == 5                     # 4 shared ONCE + 1 fresh
    # a third sharer still fits (1 free block for its suffix)...
    m3 = kv.prefix_match(toks + [9])
    t3 = kv.admit(3, full_demand - len(m3), num_tokens=33, prefix_blocks=m3)
    assert t3 is not None and kv.blocks_in_use == 6
    # ...and the exact-headroom probe refuses a fourth: zero free, and the
    # matched blocks are excluded from evictable headroom (no self-eviction).
    m4 = kv.prefix_match(toks + [10], record=False)
    assert not kv.can_admit(full_demand - len(m4), rid=4, prefix_blocks=m4)
    assert 4 in kv.memory_waits                      # honest queued_on_memory
    kv.release(2)
    kv.release(3)
    kv.flush_prefix_cache()
    assert kv.blocks_in_use == 0


def test_lru_eviction_under_pressure():
    """Unpinned cached prefixes are reclaimable headroom: admission evicts
    least-recently-touched leaves instead of refusing, and never evicts a
    block the incoming request just matched."""
    kv = KVPoolManager(num_blocks=7, block_size=4, rows=3,
                       max_blocks_per_row=6, prefix_cache=True)
    a = list(range(1, 9))                            # 2 blocks
    b = list(range(101, 109))                        # 2 blocks, distinct
    kv.admit(1, 2, num_tokens=8)
    kv.release(1, cache_tokens=a)
    kv.admit(2, 2, num_tokens=8)
    kv.release(2, cache_tokens=b)
    assert kv.blocks_cached == 4 and kv.pool.num_free == 2
    m = kv.prefix_match(b + [7])                     # touch b: now MRU
    t = kv.admit(3, 3, num_tokens=9, prefix_blocks=m)  # needs eviction of a
    assert t is not None and t.blocks[:2] == m       # b survived (matched+MRU)
    assert kv.prefix_evictions >= 1
    # leaf-first LRU: a's DEEPEST block went first, b's chain is intact
    assert len(kv.prefix_match(a + [7], record=False)) <= 1
    assert kv.prefix_match(b + [7], record=False) == m
    kv.release(3)
    kv.flush_prefix_cache()
    assert kv.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Satellite 1: SLO-aware preemption victim selection
# ---------------------------------------------------------------------------


def test_preemption_spares_tight_deadline(params):
    """Pool pressure evicts the RELAXED request (no deadline, admitted
    first), not the tight-deadline one admitted after it — the old
    newest-admitted-first policy would have picked the opposite victim.
    Both streams still finish bit-identical to unpressured runs."""
    server = BatchedServer(CFG, params, max_slots=2, max_len=48,
                           block_size=8, num_blocks=9, sampler=SAMPLER,
                           admission="fifo")
    engine = InferenceEngine(CFG, params, max_len=48, sampler=SAMPLER)
    relaxed = server.submit(Request(np.arange(4, dtype=np.int32), 40))
    tight = server.submit(Request(np.asarray([7, 3, 11, 2], np.int32), 40,
                                  slo=SLO(ttft_deadline=0.25)))
    victims = []
    orig = server._preempt
    server._preempt = lambda rid: (victims.append(rid), orig(rid))[1]
    done = server.run_to_completion()
    assert server.pool_stats()["preemptions"] >= 1
    assert relaxed in victims and tight not in victims
    for rid, prompt in ((relaxed, np.arange(4, dtype=np.int32)),
                        (tight, np.asarray([7, 3, 11, 2], np.int32))):
        assert done[rid] == engine.generate(prompt, 40, seed=rid).tokens
    assert server.kv.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Satellite 2: graceful fork_stream degradation
# ---------------------------------------------------------------------------


def test_fork_falls_back_to_replay_when_clone_impossible(params):
    """Clone exhaustion no longer raises mid-migration: the fork degrades to
    a queued re-prefill (replaying prompt + emitted, bit-identical under the
    position-keyed sampler) and the pool surfaces a ``clone_fallbacks``
    stat."""
    eng = InferenceEngine(CFG, params, max_len=48, paged=True,
                          block_size=8, kv_rows=2)
    src = eng.open_stream(Request(np.arange(8, dtype=np.int32), 24,
                                  sampler=SAMPLER, seed=5))
    src_tokens = list(src.next_chunk()[0])
    src_tokens += src.next_chunk()[0]
    blocker = eng.open_stream(Request(np.arange(30, dtype=np.int32), 4))
    blocker.next_chunk()                             # occupies the last row
    fork = eng.fork_stream(src, 24 - len(src_tokens))
    assert eng.kv.clone_fallbacks == 1               # degraded, didn't raise
    blocker.cancel()                                 # room for the re-prefill
    fork_tokens = []
    while (c := fork.next_chunk()) is not None:
        fork_tokens += c[0]
    rest = []
    while (c := src.next_chunk()) is not None:
        rest += c[0]
    assert fork_tokens == rest                       # replay is lossless
    assert eng.kv.blocks_in_use == 0


def test_fork_fallback_oom_is_soft(params):
    """If even the fallback re-prefill cannot be admitted, the fork reports
    ``oom``/exhausted instead of raising at pull time."""
    eng = InferenceEngine(CFG, params, max_len=48, paged=True,
                          block_size=8, kv_rows=2)
    src = eng.open_stream(Request(np.arange(8, dtype=np.int32), 24))
    src.next_chunk()
    blocker = eng.open_stream(Request(np.arange(30, dtype=np.int32), 4))
    blocker.next_chunk()
    fork = eng.fork_stream(src, 20)
    assert eng.kv.clone_fallbacks == 1
    assert fork.next_chunk() is None                 # soft-fail, no raise
    assert fork.exhausted and fork.oom
    src.cancel()
    blocker.cancel()
    assert eng.kv.blocks_in_use == 0


def test_fork_clone_is_zero_copy_for_sealed_blocks(params):
    """Acceptance: migration/fork hand-off performs zero device block copies
    for full shared blocks — at most ONE copy pair (the partial tail)."""
    eng = InferenceEngine(CFG, params, max_len=48, paged=True,
                          block_size=8, kv_rows=3)
    src = eng.open_stream(Request(np.arange(8, dtype=np.int32), 24))
    src_tokens = list(src.next_chunk()[0])
    src_tokens += src.next_chunk()[0]
    fork = eng.fork_stream(src, 24 - len(src_tokens))
    n_tok = eng.kv.tables[fork._rid].num_tokens
    expect = 1 if n_tok % 8 else 0
    assert eng.kv.copy_ops == expect                 # CoW tail only
    assert eng.kv.tables[fork._rid].num_prefix == n_tok // 8
    src.cancel()
    fork.cancel()
    assert eng.kv.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Satellite 3: refcount conservation invariant (property-style trace replay)
# ---------------------------------------------------------------------------


def test_refcount_invariant_random_trace():
    """Replay a randomized trace of admits (with prefix hits), extends,
    clones (migrations), cancels, and releases-with-caching against a small
    pool; after releasing everything and flushing the cache the free list
    must return EXACTLY to its initial size — no leak, no double-free."""
    rng = np.random.default_rng(42)
    bs = 4
    kv = KVPoolManager(num_blocks=33, block_size=bs, rows=8,
                       max_blocks_per_row=12, prefix_cache=True)
    initial_free = kv.pool.num_free
    systems = [list(rng.integers(1, 500, size=n)) for n in (9, 13, 5)]
    live: dict[int, list[int]] = {}
    next_rid = 0
    for _ in range(300):
        op = rng.integers(0, 10)
        if op < 4 or not live:                       # admit
            toks = list(systems[int(rng.integers(0, len(systems)))]) + list(
                rng.integers(1, 500, size=int(rng.integers(1, 10))))
            matched = kv.prefix_match(toks)
            demand = blocks_for_tokens(len(toks) + 8, bs) - len(matched)
            if kv.has_free_row and kv.can_admit(demand, next_rid,
                                                prefix_blocks=matched):
                t = kv.admit(next_rid, demand, num_tokens=len(toks),
                             prefix_blocks=matched)
                assert t is not None                 # can_admit was exact
                live[next_rid] = toks
                next_rid += 1
        elif op < 6:                                 # extend toward decode
            rid = int(rng.choice(list(live)))
            tgt = min(kv.tables[rid].num_tokens + int(rng.integers(1, 9)),
                      12 * bs)
            if kv.extend(rid, tgt):
                grown = tgt - len(live[rid])
                live[rid] += list(rng.integers(1, 500, size=max(grown, 0)))
                kv.tables[rid].num_tokens = tgt
        elif op < 7 and kv.has_free_row:             # clone (migration)
            src = int(rng.choice(list(live)))
            res = kv.clone(src, next_rid)
            if res is not None:
                live[next_rid] = list(live[src][:res[0].num_tokens])
                next_rid += 1
        elif op < 9:                                 # release, register prefix
            rid = int(rng.choice(list(live)))
            toks = live.pop(rid)
            kv.release(rid, cache_tokens=toks[:kv.tables[rid].num_tokens]
                       if rid in kv.tables else toks)
        else:                                        # cancel: no caching
            rid = int(rng.choice(list(live)))
            live.pop(rid)
            kv.release(rid)
    for rid in list(live):
        kv.release(rid, cache_tokens=live.pop(rid))
    assert kv.blocks_in_use == kv.blocks_cached      # only the cache holds on
    kv.flush_prefix_cache()
    assert kv.blocks_in_use == 0
    assert kv.pool.num_free == initial_free          # exact conservation
    assert not kv.tables


def test_blockpool_refcount_safety():
    pool = BlockPool(6)
    (b,) = pool.alloc(1)
    assert pool.ref(b) == 1
    assert pool.incref(b) == 2
    assert pool.decref(b) == 1
    assert pool.decref(b) == 0                       # returns to free list
    with pytest.raises(ValueError, match="free"):
        pool.decref(b)                               # double-decref caught
    a, c = pool.alloc(2)
    pool.incref(c)
    pool.free([a, c])                                # a freed, c survives
    assert pool.ref(c) == 1
    with pytest.raises(ValueError):
        pool.free([c, c])                            # duplicate batch caught
    pool.free([c])
    assert pool.num_free == 5
