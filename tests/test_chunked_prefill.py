"""Chunked (piecewise) prefill: the load-bearing acceptance properties.

  * piecewise prefill is BITWISE-identical to whole-prompt prefill — pages
    and sampled token — at the model layer (``paged_piece_prefill``),
    including the padding-piece-skip case where the true last position lands
    before the final bucket piece, under temperature > 0 samplers;
  * the chunk-offset causal mask agrees between the Pallas kernel
    (interpret=True), the XLA reference, and a slice of the full-prompt run;
  * a chunked ``BatchedServer`` delivers streams bit-identical to the
    monolithic server under mixed samplers, cancels, and pool-pressure
    preemption of a half-prefilled prompt;
  * the piece-size bucketing keeps the compile budget bounded:
    <= log2(chunk)+1 distinct prefill shapes for any budget sweep, one
    piece shape per bucket (same bound ``_tail_sizes`` gives decode);
  * ``make_interference_trace`` emits the advertised mixed-length workload.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper_models
from repro.kernels.ops import flash_prefill_op
from repro.kernels.ref import mha_reference
from repro.models import init_params
from repro.models.attention import attention_blockwise, attention_dense
from repro.models.paged import (
    init_paged_pages,
    paged_piece_prefill,
    paged_prefill,
)
from repro.models.sampling import SamplerConfig
from repro.serving import BatchedServer, Request, SLO
from repro.serving.engine import (
    _check_prefill_chunk,
    _piece_steps,
    _tail_sizes,
    _tail_steps,
)
from repro.sim.traces import make_interference_trace

CFG = paper_models.TINY_DEVICE
BS = 16


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Model layer: piecewise == monolithic, bitwise
# ---------------------------------------------------------------------------


def _piecewise(params, pages, padded, s, piece, sampler, keys, block_ids):
    """Issue the prompt piece by piece, engine-style: stop at the piece
    containing the true last position (pure-padding pieces never run)."""
    full_bt = jnp.asarray([block_ids], jnp.int32)
    tok, n = None, 0
    while n < s:
        ids = jnp.asarray(block_ids[n // BS:(n + piece) // BS], jnp.int32)
        tok, pages = paged_piece_prefill(
            params, CFG, pages,
            jnp.asarray(padded[:, n:n + piece], jnp.int32),
            jnp.asarray([s], jnp.int32), full_bt,
            jnp.asarray(n, jnp.int32), ids, sampler=sampler, keys=keys,
        )
        n += piece
    return tok, pages, n


@pytest.mark.parametrize("piece", [16, 32])
@pytest.mark.parametrize(
    "sampler", [None, SamplerConfig(temperature=0.8, top_p=0.95)]
)
def test_piecewise_prefill_bitwise_matches_monolithic(params, piece, sampler):
    # s=37 in a 64-bucket: position 36 sits in the 16-token piece [32, 48),
    # so with piece=16 the last bucket piece [48, 64) is pure padding and
    # must be SKIPPED (the engine's `final = n_done >= s` path)
    rng = np.random.default_rng(5)
    s, sb = 37, 64
    padded = np.zeros((1, sb), np.int64)
    padded[0, :s] = rng.integers(1, CFG.vocab, size=s)
    block_ids = np.asarray([3, 1, 4, 2], np.int32)     # non-contiguous
    keys = jnp.asarray([[123, 456]], jnp.uint32)

    tok_m, pages_m = paged_prefill(
        params, CFG, init_paged_pages(CFG, 8, BS),
        jnp.asarray(padded, jnp.int32), jnp.asarray([s], jnp.int32),
        jnp.asarray(block_ids), sampler=sampler, keys=keys,
    )
    tok_p, pages_p, n_done = _piecewise(
        params, init_paged_pages(CFG, 8, BS), padded, s, piece,
        sampler, keys, block_ids,
    )
    if piece == 16:
        assert n_done == 48 < sb                       # padding piece skipped
    assert int(np.asarray(tok_m)[0]) == int(np.asarray(tok_p)[0])
    # every block a piece wrote matches the monolithic pages bitwise (the
    # skipped padding piece's blocks stay zero — masked at read time, and
    # overwritten by decode before any query reaches them)
    written = block_ids[: n_done // BS]
    for key in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(pages_m[key][:, written]),
            np.asarray(pages_p[key][:, written]),
        )


# ---------------------------------------------------------------------------
# Kernel layer: chunk-offset causal mask parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("offset", [0, 32, 96])
def test_flash_prefill_q_offset_interpret_parity(offset):
    """Kernel (interpret), XLA reference, and a slice of the full-prompt
    run agree: a piece of queries at absolute positions offset+arange."""
    rng = np.random.default_rng(2)
    s, h, kh, d, piece = 128, 4, 2, 64, 32
    q = jnp.asarray(rng.normal(size=(1, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, s, kh, d)), jnp.float32)
    qp = q[:, offset:offset + piece]
    out = flash_prefill_op(qp, k, v, causal=True, q_offset=offset,
                           block_q=32, block_k=64, interpret=True)
    ref = mha_reference(qp, k, v, causal=True, q_offset=offset)
    full = mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(full[:, offset:offset + piece]),
                               rtol=2e-5, atol=2e-5)


def test_attention_q_offset_blockwise_matches_dense():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    a = attention_blockwise(q, k, v, q_offset=64, block_q=32, block_k=64)
    b = attention_dense(q, k, v, q_offset=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Server layer: chunked scheduling is invisible in the streams
# ---------------------------------------------------------------------------


def _serve(params, prefill_chunk, *, cancel_idx=None):
    srv = BatchedServer(CFG, params, max_slots=3, max_len=128, paged=True,
                        block_size=BS, num_blocks=40,
                        prefill_chunk=prefill_chunk)
    rng = np.random.default_rng(11)
    samplers = [None, SamplerConfig(temperature=0.8, top_k=20),
                SamplerConfig(temperature=0.7, top_p=0.9)]
    rids = [srv.submit(Request(
        rng.integers(1, CFG.vocab, size=n).astype(np.int32), 10,
        arrival=0.01 * i, sampler=samplers[i % 3], seed=100 + i,
        slo=SLO(ttft_deadline=5.0),
    )) for i, n in enumerate((70, 9, 90, 12, 50))]
    if cancel_idx is not None:
        srv.cancel(rids[cancel_idx])
    done = dict(srv.run_to_completion())
    return [done[r] for r in rids], srv


def test_server_chunked_streams_identical(params):
    mono, _ = _serve(params, None)
    chunked, srv = _serve(params, 32)
    assert chunked == mono                  # bitwise, sampled rows included
    assert srv.pool_stats()["prefill_chunk"] == 32
    # the long prompts really were split (70->3 pieces, 90->3 pieces)
    assert srv.pool_stats()["prefill_tokens_computed"] > 0


def test_server_chunked_cancel_matches_monolithic(params):
    mono, _ = _serve(params, None, cancel_idx=2)
    chunked, _ = _serve(params, 32, cancel_idx=2)
    assert chunked == mono


def test_server_chunked_preemption_lossless(params):
    """Pool pressure mid-run: the newest admission is preempted and
    replayed; streams still match the monolithic run.

    Deterministic collision: 36 usable blocks, r1 (20-token prompt, 60 new)
    grows 2 -> 5 blocks while r2 (512-token prompt) holds 33 — the pool runs
    dry regardless of wall-clock. Under chunking r2's prefill is 16 pieces
    interleaved 1:1 with decode, so the preemption lands on a HALF-PREFILLED
    partial (the ``_preempt_partial`` path); monolithic preempts it
    mid-decode. Both must replay losslessly."""
    def run(chunk):
        srv = BatchedServer(CFG, params, max_slots=2, max_len=544, paged=True,
                            block_size=BS, num_blocks=37, prefill_chunk=chunk)
        rng = np.random.default_rng(3)
        r1 = srv.submit(Request(
            rng.integers(1, CFG.vocab, size=20).astype(np.int32), 60,
            seed=1, sampler=SamplerConfig(temperature=0.9, top_p=0.9),
        ))
        r2 = srv.submit(Request(
            rng.integers(1, CFG.vocab, size=512).astype(np.int32), 32,
            seed=2, sampler=SamplerConfig(temperature=0.8, top_k=40),
        ))
        done = dict(srv.run_to_completion())
        return [done[r1], done[r2]], srv.kv.preemptions
    mono, pre_m = run(None)
    chunked, pre_c = run(32)
    assert chunked == mono
    assert pre_m >= 1 and pre_c >= 1        # the pool actually ran dry


def test_prefill_chunk_requires_paged_and_block_multiple(params):
    with pytest.raises(ValueError):
        BatchedServer(CFG, params, max_slots=2, max_len=64, paged=False,
                      prefill_chunk=32)
    with pytest.raises(ValueError):
        BatchedServer(CFG, params, max_slots=2, max_len=64, paged=True,
                      block_size=BS, prefill_chunk=8)   # < block_size


# ---------------------------------------------------------------------------
# Piece bucketing: compile budget stays bounded
# ---------------------------------------------------------------------------


def test_tail_steps_properties():
    for chunk in (1, 2, 4, 8, 16):
        for n in range(1, chunk + 1):
            t = _tail_steps(n, chunk)
            assert n <= t <= chunk
            assert t & (t - 1) == 0                    # power of two
        sizes = _tail_sizes(chunk)
        assert sizes == sorted(set(sizes))
        assert len(sizes) == chunk.bit_length()        # log2(chunk)+1
    assert _tail_sizes(8) == [1, 2, 4, 8]


def test_check_prefill_chunk_normalization():
    assert _check_prefill_chunk(16, 16) == 16
    assert _check_prefill_chunk(48, 16) == 32          # floored to pow2
    assert _check_prefill_chunk(129, 16) == 128
    with pytest.raises(ValueError):
        _check_prefill_chunk(8, 16)                    # below block_size


def test_piece_steps_compile_budget():
    chunk = 64
    shapes = set()
    for sb in (16, 32, 64, 128, 256, 512):             # pow2 buckets
        steps = _piece_steps(sb, chunk)
        assert len(set(steps)) == 1                    # ONE shape per bucket
        if sb <= chunk:
            assert steps == [sb]                       # monolithic dispatch
        else:
            assert steps == [chunk] * (sb // chunk)
            assert sum(steps) == sb                    # nothing dropped
        shapes |= set(steps)
    # any budget sweep compiles at most log2(chunk)+1 distinct piece shapes
    assert len(shapes) <= chunk.bit_length()
    assert _piece_steps(64, 0) == [64]                 # chunking off


# ---------------------------------------------------------------------------
# Telemetry: per-piece spans sum into prefill_s; decode_stall_s attributes
# ---------------------------------------------------------------------------


def test_ttft_attribution_sums_pieces_and_decode_stall():
    """A chunked prefill emits one server span per piece: all pieces sum
    into prefill_s (queue wait rides the first piece only), and OTHER
    requests' prefill overlapping a request's streaming phase lands in
    decode_stall_s."""
    from repro.serving.telemetry import ttft_attribution

    us = 1e6

    def span(srv_rid, ts, dur, **extra):
        return {"ph": "X", "cat": "server", "name": "prefill", "pid": 1,
                "tid": 1, "ts": ts * us, "dur": dur * us,
                "args": {"rid": srv_rid, **extra}}

    trace = {"traceEvents": [
        # request A: first token at 1.0s, ends at 3.0s
        {"ph": "b", "cat": "request", "id": 1, "ts": 0.0, "name": "req"},
        {"ph": "n", "cat": "request", "id": 1, "ts": 0.0, "name": "req",
         "args": {"event": "dispatch", "srv_rid": 10}},
        {"ph": "n", "cat": "request", "id": 1, "ts": 1.0 * us, "name": "req",
         "args": {"event": "first_token", "ttft_s": 1.0}},
        {"ph": "e", "cat": "request", "id": 1, "ts": 3.0 * us, "name": "req",
         "args": {"outcome": "completed"}},
        # request B: first token at 2.6s
        {"ph": "b", "cat": "request", "id": 2, "ts": 0.5 * us, "name": "req"},
        {"ph": "n", "cat": "request", "id": 2, "ts": 0.5 * us, "name": "req",
         "args": {"event": "dispatch", "srv_rid": 20}},
        {"ph": "n", "cat": "request", "id": 2, "ts": 2.6 * us, "name": "req",
         "args": {"event": "first_token", "ttft_s": 2.1}},
        {"ph": "e", "cat": "request", "id": 2, "ts": 3.0 * us, "name": "req",
         "args": {"outcome": "completed"}},
        # A's prefill: two pieces, queue wait on the first only
        span(10, 0.1, 0.2, piece=0, queue_wait_s=0.05),
        span(10, 0.4, 0.1, piece=1),
        # B's prefill: 1.5s-2.5s — entirely inside A's streaming phase
        span(20, 1.5, 1.0, queue_wait_s=0.0),
    ]}
    rows = {r["rid"]: r for r in ttft_attribution(trace)}
    a, b = rows[1], rows[2]
    assert a["prefill_s"] == pytest.approx(0.3)      # pieces sum
    assert a["queue_s"] == pytest.approx(0.05)       # first piece only
    assert a["decode_stall_s"] == pytest.approx(1.0)  # B's prefill overlap
    assert b["prefill_s"] == pytest.approx(1.0)
    assert b["decode_stall_s"] == pytest.approx(0.0)  # A prefilled earlier


# ---------------------------------------------------------------------------
# Interference trace generator
# ---------------------------------------------------------------------------


def test_interference_trace_statistics():
    n = 32
    tr = make_interference_trace(
        np.random.default_rng(0), n, service_time=0.5, slots=4, rho=0.8,
        short_prompt=8, short_new=24, long_prompt=128, long_every=8,
        long_new=8,
    )
    assert len(tr) == n
    arrivals = [a for a, _, _ in tr]
    assert arrivals == sorted(arrivals) and arrivals[0] >= 0.0
    for i, (_, plen, mnew) in enumerate(tr):
        if i % 8 == 7:                                 # every 8th is long
            assert (plen, mnew) == (128, 8)
        else:
            assert (plen, mnew) == (8, 24)
    assert sum(p == 128 for _, p, _ in tr) == n // 8


def test_interference_trace_jitter_randomizes_cadence():
    """Jitter resamples positions (rate-preserving in expectation, not in
    count): every entry is still one of the two request shapes and both
    kinds survive."""
    n = 48
    tr = make_interference_trace(
        np.random.default_rng(1), n, service_time=0.5, slots=4, rho=0.8,
        long_prompt=128, long_every=6, jitter=0.5,
    )
    assert len(tr) == n
    assert {(p, m) for _, p, m in tr} <= {(128, 8), (8, 24)}
    n_long = sum(p == 128 for _, p, _ in tr)
    assert 0 < n_long < n


def test_interference_trace_rejects_degenerate_cadence():
    with pytest.raises(ValueError):
        make_interference_trace(np.random.default_rng(0), 8,
                                service_time=0.1, slots=2, rho=0.5,
                                long_every=1)
