"""Paged KV-cache subsystem tests: block-pool allocator lifecycle
(exhaustion -> queueing, free-on-cancel reuse, copy-on-migration),
capacity-driven BatchedServer admission with recompute preemption,
paged-vs-dense decode equivalence (kernel interpret parity included),
and cancel-propagation latency accounting."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import paper_models
from repro.kernels.paged_decode_attention import (
    paged_decode_attention,
    paged_decode_attention_ref,
    paged_gather_kv,
)
from repro.kernels.ref import decode_reference
from repro.models import init_params, supports_paged
from repro.serving import (
    BatchedServer,
    BlockPool,
    InferenceEngine,
    KVPoolManager,
    Request,
)

CFG = paper_models.TINY_DEVICE


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def dense_engine(params):
    return InferenceEngine(CFG, params, max_len=48)


# ---------------------------------------------------------------------------
# BlockPool / KVPoolManager (host-side allocator)
# ---------------------------------------------------------------------------


def test_block_pool_alloc_free_reuse():
    pool = BlockPool(6)              # block 0 reserved -> 5 usable
    assert pool.num_free == 5
    a = pool.alloc(3)
    assert a is not None and 0 not in a
    assert pool.alloc(3) is None     # all-or-nothing: only 2 left
    assert pool.num_free == 2        # the failed alloc took nothing
    pool.free(a)
    b = pool.alloc(3)
    assert b == a                    # LIFO: freed blocks come back first
    assert pool.peak_in_use == 3
    with pytest.raises(ValueError):
        pool.free([0])               # trash block is not freeable
    with pytest.raises(ValueError):
        pool.free(b + [b[0]])        # double free


def test_manager_admit_extend_release():
    kv = KVPoolManager(num_blocks=8, block_size=8, rows=2, max_blocks_per_row=6)
    t1 = kv.admit(1, kv.prefill_demand(16, 10), num_tokens=10)   # 2 blocks
    assert t1 is not None and t1.capacity == 2
    assert kv.extend(1, 17)          # crosses a boundary -> 3 blocks
    assert kv.tables[1].capacity == 3
    assert kv.extend(1, 20)          # same block, no alloc
    assert kv.tables[1].capacity == 3
    t2 = kv.admit(2, 4, num_tokens=20)
    assert t2 is not None
    assert kv.blocks_in_use == 7
    assert not kv.extend(1, 30)      # pool dry: table unchanged, rid recorded
    assert kv.tables[1].capacity == 3
    assert 1 in kv.extend_stalls     # decode stall, NOT admission queueing
    assert 1 not in kv.memory_waits
    kv.release(2)
    assert kv.blocks_in_use == 3 and kv.has_free_row
    assert kv.extend(1, 30)


def test_manager_exhaustion_blocks_admission_not_rows():
    kv = KVPoolManager(num_blocks=6, block_size=8, rows=4, max_blocks_per_row=5)
    assert kv.admit(1, 4) is not None
    # rows are free, memory is not: the queued-on-memory signal fires
    assert not kv.can_admit(2, rid=7)
    assert kv.has_free_row and 7 in kv.memory_waits
    assert kv.admit(7, 2) is None
    kv.release(1)
    assert kv.admit(7, 2) is not None


def test_manager_clone_copy_on_migration():
    """Clone is alias-on-migration: sealed (full) blocks are shared via a
    refcount bump — zero device copies — and only a partial tail block is
    copy-on-write'd."""
    kv = KVPoolManager(num_blocks=12, block_size=8, rows=3, max_blocks_per_row=6)
    src = kv.admit(1, 3, num_tokens=20)              # 2 sealed + partial tail
    res = kv.clone(1, 2)
    assert res is not None
    dst, pairs = res
    assert dst.blocks[:2] == src.blocks[:2]          # sealed blocks aliased
    assert dst.blocks[2] != src.blocks[2]            # tail gets a fresh block
    assert pairs == [(src.blocks[2], dst.blocks[2])]  # ONE device copy: tail
    assert kv.copy_ops == 1
    assert dst.num_tokens == src.num_tokens and dst.row != src.row
    assert kv.blocks_in_use == 4                     # 3 src + 1 CoW tail
    kv.release(1)                                    # source free'd, clone lives
    assert 2 in kv.tables and kv.blocks_in_use == 3  # shared blocks survive
    assert kv.clone(2, 3) is not None
    assert kv.clone(2, 4) is not None
    assert kv.clone(2, 5) is None                    # rows exhausted
    kv2 = KVPoolManager(num_blocks=5, block_size=8, rows=3, max_blocks_per_row=4)
    kv2.admit(1, 3)
    assert kv2.clone(1, 2) is None                   # blocks exhausted
    assert 2 in kv2.extend_stalls


def test_manager_clone_block_aligned_is_metadata_only():
    """A block-aligned source (num_tokens % block_size == 0) clones with NO
    device copies and NO fresh data blocks beyond unwritten capacity."""
    kv = KVPoolManager(num_blocks=12, block_size=8, rows=3, max_blocks_per_row=6)
    src = kv.admit(1, 2, num_tokens=16)              # exactly 2 sealed blocks
    dst, pairs = kv.clone(1, 2)
    assert pairs == [] and kv.copy_ops == 0          # pure metadata op
    assert dst.blocks == src.blocks                  # fully aliased
    assert kv.blocks_in_use == 2                     # counted once
    for b in src.blocks:
        assert kv.pool.ref(b) == 2
    kv.release(1)
    assert kv.blocks_in_use == 2                     # clone still owns them
    kv.release(2)
    assert kv.blocks_in_use == 0                     # last owner frees


# ---------------------------------------------------------------------------
# Paged decode attention: kernel / gather-ref / dense-ref equivalence
# ---------------------------------------------------------------------------


def test_paged_decode_matches_dense_reference():
    """Acceptance: paged decode == dense decode logits, bitwise-or-tolerance,
    in interpret mode. Three-way: Pallas kernel (interpret) vs XLA gather
    reference vs the seq-major dense oracle."""
    rng = np.random.default_rng(3)
    B, H, K, D, bs, N, MB = 3, 8, 4, 16, 8, 10, 4
    q = jnp.asarray(rng.normal(size=(B, H, D)).astype(np.float32))
    k_pages = jnp.asarray(rng.normal(size=(N, K, bs, D)).astype(np.float32))
    v_pages = jnp.asarray(rng.normal(size=(N, K, bs, D)).astype(np.float32))
    bt = jnp.asarray(rng.integers(1, N, size=(B, MB)).astype(np.int32))
    lengths = jnp.asarray(np.array([3, 17, 32], np.int32))

    out_kernel = paged_decode_attention(q, k_pages, v_pages, bt, lengths,
                                        interpret=True)
    out_ref = paged_decode_attention_ref(q, k_pages, v_pages, bt, lengths)
    # dense oracle over the materialized sequences (seq-major layout)
    k_seq = paged_gather_kv(k_pages, bt).transpose(0, 2, 1, 3)   # (B,S,K,D)
    v_seq = paged_gather_kv(v_pages, bt).transpose(0, 2, 1, 3)
    out_dense = decode_reference(q, k_seq, v_seq, lengths)

    np.testing.assert_allclose(out_kernel, out_ref, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(out_ref, out_dense, atol=2e-5, rtol=2e-5)
    for w in (5, 16):
        ok = paged_decode_attention(q, k_pages, v_pages, bt, lengths,
                                    window=w, interpret=True)
        od = decode_reference(q, k_seq, v_seq, lengths, window=w)
        np.testing.assert_allclose(ok, od, atol=2e-5, rtol=2e-5)


def test_supports_paged_gating():
    assert supports_paged(CFG)
    encoder = dataclasses.replace(CFG, is_encoder=True)   # bidirectional
    assert not supports_paged(encoder)
    with pytest.raises(ValueError, match="paged"):
        BatchedServer(encoder, {}, paged=True)
    srv = BatchedServer(encoder, {}, max_slots=1, max_len=32)
    assert not srv.paged                     # silent dense fallback


# ---------------------------------------------------------------------------
# BatchedServer: capacity-driven admission / preemption / cancel
# ---------------------------------------------------------------------------


def test_server_block_exhaustion_queues_then_completes(params, dense_engine):
    """Rows free but blocks scarce: admission queues on MEMORY; when the
    running request releases its blocks the queued one proceeds, and the
    delivered tokens still match a lone dense engine exactly."""
    server = BatchedServer(CFG, params, max_slots=3, max_len=48,
                           block_size=8, num_blocks=8)  # 7 usable blocks
    prompts = [np.arange(20, dtype=np.int32),           # bucket 32 -> 4 blocks
               (np.arange(20, dtype=np.int32) * 5) % CFG.vocab]
    expected = [dense_engine.generate(p, 8).tokens for p in prompts]
    r1 = server.submit(Request(prompts[0], 8))
    r2 = server.submit(Request(prompts[1], 8))
    done = server.run_to_completion()
    assert done[r1] == expected[0] and done[r2] == expected[1]
    stats = server.pool_stats()
    assert stats["queued_on_memory"] >= 1          # r2 waited on blocks
    assert stats["blocks_in_use_peak"] <= 7
    assert server.ttft(r2) > server.ttft(r1)
    assert server.kv.blocks_in_use == 0            # free-on-finish


def test_server_cancel_returns_blocks_same_tick(params):
    """Acceptance: cancel(rid) returns blocks to the pool within the same
    tick, unblocking a memory-queued request immediately."""
    server = BatchedServer(CFG, params, max_slots=3, max_len=48,
                           block_size=8, num_blocks=8)
    a = server.submit(Request(np.arange(20, dtype=np.int32), 30))
    b = server.submit(Request(np.arange(20, dtype=np.int32), 4))
    while not server.events[a]:
        server.step()
    in_use = server.kv.blocks_in_use
    assert in_use >= 4 and not server._admissible()   # b blocked on memory
    server.cancel(a)
    assert server.kv.blocks_in_use == 0               # synchronous release
    assert server._admissible()                       # b admissible same tick
    server.run_to_completion()
    assert len(server.completed[b]) == 4


def test_server_preemption_recompute_is_lossless(params, dense_engine):
    """Two requests outgrow the pool mid-decode: the newest is preempted
    (blocks freed, requeued), later re-prefills prompt+tokens and continues —
    delivered streams still match the dense engine exactly."""
    server = BatchedServer(CFG, params, max_slots=2, max_len=48,
                           block_size=8, num_blocks=9)  # 8 usable
    prompts = [np.arange(4, dtype=np.int32),
               np.asarray([7, 3, 11, 2], np.int32)]
    expected = [dense_engine.generate(p, 40).tokens for p in prompts]
    rids = [server.submit(Request(p, 40)) for p in prompts]
    done = server.run_to_completion()
    assert server.pool_stats()["preemptions"] >= 1
    for rid, exp in zip(rids, expected):
        assert done[rid] == exp
    assert server.kv.blocks_in_use == 0


def test_server_cancel_propagation_wastes_tokens(params):
    """Satellite: a cancel issued by the driver reaches the server one
    uplink RTT later — meanwhile the request keeps generating (or slips into
    prefill), and the overrun is surfaced in ``cancel_lag_tokens``."""
    server = BatchedServer(CFG, params, max_slots=1, max_len=48,
                           block_size=8, decode_chunk=2)
    a = server.submit(Request(np.arange(6, dtype=np.int32), 40))
    while not server.events[a]:
        server.step()
    n_at_issue = server.generated[a]
    # issue now, landing far in the virtual future: the request keeps running
    server.cancel(a, at=server.clock + 1e9)
    assert a not in server.cancelled
    for _ in range(4):
        server.step()
    assert server.generated[a] > n_at_issue
    assert server.cancel_lag_tokens == server.generated[a] - n_at_issue
    # a due cancel lands on the next tick and frees the request
    server._cancel_due[a] = server.clock
    server.step()
    assert a in server.cancelled
    assert server.kv.blocks_in_use == 0


def test_server_cancel_propagation_lets_queued_loser_prefill(params):
    """A queued request whose cancel is still in flight slips into prefill
    and burns blocks (the wasted work the DiSCo driver accounts for)."""
    server = BatchedServer(CFG, params, max_slots=1, max_len=48,
                           block_size=8, decode_chunk=2)
    a = server.submit(Request(np.arange(6, dtype=np.int32), 4))
    b = server.submit(Request(np.arange(6, dtype=np.int32), 8))   # queued behind a
    server.cancel(b, at=1e9)                             # in flight, not landed
    done = server.run_to_completion()
    assert len(done[a]) == 4
    assert b in server.first_token_time                  # b DID prefill
    assert server.generated[b] >= 1
    assert server.cancel_lag_tokens >= server.generated[b]


def test_server_cancel_lands_exactly_one_uplink_late(params):
    """Regression pin on the landing arithmetic: a driver cancel issued at
    virtual time t reaches the server at exactly t + uplink — not t, not
    t + rtt, not t + 2*uplink."""
    from repro.serving import ServerTokenStream

    server = BatchedServer(CFG, params, max_slots=1, max_len=48, block_size=8)
    rid = server.submit(Request(np.arange(6, dtype=np.int32), 8))
    st = ServerTokenStream(server, rid, start_at=0.0, downlink=0.01,
                          prefill_tokens=6, uplink=0.03)
    st.cancel(at=2.0)
    assert server.cancel_pending(rid)
    assert server._cancel_due[rid] == pytest.approx(2.0 + 0.03)
    st.cancel(at=1.0)                       # second cancel: already in flight
    assert server._cancel_due[rid] == pytest.approx(2.03)


def test_server_cancel_landing_after_completion_is_moot(params):
    """Regression: a request that finishes BEFORE its in-flight cancel lands
    must not leave ``cancel_pending`` wedged True forever (that would hang
    the driver's finalize wait)."""
    server = BatchedServer(CFG, params, max_slots=1, max_len=48, block_size=8)
    a = server.submit(Request(np.arange(6, dtype=np.int32), 4))    # finishes fast
    server.cancel(a, at=1e9)                              # lands "never"
    done = server.run_to_completion()
    assert len(done[a]) == 4                              # ran to completion
    assert not server.cancel_pending(a)                   # entry expunged
    assert server.kv.blocks_in_use == 0


def test_block_size_validated():
    with pytest.raises(ValueError, match="block_size"):
        BatchedServer(CFG, {}, max_len=48, block_size=32)   # > min bucket
    with pytest.raises(ValueError, match="block_size"):
        InferenceEngine(CFG, {}, max_len=48, paged=True, block_size=12)


# ---------------------------------------------------------------------------
# Paged InferenceEngine: per-request alloc / free / fork
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def paged_engine(params):
    return InferenceEngine(CFG, params, max_len=48, paged=True,
                           block_size=8, kv_rows=3)


def test_paged_engine_matches_dense(paged_engine, dense_engine):
    prompt = np.arange(10, dtype=np.int32)
    assert (paged_engine.generate(prompt, 20).tokens
            == dense_engine.generate(prompt, 20).tokens)
    assert paged_engine.kv.blocks_in_use == 0            # free-on-finish


def test_paged_engine_stream_cancel_frees_blocks(paged_engine):
    st = paged_engine.open_stream(Request(np.arange(10, dtype=np.int32), 30))
    st.next_chunk()                                      # alloc-on-prefill
    assert paged_engine.kv.blocks_in_use > 0
    st.cancel()
    assert paged_engine.kv.blocks_in_use == 0            # free-on-cancel
    assert st.next_chunk() is None


def test_paged_engine_fork_continues_identically(paged_engine):
    """Copy-on-migration: a forked stream (page-table clone + device block
    copy, no re-prefill) continues with exactly the tokens the source would
    have produced."""
    prompt = np.arange(8, dtype=np.int32)
    src = paged_engine.open_stream(Request(prompt, 24))
    src_tokens = list(src.next_chunk()[0])               # prefill token
    src_tokens += src.next_chunk()[0]                    # one decode chunk
    fork = paged_engine.fork_stream(src, 24 - len(src_tokens))
    fork_tokens = []
    while (c := fork.next_chunk()) is not None:
        fork_tokens += c[0]
    rest = []
    while (c := src.next_chunk()) is not None:
        rest += c[0]
    assert fork_tokens == rest
    assert paged_engine.kv.blocks_in_use == 0


def test_paged_engine_pool_exhaustion(params):
    """Admission raises when the pool cannot hold the prompt; a mid-decode
    extension failure truncates the stream and flags it oom."""
    eng = InferenceEngine(CFG, params, max_len=48, paged=True,
                          block_size=8, kv_rows=2, num_blocks=7)  # 6 usable
    a = eng.open_stream(Request(np.arange(10, dtype=np.int32), 40))  # grows to 6 blocks
    b = eng.open_stream(Request(np.arange(10, dtype=np.int32), 40))
    a.next_chunk()                                       # 2 blocks
    b.next_chunk()                                       # 2 blocks
    while not (a.done or b.done):
        a.next_chunk()
        b.next_chunk()
    assert a.oom or b.oom                                # someone hit the wall
    truncated = a if a.oom else b
    assert truncated.exhausted and truncated.tokens_emitted < 40
    # a third admission while both hold blocks fails loudly
    c = eng.open_stream(Request(np.arange(30, dtype=np.int32), 4))
    with pytest.raises(RuntimeError, match="exhausted"):
        c.next_chunk()
    a.cancel()
    b.cancel()
    assert eng.kv.blocks_in_use == 0
