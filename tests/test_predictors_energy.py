"""TTFT predictors (App. C) + FLOPs/energy model (App. E) unit tests."""
import numpy as np
import pytest

from repro.core import BLOOM_1B1, BLOOM_560M, QWEN_05B, energy_cost_per_token, flops_per_token
from repro.core.predictors import (
    boosted_stumps_forecast,
    exponential_smoothing_forecast,
    mae,
    mape,
    moving_average_forecast,
)


# ---------------------------------------------------------------------------
# Appendix E — the faithfulness anchors
# ---------------------------------------------------------------------------

def test_table6_bloom_1b1_decode_matches_paper():
    g = flops_per_token(BLOOM_1B1, 128, "decode").total / 1e9
    assert abs(g - 0.82) / 0.82 < 0.01  # paper: 0.82


def test_table6_qwen_decode_matches_paper():
    g = flops_per_token(QWEN_05B, 128, "decode").total / 1e9
    assert abs(g - 0.37) / 0.37 < 0.01  # paper: 0.37


def test_table6_prefill_l32_close():
    g = flops_per_token(BLOOM_1B1, 32, "prefill").total / 1e9
    assert abs(g - 0.85) / 0.85 < 0.05  # paper: 0.85


def test_table7_component_ratios():
    r = flops_per_token(BLOOM_1B1, 128, "prefill").ratios()
    assert abs(r["Embedding"] - 0.3124) < 0.02
    assert abs(r["Output"] - 0.3124) < 0.02
    assert abs(r["FFN"] - 0.2448) < 0.02
    assert r["LayerNorm"] < 0.001


def test_decode_flops_constant_in_length_prefill_grows():
    d32 = flops_per_token(BLOOM_1B1, 32, "decode").total
    d128 = flops_per_token(BLOOM_1B1, 128, "decode").total
    assert (d128 - d32) / d32 < 0.01  # KV caching kills the quadratic term
    p32 = flops_per_token(BLOOM_1B1, 32, "prefill").total
    p128 = flops_per_token(BLOOM_1B1, 128, "prefill").total
    assert p128 > p32 * 1.02


def test_energy_cost_scales_with_rate():
    a = energy_cost_per_token(BLOOM_560M, 64, "decode", energy_to_money=0.3)
    b = energy_cost_per_token(BLOOM_560M, 64, "decode", energy_to_money=5.0)
    assert b / a == pytest.approx(5.0 / 0.3)


# ---------------------------------------------------------------------------
# Appendix C — predictors (the negative result)
# ---------------------------------------------------------------------------

def _spiky_series(n=500, seed=0):
    rng = np.random.default_rng(seed)
    body = rng.lognormal(np.log(0.4), 0.4, n)
    spikes = np.where(rng.random(n) < 0.08, 4.0 * (1 + rng.random(n)), 1.0)
    return body * spikes


def test_predictors_one_step_shapes():
    s = _spiky_series()
    for fn in (moving_average_forecast, exponential_smoothing_forecast,
               boosted_stumps_forecast):
        p = fn(s)
        assert p.shape == s.shape
        assert np.all(np.isfinite(p))


def test_predictors_fail_on_spiky_ttft():
    """The paper's conclusion: point prediction is not accurate enough."""
    s = _spiky_series()
    half = s.size // 2
    for fn in (moving_average_forecast, exponential_smoothing_forecast,
               boosted_stumps_forecast):
        p = fn(s)
        assert mape(s[half:], p[half:]) > 15.0


def test_predictors_track_smooth_series():
    """Sanity: they DO work when the series is predictable."""
    t = np.linspace(0, 8 * np.pi, 400)
    s = 1.0 + 0.05 * np.sin(t)
    p = exponential_smoothing_forecast(s, alpha=0.5)
    assert mape(s[200:], p[200:]) < 3.0


def test_mape_mae_basics():
    y = np.array([1.0, 2.0, 4.0])
    p = np.array([1.1, 1.8, 4.4])
    assert mae(y, p) == pytest.approx((0.1 + 0.2 + 0.4) / 3)
    assert mape(y, p) == pytest.approx((10 + 10 + 10) / 3, rel=1e-6)
