"""Disaggregated prefill/decode + cluster router tests: cross-pool KV
hand-off refcount/free invariants (detach -> receive -> release_detached),
recompute fallback on decode-pool exhaustion, cancel mid-transfer on both
the pool and server layers, bitwise stream identity vs a monolithic
``BatchedServer`` under mixed temperature>0 samplers, and sticky
prefix-aware cluster routing."""
import dataclasses

import numpy as np
import pytest
import jax

from repro.configs import paper_models
from repro.models import init_params
from repro.serving import (
    BatchedServer,
    ClusterServer,
    DisaggregatedServer,
    InterconnectModel,
    KVPoolManager,
    Request,
    SamplerConfig,
)
from repro.serving.telemetry import (
    Tracer,
    reconcile_trace,
    trace_spans,
    ttft_attribution,
    validate_trace,
)

CFG = paper_models.TINY_DEVICE


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


def _clean(kv: KVPoolManager) -> bool:
    kv.flush_prefix_cache()
    return kv.pool.num_free == kv.pool.num_blocks - 1


def _mixed_requests(n=10, seed=7, max_new_hi=10):
    """Heterogeneous workload: greedy + two temperature>0 samplers."""
    rng = np.random.default_rng(seed)
    samplers = [
        None,
        SamplerConfig(temperature=0.8, top_k=20),
        SamplerConfig(temperature=1.1, top_p=0.9),
    ]
    reqs = []
    for i in range(n):
        prompt = rng.integers(1, CFG.vocab - 1, size=int(rng.integers(4, 24)))
        reqs.append(Request(
            prompt=np.asarray(prompt, np.int32),
            max_new=int(rng.integers(1, max_new_hi)),
            arrival=float(i) * 0.003,
            sampler=samplers[i % len(samplers)],
            seed=i,                 # pinned: identical streams on any stack
        ))
    return reqs


def _run(server, reqs):
    for r in reqs:
        server.submit(r, at=r.arrival)
    return server.run_to_completion()


# ---------------------------------------------------------------------------
# KV pool layer: detach / receive / release_detached
# ---------------------------------------------------------------------------


def test_receive_refcounts_and_free_both_pools():
    src = KVPoolManager(num_blocks=12, block_size=8, rows=3, max_blocks_per_row=6)
    dst = KVPoolManager(num_blocks=12, block_size=8, rows=3, max_blocks_per_row=6)
    src.admit(1, 3, num_tokens=20)               # 2 sealed + partial tail
    free_during = src.pool.num_free
    table = src.detach(1)
    # detached: the row frees for reuse, the blocks stay referenced
    assert 1 not in src.tables
    assert src.pool.num_free == free_during
    got = dst.receive(5, table)
    assert got is not None
    dst_table, pairs = got
    assert len(pairs) == 3                       # every written block copies
    assert dst.pool.num_free == 12 - 1 - 3
    assert dst.handoffs == 1 and dst.handoff_blocks == 3
    assert dst_table.num_tokens == 20
    # transfer complete: source side drops its hold
    src.release_detached(table)
    assert _clean(src)
    dst.release(5)
    assert _clean(dst)


def test_receive_fallback_pool_and_rows_exhausted():
    src = KVPoolManager(num_blocks=12, block_size=8, rows=3, max_blocks_per_row=6)
    src.admit(1, 4, num_tokens=30)
    table = src.detach(1)

    full = KVPoolManager(num_blocks=5, block_size=8, rows=3, max_blocks_per_row=4)
    full.admit(9, 3)                             # 3 of 4 usable blocks gone
    free_before = full.pool.num_free
    assert full.receive(5, table) is None        # blocks exhausted
    assert full.handoff_fallbacks == 1
    assert full.pool.num_free == free_before     # failed receive took nothing
    assert 5 not in full.tables

    norows = KVPoolManager(num_blocks=20, block_size=8, rows=1, max_blocks_per_row=6)
    norows.admit(9, 2)
    assert norows.receive(5, table) is None      # rows exhausted
    assert norows.handoff_fallbacks == 1

    src.release_detached(table)
    assert _clean(src)


def test_detach_cancel_mid_transfer_pool_level():
    kv = KVPoolManager(num_blocks=12, block_size=8, rows=3,
                       max_blocks_per_row=6, prefix_cache=True)
    tokens = np.arange(1, 21, dtype=np.int32)
    kv.admit(1, 3, num_tokens=20)
    table = kv.detach(1)
    # cancelled mid-flight: the hold drops, sealed blocks stay warm in the
    # prefix index (refcounted there), a flush returns the pool to empty
    kv.release_detached(table, cache_tokens=tokens)
    assert len(kv.prefix_match(tokens, record=False)) == 2
    assert _clean(kv)


# ---------------------------------------------------------------------------
# Server layer: disaggregated vs monolithic
# ---------------------------------------------------------------------------

_KW = dict(max_slots=3, max_len=96, block_size=16, decode_chunk=2)


def test_disaggregated_bitwise_identity_mixed_samplers(params):
    reqs = _mixed_requests()
    mono = BatchedServer(CFG, params, paged=True, **_KW)
    mono.warmup()
    mono_out = _run(mono, reqs)

    tr = Tracer()
    dis = DisaggregatedServer(CFG, params, tracer=tr, **_KW)
    dis.warmup()
    dis_out = _run(dis, reqs)

    assert dis_out == mono_out                   # bitwise, per request
    stats = dis.pool_stats()
    assert stats["handoffs"] + stats["handoff_fallbacks"] > 0
    # pools drain clean on both sides
    assert _clean(dis.prefill.kv) and _clean(dis.decode.kv)
    assert not dis.prefill.held_tables and not dis.prefill.kv_hold
    # trace validates, and hand-off instants reconcile with pool_stats
    trace = tr.export()
    assert validate_trace(trace) == []
    assert reconcile_trace(trace, stats) == []
    spans = trace_spans(trace, name="handoff")
    assert len(spans) == stats["handoffs"] + stats["handoff_fallbacks"]
    assert all(s["args"]["bytes"] >= 0 for s in spans)


def test_disaggregated_fallback_decode_pool_exhausted(params):
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(2):
        prompt = rng.integers(1, CFG.vocab - 1, size=70)
        reqs.append(Request(
            prompt=np.asarray(prompt, np.int32), max_new=40,
            arrival=float(i) * 0.001,
            sampler=SamplerConfig(temperature=0.9, top_k=32), seed=i,
        ))
    mono = BatchedServer(CFG, params, paged=True, **_KW)
    mono.warmup()
    mono_out = _run(mono, reqs)

    # decode pool at the floor: one 70-token row fills it, so the second
    # hand-off MUST take the recompute fallback while the first decodes
    dis = DisaggregatedServer(CFG, params, decode_blocks=7, **_KW)
    dis.warmup()
    dis_out = _run(dis, reqs)
    assert dis.pool_stats()["handoff_fallbacks"] >= 1
    assert dis_out == mono_out                   # fallback is lossless
    assert _clean(dis.prefill.kv) and _clean(dis.decode.kv)


def test_disaggregated_cancel_mid_transfer_server_level(params):
    req = Request(
        prompt=np.arange(1, 9, dtype=np.int32), max_new=6,
        sampler=SamplerConfig(temperature=0.7, top_k=16), seed=0,
    )
    mono = BatchedServer(CFG, params, paged=True, **_KW)
    mono.warmup()
    mono_out = _run(mono, [req])

    dis = DisaggregatedServer(
        CFG, params, interconnect=InterconnectModel(latency_s=5.0), **_KW)
    dis.warmup()
    gid = dis.submit(req, at=0.0)
    dis.run_until(1.0)                           # prefill done, KV in flight
    plan = dis._plans[gid]
    assert plan.state == "transfer"
    held_blocks = len(dis.prefill.held_tables[gid][0].blocks)
    # in flight: the retired row is free but its blocks stay referenced
    assert held_blocks > 0
    assert (dis.prefill.kv.pool.num_free
            == dis.prefill.kv.pool.num_blocks - 1 - held_blocks)
    assert not dis.is_finished(gid)

    dis.cancel(gid)                              # lands before arrival
    dis.run_until(float("inf"))
    assert plan.state == "done"
    assert dis.pool_stats()["handoffs_cancelled"] == 1
    # the payload never landed: decode pool untouched, source hold freed
    assert dis.decode.kv.pool.num_free == dis.decode.kv.pool.num_blocks - 1
    assert not dis.prefill.held_tables
    assert _clean(dis.prefill.kv)
    # delivered stream = exactly the prefill worker's first token, which is
    # bitwise the monolithic stream's first token
    events = dis.pop_events(gid)
    assert [t for t, _ in events] == mono_out[0][:1]
    assert dis.is_finished(gid)


def test_disaggregated_rejects_verify(params):
    dis = DisaggregatedServer(CFG, params, **_KW)
    with pytest.raises(ValueError, match="verify"):
        dis.submit(Request(prompt=np.arange(1, 5, dtype=np.int32), max_new=2),
                   verify=True)


# ---------------------------------------------------------------------------
# Cluster router
# ---------------------------------------------------------------------------


def test_cluster_bitwise_identity_and_spread(params):
    reqs = _mixed_requests(n=8)
    mono = BatchedServer(CFG, params, paged=True, **_KW)
    mono.warmup()
    mono_out = _run(mono, reqs)

    cluster = ClusterServer([
        DisaggregatedServer(CFG, params, **_KW),
        DisaggregatedServer(CFG, params, **_KW),
    ])
    cluster.warmup()
    cl_out = _run(cluster, reqs)
    assert cl_out == mono_out                    # placement never leaks into content
    assert sum(cluster.routed) == len(reqs)
    assert all(n > 0 for n in cluster.routed)    # load actually spreads


def test_cluster_sticky_prefix_routing(params):
    kw = dict(_KW, prefix_cache=True)
    cluster = ClusterServer([
        DisaggregatedServer(CFG, params, **kw),
        DisaggregatedServer(CFG, params, **kw),
    ], sticky_weight=2.0)
    cluster.warmup()
    a = Request(prompt=np.arange(1, 49, dtype=np.int32), max_new=2, seed=0)
    b = Request(prompt=np.arange(100, 148, dtype=np.int32), max_new=2, seed=1)
    ga = cluster.submit(a, at=0.0)               # idle tie -> replica 0
    gb = cluster.submit(b, at=0.0)               # r0 now pressured -> replica 1
    cluster.run_to_completion()
    assert cluster._where[ga][0] == 0 and cluster._where[gb][0] == 1
    # same prefix as b: pressure ties (both idle), but b's prefix is warm on
    # replica 1 -> sticky routing overrides the lowest-index tie-break
    gc = cluster.submit(dataclasses.replace(b, seed=2), at=1.0)
    assert cluster._where[gc][0] == 1
    assert cluster.pool_stats()["sticky_routes"] >= 1
    cluster.run_to_completion()


def test_cluster_traced_attribution(params):
    tr = Tracer()
    reqs = _mixed_requests(n=6)
    cluster = ClusterServer([
        DisaggregatedServer(CFG, params, **_KW),
        DisaggregatedServer(CFG, params, **_KW),
    ], tracer=tr)
    cluster.warmup()
    out = _run(cluster, reqs)
    assert len(out) == len(reqs)
    trace = tr.export()
    assert validate_trace(trace) == []
    assert reconcile_trace(trace, cluster.pool_stats()) == []
    # per-replica scoping: both replicas' workers trace into distinct groups
    spans = trace_spans(trace, name="prefill")
    scopes = {s["args"].get("replica") for s in spans if "args" in s}
    assert any(str(sc).startswith("r0.") for sc in scopes)
    assert any(str(sc).startswith("r1.") for sc in scopes)
    assert ttft_attribution(trace) == []         # no driver records here
