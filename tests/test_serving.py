"""Serving engine + DiSCo driver integration tests (real tiny JAX models)."""
import numpy as np
import pytest
import jax

from repro.configs import paper_models
from repro.core import CostModel, DiSCoScheduler, Endpoint, MigrationConfig
from repro.models import init_params
from repro.serving import (
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    ServerEndpoint,
)


@pytest.fixture(scope="module")
def engines():
    dev_cfg, srv_cfg = paper_models.TINY_DEVICE, paper_models.TINY_SERVER
    dev = InferenceEngine(dev_cfg, init_params(dev_cfg, jax.random.PRNGKey(0)), max_len=96)
    srv = InferenceEngine(srv_cfg, init_params(srv_cfg, jax.random.PRNGKey(1)), max_len=96)
    dev.warmup(); srv.warmup()
    return dev, srv


def test_generate_streams_tokens(engines):
    dev, _ = engines
    prompt = np.arange(10, dtype=np.int32) % dev.cfg.vocab
    res = dev.generate(prompt, max_new=12)
    assert len(res.tokens) == 12
    assert res.ttft > 0
    assert all(t2 >= t1 for t1, t2 in zip(res.token_times, res.token_times[1:]))


def test_generation_deterministic(engines):
    dev, _ = engines
    prompt = np.arange(8, dtype=np.int32)
    a = dev.generate(prompt, max_new=10).tokens
    b = dev.generate(prompt, max_new=10).tokens
    assert a == b  # greedy + fixed params


def test_replay_then_continue_matches_direct(engines):
    """Token-ID migration invariant: target re-prefill of (prompt+generated)
    continues exactly where a from-scratch generation of the same length
    would — the §4.3 'no state transfer' design is lossless for greedy."""
    dev, _ = engines
    prompt = np.arange(6, dtype=np.int32)
    direct = dev.generate(prompt, max_new=16).tokens
    cut = 5
    replay_s, cont = dev.replay_then_continue(prompt, direct[:cut], max_new=11)
    continued = list(cont)
    assert replay_s > 0
    assert direct[cut:] == continued


def test_batched_server_serves_all(engines):
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=3, max_len=96)
    rng = np.random.default_rng(0)
    rids = [
        server.submit(rng.integers(0, srv.cfg.vocab, size=rng.integers(4, 12)).astype(np.int32), 8)
        for _ in range(7)
    ]
    done = server.run_to_completion()
    assert sorted(done) == sorted(rids)
    assert all(len(v) >= 1 for v in done.values())
    assert all(server.ttft(r) > 0 for r in rids)


def test_batched_server_queueing_raises_ttft(engines):
    """Requests beyond slot capacity wait — the §2.3 queueing effect."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=1, max_len=96)
    prompts = [np.arange(6, dtype=np.int32) for _ in range(3)]
    rids = [server.submit(p, 6) for p in prompts]
    server.run_to_completion()
    ttfts = [server.ttft(r) for r in rids]
    assert ttfts[-1] > ttfts[0]  # the queued request saw worse TTFT


def test_batched_server_evicts_rows_at_max_len(engines):
    """A request whose decode would overrun the cache stops at max_len-1 and
    frees its slot for the queue."""
    _, srv = engines
    max_len = 32
    server = BatchedServer(srv.cfg, srv.params, max_slots=1, max_len=max_len)
    long_prompt = np.arange(24, dtype=np.int32)
    short_prompt = np.arange(4, dtype=np.int32)
    r_long = server.submit(long_prompt, 64)    # wants 64, cache allows 7 more
    r_short = server.submit(short_prompt, 4)   # queued until the row frees
    done = server.run_to_completion()
    assert sorted(done) == [r_long, r_short]
    # 1 prefill token + decodes until lengths == max_len - 1
    assert len(done[r_long]) == 1 + (max_len - 1 - 24)
    assert len(done[r_short]) == 4
    assert server.ttft(r_short) > server.ttft(r_long)


def test_batched_server_ttft_bookkeeping(engines):
    """TTFT = first-token time - submit time, positive and ordered for every
    request, including queued ones."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=2, max_len=96)
    rids = [server.submit(np.arange(5, dtype=np.int32), 6) for _ in range(5)]
    server.run_to_completion()
    for rid in rids:
        assert rid in server.first_token_time
        assert rid in server.submit_time
        assert server.ttft(rid) > 0
        assert server.first_token_time[rid] >= server.submit_time[rid]


def test_batched_server_matches_single_engine_stream(engines):
    """Batched continuous decoding must emit exactly the tokens a lone
    engine produces for the same prompt (greedy determinism across the
    batched cache + fused multi-token decode)."""
    _, srv = engines
    engine = InferenceEngine(srv.cfg, srv.params, max_len=96)
    prompts = [
        np.arange(7, dtype=np.int32),
        (np.arange(11, dtype=np.int32) * 3) % srv.cfg.vocab,
        np.asarray([5, 2, 9], np.int32),
    ]
    expected = [engine.generate(p, max_new=9).tokens for p in prompts]
    server = BatchedServer(srv.cfg, srv.params, max_slots=2, max_len=96)
    rids = [server.submit(p, 9) for p in prompts]
    done = server.run_to_completion()
    for rid, exp in zip(rids, expected):
        assert done[rid] == exp


def test_multi_token_decode_matches_single_step(engines):
    """decode_n(T) must emit exactly the tokens T sequential decode_steps do
    (the fused scan is a pure re-batching of the same math)."""
    import jax.numpy as jnp
    from repro.models import decode_n, decode_step, prefill

    dev, _ = engines
    cfg, params = dev.cfg, dev.params
    toks = np.arange(9, dtype=np.int32)[None, :]
    logits, cache = prefill(params, cfg, jnp.asarray(toks), 64)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    fused, _ = decode_n(params, cfg, cache, tok, 12)
    c, t, stepwise = cache, tok, []
    for _ in range(12):
        lg, c = decode_step(params, cfg, c, t)
        t = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        stepwise.append(int(t[0]))
    assert [int(x) for x in np.asarray(fused)[:, 0]] == stepwise


def test_generate_chunked_equals_per_token(engines):
    """Engine output is invariant to the decode chunk size (1 == seed
    behavior; 8 == fused hot path)."""
    dev, _ = engines
    per_token = InferenceEngine(dev.cfg, dev.params, max_len=96, decode_chunk=1)
    chunked = InferenceEngine(dev.cfg, dev.params, max_len=96, decode_chunk=8)
    prompt = np.arange(10, dtype=np.int32)
    for max_new in (1, 7, 8, 9, 20):
        assert (
            per_token.generate(prompt, max_new=max_new).tokens
            == chunked.generate(prompt, max_new=max_new).tokens
        )


def test_generate_saturates_at_max_len(engines):
    """Generation stops exactly at cache capacity regardless of chunking."""
    dev, _ = engines
    engine = InferenceEngine(dev.cfg, dev.params, max_len=32, decode_chunk=8)
    prompt = np.arange(20, dtype=np.int32)
    res = engine.generate(prompt, max_new=50)
    # 1 prefill token + decodes until lengths == max_len - 1
    assert len(res.tokens) == 1 + (32 - 1 - 20)


def _make_disco(engines, constraint: str) -> DiSCoServer:
    dev_e, srv_e = engines
    if constraint == "device":
        cm = CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6)
    else:
        cm = CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12)
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        cm,
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(rng.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.5,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )
    return DiSCoServer(
        sched,
        DeviceEndpoint(dev_e),
        ServerEndpoint(srv_e, NetworkModel(rtt_mean=0.05, queue_spike_prob=0.3)),
        rng=np.random.default_rng(7),
    )


@pytest.mark.parametrize("constraint", ["device", "server"])
def test_disco_server_end_to_end(engines, constraint):
    disco = _make_disco(engines, constraint)
    rng = np.random.default_rng(3)
    results = [
        disco.serve(rng.integers(0, 1024, size=int(n)).astype(np.int32), max_new=20)
        for n in rng.integers(4, 40, size=8)
    ]
    for r in results:
        assert len(r.tokens) >= 1
        assert r.ttft > 0
        assert r.cost > 0
        assert all(dt >= 0 for dt in r.tbt_series)


def test_disco_migration_happens_when_decode_cost_gap_large(engines):
    disco = _make_disco(engines, "device")  # device decode expensive -> migrate off
    rng = np.random.default_rng(5)
    results = [
        disco.serve(rng.integers(0, 1024, size=12).astype(np.int32), max_new=24)
        for _ in range(6)
    ]
    assert any(r.migrated for r in results)
    # delivered stream never stalls badly: P99 TBT within 3x consumption gap
    tbts = np.concatenate([r.tbt_series for r in results if r.tbt_series])
    assert np.percentile(tbts, 99) < 3.0 / 30.0 + 0.5
