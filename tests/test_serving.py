"""Serving engine + DiSCo driver integration tests (real tiny JAX models)."""
import numpy as np
import pytest
import jax

from repro.configs import paper_models
from repro.core import CostModel, DiSCoScheduler, Endpoint, MigrationConfig
from repro.models import init_params
from repro.serving import (
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    ServerEndpoint,
)


@pytest.fixture(scope="module")
def engines():
    dev_cfg, srv_cfg = paper_models.TINY_DEVICE, paper_models.TINY_SERVER
    dev = InferenceEngine(dev_cfg, init_params(dev_cfg, jax.random.PRNGKey(0)), max_len=96)
    srv = InferenceEngine(srv_cfg, init_params(srv_cfg, jax.random.PRNGKey(1)), max_len=96)
    dev.warmup(); srv.warmup()
    return dev, srv


def test_generate_streams_tokens(engines):
    dev, _ = engines
    prompt = np.arange(10, dtype=np.int32) % dev.cfg.vocab
    res = dev.generate(prompt, max_new=12)
    assert len(res.tokens) == 12
    assert res.ttft > 0
    assert all(t2 >= t1 for t1, t2 in zip(res.token_times, res.token_times[1:]))


def test_generation_deterministic(engines):
    dev, _ = engines
    prompt = np.arange(8, dtype=np.int32)
    a = dev.generate(prompt, max_new=10).tokens
    b = dev.generate(prompt, max_new=10).tokens
    assert a == b  # greedy + fixed params


def test_replay_then_continue_matches_direct(engines):
    """Token-ID migration invariant: target re-prefill of (prompt+generated)
    continues exactly where a from-scratch generation of the same length
    would — the §4.3 'no state transfer' design is lossless for greedy."""
    dev, _ = engines
    prompt = np.arange(6, dtype=np.int32)
    direct = dev.generate(prompt, max_new=16).tokens
    cut = 5
    replay_s, cont = dev.replay_then_continue(prompt, direct[:cut], max_new=11)
    continued = list(cont)
    assert replay_s > 0
    assert direct[cut:] == continued


def test_batched_server_serves_all(engines):
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=3, max_len=96)
    rng = np.random.default_rng(0)
    rids = [
        server.submit(rng.integers(0, srv.cfg.vocab, size=rng.integers(4, 12)).astype(np.int32), 8)
        for _ in range(7)
    ]
    done = server.run_to_completion()
    assert sorted(done) == sorted(rids)
    assert all(len(v) >= 1 for v in done.values())
    assert all(server.ttft(r) > 0 for r in rids)


def test_batched_server_queueing_raises_ttft(engines):
    """Requests beyond slot capacity wait — the §2.3 queueing effect."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=1, max_len=96)
    prompts = [np.arange(6, dtype=np.int32) for _ in range(3)]
    rids = [server.submit(p, 6) for p in prompts]
    server.run_to_completion()
    ttfts = [server.ttft(r) for r in rids]
    assert ttfts[-1] > ttfts[0]  # the queued request saw worse TTFT


def _make_disco(engines, constraint: str) -> DiSCoServer:
    dev_e, srv_e = engines
    if constraint == "device":
        cm = CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6)
    else:
        cm = CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12)
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        cm,
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(rng.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.5,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )
    return DiSCoServer(
        sched,
        DeviceEndpoint(dev_e),
        ServerEndpoint(srv_e, NetworkModel(rtt_mean=0.05, queue_spike_prob=0.3)),
        rng=np.random.default_rng(7),
    )


@pytest.mark.parametrize("constraint", ["device", "server"])
def test_disco_server_end_to_end(engines, constraint):
    disco = _make_disco(engines, constraint)
    rng = np.random.default_rng(3)
    results = [
        disco.serve(rng.integers(0, 1024, size=int(n)).astype(np.int32), max_new=20)
        for n in rng.integers(4, 40, size=8)
    ]
    for r in results:
        assert len(r.tokens) >= 1
        assert r.ttft > 0
        assert r.cost > 0
        assert all(dt >= 0 for dt in r.tbt_series)


def test_disco_migration_happens_when_decode_cost_gap_large(engines):
    disco = _make_disco(engines, "device")  # device decode expensive -> migrate off
    rng = np.random.default_rng(5)
    results = [
        disco.serve(rng.integers(0, 1024, size=12).astype(np.int32), max_new=24)
        for _ in range(6)
    ]
    assert any(r.migrated for r in results)
    # delivered stream never stalls badly: P99 TBT within 3x consumption gap
    tbts = np.concatenate([r.tbt_series for r in results if r.tbt_series])
    assert np.percentile(tbts, 99) < 3.0 / 30.0 + 0.5
