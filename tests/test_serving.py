"""Serving engine + event-driven DiSCo runtime integration tests (real tiny
JAX models): lazy token streams, virtual-time BatchedServer, loser
cancellation, and migration under concurrent load."""
import numpy as np
import pytest
import jax

from repro.configs import paper_models
from repro.core import CostModel, DiSCoScheduler, Endpoint, MigrationConfig
from repro.core.dispatch import DispatchDecision, DispatchPolicy
from repro.models import init_params
from repro.serving import (
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    Request,
    ServerEndpoint,
)


@pytest.fixture(scope="module")
def engines():
    dev_cfg, srv_cfg = paper_models.TINY_DEVICE, paper_models.TINY_SERVER
    dev = InferenceEngine(dev_cfg, init_params(dev_cfg, jax.random.PRNGKey(0)), max_len=96)
    srv = InferenceEngine(srv_cfg, init_params(srv_cfg, jax.random.PRNGKey(1)), max_len=96)
    dev.warmup(); srv.warmup()
    return dev, srv


def _serve_now(disco, prompt, max_new, **req_kwargs):
    """One request arriving at the runtime frontier — the first-class
    replacement for the deprecated ``serve()`` shim's timeline semantics."""
    at = max(disco._frontier, disco.server.server.clock)
    return disco.serve_many([Request(prompt, max_new, arrival=at,
                                     **req_kwargs)])[0]


def test_generate_streams_tokens(engines):
    dev, _ = engines
    prompt = np.arange(10, dtype=np.int32) % dev.cfg.vocab
    res = dev.generate(prompt, max_new=12)
    assert len(res.tokens) == 12
    assert res.ttft > 0
    assert all(t2 >= t1 for t1, t2 in zip(res.token_times, res.token_times[1:]))


def test_generation_deterministic(engines):
    dev, _ = engines
    prompt = np.arange(8, dtype=np.int32)
    a = dev.generate(prompt, max_new=10).tokens
    b = dev.generate(prompt, max_new=10).tokens
    assert a == b  # greedy + fixed params


def test_replay_then_continue_matches_direct(engines):
    """Token-ID migration invariant: target re-prefill of (prompt+generated)
    continues exactly where a from-scratch generation of the same length
    would — the §4.3 'no state transfer' design is lossless for greedy."""
    dev, _ = engines
    prompt = np.arange(6, dtype=np.int32)
    direct = dev.generate(prompt, max_new=16).tokens
    cut = 5
    replay_s, cont = dev.replay_then_continue(prompt, direct[:cut], max_new=11)
    continued = list(cont)
    assert replay_s > 0
    assert direct[cut:] == continued


# ---------------------------------------------------------------------------
# EngineStream: the lazy pulled source feeding the event loop
# ---------------------------------------------------------------------------


def test_engine_stream_matches_generate(engines):
    dev, _ = engines
    prompt = np.arange(10, dtype=np.int32)
    direct = dev.generate(prompt, max_new=20)
    st = dev.open_stream(Request(prompt, 20))
    tokens, times = [], []
    while (chunk := st.next_chunk()) is not None:
        tokens += chunk[0]
        times += chunk[1]
    assert tokens == direct.tokens
    assert st.tokens_emitted == 20
    assert all(t2 > t1 for t1, t2 in zip(times, times[1:]))


def test_engine_stream_cancel_stops_dispatches(engines):
    dev, _ = engines
    st = dev.open_stream(Request(np.arange(8, dtype=np.int32), 64))
    st.next_chunk()   # prefill
    st.next_chunk()   # one decode chunk
    n = st.decode_dispatches
    st.cancel()
    assert st.next_chunk() is None
    assert st.decode_dispatches == n == 1


def test_replay_stream_times_interpolated(engines):
    """Satellite fix: replayed (migration-target) streams must carry
    per-token interpolated times, not one host-buffered burst timestamp per
    chunk. Interpolated per-token gaps stay within the chunk-duration noise
    band; the old burst pattern put ~µs gaps inside a chunk and ~ms gaps at
    chunk boundaries (orders of magnitude apart)."""
    dev, _ = engines
    prompt = np.arange(6, dtype=np.int32)
    head = dev.generate(prompt, max_new=4).tokens
    ep = DeviceEndpoint(dev)
    st = ep.open_replay_stream(Request(prompt, 4 + 17), head, None, start_at=1.0)
    st.activate()
    events = []
    while st.peek() is not None:
        events.append(st.pop())
    assert len(events) == 17
    ts = [e.t for e in events]
    assert all(t >= 1.0 for t in ts)          # start offset respected
    assert all(b > a for a, b in zip(ts, ts[1:]))
    gaps = np.diff(ts[1:])                    # decode gaps (skip replay gap)
    assert gaps.max() / max(gaps.min(), 1e-12) < 50.0


# ---------------------------------------------------------------------------
# BatchedServer: virtual-time event-driven continuous batching
# ---------------------------------------------------------------------------


def test_batched_server_serves_all(engines):
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=3, max_len=96)
    rng = np.random.default_rng(0)
    rids = [
        server.submit(Request(
            rng.integers(0, srv.cfg.vocab, size=rng.integers(4, 12)).astype(np.int32), 8
        ))
        for _ in range(7)
    ]
    done = server.run_to_completion()
    assert sorted(done) == sorted(rids)
    assert all(len(v) >= 1 for v in done.values())
    assert all(server.ttft(r) > 0 for r in rids)


def test_batched_server_queueing_raises_ttft(engines):
    """Requests beyond slot capacity wait — §2.3 queueing, now emergent in
    virtual time."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=1, max_len=96)
    prompts = [np.arange(6, dtype=np.int32) for _ in range(3)]
    rids = [server.submit(Request(p, 6)) for p in prompts]
    server.run_to_completion()
    ttfts = [server.ttft(r) for r in rids]
    assert ttfts[-1] > ttfts[0]  # the queued request saw worse TTFT


def test_batched_server_evicts_rows_at_max_len(engines):
    """A request whose decode would overrun the cache stops at max_len-1 and
    frees its slot for the queue (eviction + requeue)."""
    _, srv = engines
    max_len = 32
    server = BatchedServer(srv.cfg, srv.params, max_slots=1, max_len=max_len)
    long_prompt = np.arange(24, dtype=np.int32)
    short_prompt = np.arange(4, dtype=np.int32)
    r_long = server.submit(Request(long_prompt, 64))  # wants 64, cache allows 7 more
    r_short = server.submit(Request(short_prompt, 4))  # queued until the row frees
    done = server.run_to_completion()
    assert sorted(done) == [r_long, r_short]
    # 1 prefill token + decodes until lengths == max_len - 1
    assert len(done[r_long]) == 1 + (max_len - 1 - 24)
    assert len(done[r_short]) == 4
    assert server.ttft(r_short) > server.ttft(r_long)


def test_batched_server_ttft_bookkeeping(engines):
    """TTFT = first-token time - arrival on the virtual timeline, positive
    for every admitted request, including queued ones."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=2, max_len=96)
    rids = [server.submit(Request(np.arange(5, dtype=np.int32), 6)) for _ in range(5)]
    server.run_to_completion()
    for rid in rids:
        assert rid in server.first_token_time
        assert rid in server.submit_time
        assert server.ttft(rid) > 0
        assert server.first_token_time[rid] >= server.submit_time[rid]


def test_batched_server_ttft_unknown_and_unadmitted(engines):
    """Satellite fix: ttft() raises a clear ValueError for unknown rids and
    returns None for queued-but-never-admitted ones (no bare KeyError)."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=1, max_len=96)
    with pytest.raises(ValueError, match="unknown request id"):
        server.ttft(12345)
    a = server.submit(Request(np.arange(6, dtype=np.int32), 8))
    b = server.submit(Request(np.arange(6, dtype=np.int32), 8))
    assert server.ttft(a) is None and server.ttft(b) is None  # nothing ran yet
    server.step()                      # admits a only (1 slot)
    assert server.ttft(a) is not None
    assert server.ttft(b) is None      # still queued
    server.cancel(b)                   # cancelled while queued: never admitted
    server.run_to_completion()
    assert server.ttft(b) is None
    assert server.completed[b] == []


def test_batched_server_cancel_frees_row_within_tick(engines):
    """Acceptance: cancel(rid) frees the row immediately — a queued request
    is admitted by the very next tick, with no drain of the cancelled row."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=1, max_len=96,
                           decode_chunk=4)
    a = server.submit(Request(np.arange(8, dtype=np.int32), 64))
    b = server.submit(Request(np.arange(4, dtype=np.int32), 4))
    while not server.events[a]:
        server.step()                  # admit a, start decoding
    assert not server.free_rows
    server.cancel(a)
    assert server.free_rows            # freed synchronously, same tick
    dispatches_at_cancel = server.decode_dispatches.get(a, 0)
    server.run_to_completion()
    assert server.decode_dispatches.get(a, 0) == dispatches_at_cancel  # no overrun
    assert len(server.completed[b]) == 4
    assert server.ttft(b) is not None
    assert len(server.completed[a]) < 64


def test_batched_server_incremental_events(engines):
    """Per-request incremental delivery: pop_events streams (token, t) pairs
    with monotone virtual times matching the completed transcript."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=2, max_len=96)
    rids = [server.submit(Request(np.arange(7, dtype=np.int32), 9), at=0.01 * i)
            for i in range(3)]
    server.run_to_completion()
    for rid in rids:
        events = server.pop_events(rid)
        assert [tok for tok, _ in events] == server.completed[rid]
        times = [t for _, t in events]
        assert all(b >= a for a, b in zip(times, times[1:]))
        assert times[0] >= server.submit_time[rid]
    assert server.pop_events(rids[0]) == []   # drained


def test_batched_server_matches_single_engine_stream(engines):
    """Batched continuous decoding must emit exactly the tokens a lone
    engine produces for the same prompt (greedy determinism across the
    batched cache + fused multi-token decode)."""
    _, srv = engines
    engine = InferenceEngine(srv.cfg, srv.params, max_len=96)
    prompts = [
        np.arange(7, dtype=np.int32),
        (np.arange(11, dtype=np.int32) * 3) % srv.cfg.vocab,
        np.asarray([5, 2, 9], np.int32),
    ]
    expected = [engine.generate(p, max_new=9).tokens for p in prompts]
    server = BatchedServer(srv.cfg, srv.params, max_slots=2, max_len=96)
    rids = [server.submit(Request(p, 9)) for p in prompts]
    done = server.run_to_completion()
    for rid, exp in zip(rids, expected):
        assert done[rid] == exp


def test_multi_token_decode_matches_single_step(engines):
    """decode_n(T) must emit exactly the tokens T sequential decode_steps do
    (the fused scan is a pure re-batching of the same math)."""
    import jax.numpy as jnp
    from repro.models import decode_n, decode_step, prefill

    dev, _ = engines
    cfg, params = dev.cfg, dev.params
    toks = np.arange(9, dtype=np.int32)[None, :]
    logits, cache = prefill(params, cfg, jnp.asarray(toks), 64)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    fused, _ = decode_n(params, cfg, cache, tok, 12)
    c, t, stepwise = cache, tok, []
    for _ in range(12):
        lg, c = decode_step(params, cfg, c, t)
        t = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        stepwise.append(int(t[0]))
    assert [int(x) for x in np.asarray(fused)[:, 0]] == stepwise


def test_generate_chunked_equals_per_token(engines):
    """Engine output is invariant to the decode chunk size (1 == seed
    behavior; 8 == fused hot path)."""
    dev, _ = engines
    per_token = InferenceEngine(dev.cfg, dev.params, max_len=96, decode_chunk=1)
    chunked = InferenceEngine(dev.cfg, dev.params, max_len=96, decode_chunk=8)
    prompt = np.arange(10, dtype=np.int32)
    for max_new in (1, 7, 8, 9, 20):
        assert (
            per_token.generate(prompt, max_new=max_new).tokens
            == chunked.generate(prompt, max_new=max_new).tokens
        )


def test_generate_saturates_at_max_len(engines):
    """Generation stops exactly at cache capacity regardless of chunking."""
    dev, _ = engines
    engine = InferenceEngine(dev.cfg, dev.params, max_len=32, decode_chunk=8)
    prompt = np.arange(20, dtype=np.int32)
    res = engine.generate(prompt, max_new=50)
    # 1 prefill token + decodes until lengths == max_len - 1
    assert len(res.tokens) == 1 + (32 - 1 - 20)


# ---------------------------------------------------------------------------
# Event-driven DiSCo runtime
# ---------------------------------------------------------------------------


def test_server_endpoint_network_not_aliased(engines):
    """Satellite fix: the default NetworkModel must be constructed per
    endpoint instance, not shared across every endpoint in the process."""
    _, srv = engines
    server = BatchedServer(srv.cfg, srv.params, max_slots=1, max_len=32)
    e1 = ServerEndpoint(server)
    e2 = ServerEndpoint(server)
    assert e1.network is not e2.network
    e1.network.rtt_mean = 99.0
    assert e2.network.rtt_mean != 99.0


def _make_disco(engines, constraint: str, cancel_losers: bool = True,
                max_slots: int = 2) -> DiSCoServer:
    dev_e, srv_e = engines
    server = BatchedServer(srv_e.cfg, srv_e.params, max_slots=max_slots,
                           max_len=96)
    server.warmup(prompt_lens=(16, 48))
    if constraint == "device":
        cm = CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6)
    else:
        cm = CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12)
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        cm,
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(rng.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.5,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )
    return DiSCoServer(
        sched,
        DeviceEndpoint(dev_e),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.05)),
        rng=np.random.default_rng(7),
        cancel_losers=cancel_losers,
    )


@pytest.mark.parametrize("constraint", ["device", "server"])
def test_disco_server_end_to_end(engines, constraint):
    disco = _make_disco(engines, constraint)
    rng = np.random.default_rng(3)
    results = [
        _serve_now(disco, rng.integers(0, 1024, size=int(n)).astype(np.int32), 20)
        for n in rng.integers(4, 40, size=8)
    ]
    for r in results:
        assert len(r.tokens) >= 1
        assert r.ttft > 0
        assert r.cost > 0
        assert all(dt >= 0 for dt in r.tbt_series)
        assert r.generated_tokens >= len(r.tokens)
        assert r.wasted_tokens == r.generated_tokens - len(r.tokens)


def test_disco_serve_many_concurrent(engines):
    """The multi-request event loop: overlapping arrivals race a shared
    contended server; every request completes with consistent accounting and
    results come back in arrival order."""
    disco = _make_disco(engines, "server")
    rng = np.random.default_rng(11)
    reqs = [
        Request(rng.integers(0, 1024, size=int(n)).astype(np.int32), 10,
                arrival=0.02 * i)
        for i, n in enumerate(rng.integers(4, 40, size=9))
    ]
    results = disco.serve_many(reqs)
    assert len(results) == len(reqs)
    for q, r in zip(reqs, results):
        assert r.arrival == q.arrival
        assert 1 <= len(r.tokens) <= q.max_new
        assert r.ttft > 0
        assert r.wasted_tokens == r.generated_tokens - len(r.tokens)


def test_race_loser_stops_within_one_chunk_of_cancel_landing(engines):
    """Acceptance: the race loser stops within ONE decode chunk of the
    cancel LANDING server-side. The cancel is issued at the winner's first
    token but crosses the uplink first (cancel-propagation latency), so the
    loser's waste = the propagation window's tokens (``cancel_lag_tokens``)
    plus at most one in-flight chunk — never the full max_new generation."""
    disco = _make_disco(engines, "server")
    server = disco.server.server
    rid_before = server.next_id
    prompt = np.arange(40, dtype=np.int32)    # long: both endpoints race
    r = _serve_now(disco, prompt, 24)
    assert r.winner is Endpoint.DEVICE        # local prefill beats RTT + queue
    loser_rid = rid_before                    # the request's server submission
    # the cancel has landed by finalize time (the driver waits for it)
    assert loser_rid in server.cancelled
    assert not server.cancel_pending(loser_rid)
    # waste identity: exactly what the loser generated, all accounted
    assert r.wasted_tokens == server.generated.get(loser_rid, 0)
    # lag-INDEPENDENT bound: outside the propagation window the loser wastes
    # at most its prefill token + the one chunk in flight at issue time —
    # a regression that delays the landing inflates lag, not this margin
    lag = server.cancel_lag_tokens
    assert r.wasted_tokens - lag <= 1 + server.decode_chunk
    assert r.generated_tokens < 2 * 24        # loser never ran to completion


class _RaceBothPolicy(DispatchPolicy):
    def __init__(self, device_wait: float):
        self.device_wait = device_wait

    def decide(self, length, rng=None):
        return DispatchDecision(use_server=True, use_device=True,
                                device_wait=self.device_wait)


def test_device_never_starts_when_server_wins_first(engines):
    """Lazy activation: if the server's first token lands before the device
    wait elapses, the device prefill is never dispatched — zero device
    compute, zero waste (the §4.2 wait-policy saving)."""
    disco = _make_disco(engines, "server")
    disco.sched.policy = _RaceBothPolicy(device_wait=30.0)
    # max_new below min_remaining_tokens: no migration, pure race isolation
    r = _serve_now(disco, np.arange(12, dtype=np.int32), 4)
    assert r.winner is Endpoint.SERVER
    assert r.generated_tokens == len(r.tokens)
    assert r.wasted_tokens == 0


def test_no_cancellation_control_wastes_more(engines):
    """Acceptance: with cancellation off (control), race losers generate to
    completion — wasted tokens rise by >= 2x; the delivered streams are
    bit-identical in both modes."""
    rng = np.random.default_rng(5)
    reqs = [
        Request(rng.integers(0, 1024, size=40).astype(np.int32), 10,
                arrival=0.01 * i)
        for i in range(5)
    ]
    out_c = _make_disco(engines, "server", cancel_losers=True).serve_many(reqs)
    out_n = _make_disco(engines, "server", cancel_losers=False).serve_many(reqs)
    wasted_c = sum(r.wasted_tokens for r in out_c)
    wasted_n = sum(r.wasted_tokens for r in out_n)
    assert wasted_n >= 2 * max(wasted_c, 1)
    for a, b in zip(out_c, out_n):
        assert a.tokens == b.tokens


def test_disco_migration_happens_when_decode_cost_gap_large(engines):
    disco = _make_disco(engines, "device")  # device decode expensive -> migrate off
    rng = np.random.default_rng(5)
    results = [
        _serve_now(disco, rng.integers(0, 1024, size=12).astype(np.int32), 24)
        for _ in range(6)
    ]
    assert any(r.migrated for r in results)
    # delivered stream never stalls badly: P99 TBT within 3x consumption gap
    tbts = np.concatenate([r.tbt_series for r in results if r.tbt_series])
    assert np.percentile(tbts, 99) < 3.0 / 30.0 + 0.5


def test_migration_under_load_matches_no_migration_stream(engines):
    """Acceptance: with IDENTICAL models on both endpoints, migration under
    concurrent load is lossless — every delivered token stream equals the
    no-migration greedy baseline (consistent-prefix hand-off, §4.3)."""
    dev_e, _ = engines
    server = BatchedServer(dev_e.cfg, dev_e.params, max_slots=2, max_len=96)
    server.warmup(prompt_lens=(16,))
    cm = CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6)
    rng = np.random.default_rng(0)
    sched = DiSCoScheduler(
        cm,
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(rng.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.5,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.005),
    )
    disco = DiSCoServer(
        sched,
        DeviceEndpoint(dev_e),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.01, rtt_jitter=0.0)),
        rng=np.random.default_rng(7),
    )
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, dev_e.cfg.vocab, size=12).astype(np.int32)
               for _ in range(4)]
    baseline = [dev_e.generate(p, 40).tokens for p in prompts]
    results = disco.serve_many(
        [Request(p, 40, arrival=0.002 * i) for i, p in enumerate(prompts)]
    )
    assert any(r.migrated for r in results)
    for r, base in zip(results, baseline):
        assert r.winner is Endpoint.DEVICE
        assert r.tokens == base
