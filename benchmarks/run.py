"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stdout). Select subsets with
``python -m benchmarks.run table2 fig7``.
"""
from __future__ import annotations

import sys
import time

from . import (
    bench_chunked_prefill,
    bench_cluster,
    bench_decode_throughput,
    bench_e2e_serving,
    bench_paged_decode,
    bench_prefill_throughput,
    bench_fig23_stability,
    bench_roofline_endpoints,
    bench_table4_coldstart,
    bench_fig5_intervals,
    bench_fig6_ttft,
    bench_fig7_cost,
    bench_fig8_quality,
    bench_fig9_overhead,
    bench_table1_correlation,
    bench_table2_tail,
    bench_table3_tbt,
    bench_speculative,
    bench_table5_predictors,
    bench_table6_flops,
)

MODULES = {
    "table1": bench_table1_correlation,
    "fig2_3": bench_fig23_stability,
    "fig5": bench_fig5_intervals,
    "fig6": bench_fig6_ttft,
    "table2": bench_table2_tail,
    "table3": bench_table3_tbt,
    "fig7": bench_fig7_cost,
    "fig9": bench_fig9_overhead,
    "table5": bench_table5_predictors,
    "table6": bench_table6_flops,
    "fig8": bench_fig8_quality,
    "roofline_endpoints": bench_roofline_endpoints,
    "table4": bench_table4_coldstart,
    "decode": bench_decode_throughput,
    "e2e_serving": bench_e2e_serving,
    "chunked_prefill": bench_chunked_prefill,
    "cluster": bench_cluster,
    "speculative": bench_speculative,
    "prefill": bench_prefill_throughput,
    "paged_decode": bench_paged_decode,
}


def main() -> None:
    wanted = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for key in wanted:
        mod = MODULES[key]
        for row in mod.run():
            print(row.csv(), flush=True)
    print(f"# total_wall_s,{time.time() - t0:.1f},", file=sys.stderr)


if __name__ == "__main__":
    main()
