"""Disaggregated prefill/decode cluster: replica scaling + interference.

Two experiments over ``src/repro/serving/cluster.py``:

1. **Replica scaling** — a :class:`ClusterServer` of N disaggregated
   replicas driven by ``make_cluster_load_trace``: request count AND
   offered load grow with N while per-replica load stays fixed.  The
   router spreads by queue depth / pool pressure / EDF headroom, so p99
   TTFT should stay ~FLAT as the fleet and the load scale together — the
   acceptance property.  Streams are compared bitwise against a monolithic
   ``BatchedServer`` fed the same requests (mixed temperature>0 samplers):
   placement and hand-off must never leak into content.

2. **Interference** — the ``make_interference_trace`` workload (steady
   short-prompt streamers + a long prompt every Nth arrival) at EQUAL
   hardware on both sides: two boxes of ``2*_ROWS`` total rows.  The
   monolithic side spends them symmetrically — two replicas behind the
   cluster router, plain and with chunked prefill — the disaggregated
   side asymmetrically (a small prefill worker + a wide decode worker).
   Long prefills run on the prefill worker while streamers decode
   undisturbed, so the prompt-sized TBT stalls a monolithic server
   injects REPEATEDLY (once per long, or once per chunked piece) drop to
   a single bounded hand-off seam — about one in-flight decode chunk —
   after which the stream is clean.

Measured per mode: ``tbt_stall_p99_s`` (p99 over streamers' worst TBT gap
minus the pooled p50 pace — for disaggregation this is the one-time
hand-off seam), ``tbt_recurring_stall_p99_s`` (same over the SECOND-worst
gap — the interference that keeps re-hitting a stream; ~0 for
disaggregation, large for chunked longs), TTFT stats, hand-off counters
(transfers, blocks, bytes, fallbacks, stall).  Headline:
``p99_ttft_flat_x`` (largest-fleet p99 over single-replica p99) and the
recurring stalls.  Emits ``BENCH_cluster.json`` at the repo root on full
runs plus CSV rows for ``benchmarks/run.py``.

    PYTHONPATH=src python -m benchmarks.bench_cluster [--smoke | --check-cluster]
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import paper_models
from repro.models import init_params
from repro.serving import (
    BatchedServer,
    ClusterServer,
    DisaggregatedServer,
    InterconnectModel,
    Request,
    SamplerConfig,
    SLO,
)
from repro.sim.traces import make_cluster_load_trace, make_interference_trace

from .common import Row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_cluster.json"

_CFG = paper_models.TINY_SERVER
_ROWS = 4                    # per worker; monolithic baselines get 2x
_BLOCK_SIZE = 16
_MAX_LEN = 576
_DECODE_CHUNK = 4
_RHO = 0.7                   # per-replica offered load, held fixed in the sweep
_REPLICAS = (1, 2, 4)
_N_PER_REPLICA = 14
_SHORT_PROMPT = 8
_SHORT_NEW = 24
_LONG_PROMPT = 512           # long enough that a monolithic prefill stalls
_LONG_NEW = 8                # streamers for many decode ticks
_LONG_EVERY = 4
_N_INTERFERENCE = 24
_CHUNK_PIECE = 128           # monolithic-with-chunking comparison point
_TTFT_DEADLINE_X = 6.0
# interference: equal hardware on both sides — two boxes, 2*_ROWS rows
# total.  The monolithic side spends them symmetrically (two _ROWS-row
# replicas behind the router); the disaggregated side asymmetrically
# (prefill admission needs few rows, the decode worker carries EVERY
# stream so it gets the rest).
_P_SLOTS = 2
_D_SLOTS = 2 * _ROWS - _P_SLOTS
_IRHO = 0.5                  # interference offered load (of one box's rows)

# bit-identity must hold under stochastic sampling, not just greedy argmax
_SAMPLERS = (
    None,
    SamplerConfig(temperature=0.8, top_p=0.95),
    SamplerConfig(temperature=0.7, top_k=50),
)


def _estimate_service_time(params) -> float:
    srv = BatchedServer(
        _CFG, params, max_slots=1, max_len=_MAX_LEN,
        decode_chunk=_DECODE_CHUNK, block_size=_BLOCK_SIZE,
    )
    srv.warmup(prompt_lens=(_SHORT_PROMPT,))
    rng = np.random.default_rng(0)
    n = 3
    for _ in range(n):
        srv.submit(Request(
            rng.integers(1, 1024, size=_SHORT_PROMPT).astype(np.int32),
            _SHORT_NEW,
        ))
    srv.run_to_completion()
    return srv.clock / n


def _requests(trace, service: float) -> list[Request]:
    prompt_rng = np.random.default_rng(7)
    deadline = _TTFT_DEADLINE_X * service
    return [
        Request(
            prompt_rng.integers(1, 1024, size=length).astype(np.int32), m,
            arrival=a, sampler=_SAMPLERS[i % len(_SAMPLERS)],
            slo=SLO(ttft_deadline=deadline), seed=100 + i,
        )
        for i, (a, length, m) in enumerate(trace)
    ]


def _drive(srv, reqs, warm_lens):
    """Submit every request and run to completion; works identically for
    BatchedServer, DisaggregatedServer and ClusterServer.  Returns
    (streams, event-times, rel_ttfts, deadline_attainment)."""
    srv.warmup(prompt_lens=warm_lens)
    rids = [srv.submit(r, at=r.arrival) for r in reqs]
    srv.run_to_completion()
    events = [srv.pop_events(r) for r in rids]
    streams = [[t for t, _ in ev] for ev in events]
    times = [[ts for _, ts in ev] for ev in events]
    ttfts = np.array([srv.ttft(r) for r in rids], dtype=float)
    deadline = reqs[0].slo.ttft_deadline
    return streams, times, ttfts, float(np.mean(ttfts <= deadline))


def _replica(params, **kw) -> DisaggregatedServer:
    return DisaggregatedServer(
        _CFG, params, max_slots=_ROWS, max_len=_MAX_LEN,
        decode_chunk=_DECODE_CHUNK, block_size=_BLOCK_SIZE,
        interconnect=InterconnectModel(), **kw,
    )


def _mono(params, rows_x: int = 1, prefill_chunk=None) -> BatchedServer:
    return BatchedServer(
        _CFG, params, paged=True, max_slots=_ROWS * rows_x,
        max_len=_MAX_LEN, decode_chunk=_DECODE_CHUNK,
        block_size=_BLOCK_SIZE, prefill_chunk=prefill_chunk,
    )


def _stall_metrics(kinds, times):
    """(worst_stall, recurring_stall, pace) over the short streamers.

    ``worst``: p99 of each streamer's single worst TBT gap minus the pooled
    p50 pace (see bench_chunked_prefill: pooled percentiles drown the stall
    in noise).  ``recurring``: same over each streamer's SECOND-worst gap —
    a one-time hiccup (the disaggregated hand-off seam, a single long
    prefill) drops out, while interference that keeps re-hitting the stream
    (every piece of a chunked long prefill) stays.  The recurring stall is
    the interference property the cluster gate asserts on."""
    gaps = [np.diff(ts) for k, ts in zip(kinds, times)
            if k == "short" and len(ts) > 2]
    if not gaps:
        return 0.0, 0.0, 0.0
    pooled = np.concatenate(gaps)
    pace = float(np.percentile(pooled, 50))
    worst = np.array([np.sort(g)[-1] for g in gaps])
    second = np.array([np.sort(g)[-2] for g in gaps])
    return (float(np.percentile(worst, 99) - pace),
            float(np.percentile(second, 99) - pace), pace)


def _handoff_stats(stats: dict) -> dict:
    return {
        "handoffs": stats.get("handoffs", 0),
        "handoff_blocks": stats.get("handoff_blocks", 0),
        "handoff_fallbacks": stats.get("handoff_fallbacks", 0),
        "handoff_bytes": stats.get("handoff_bytes", 0),
        "handoff_stall_mean_s": stats.get(
            "handoff_stall_s", {"count": 0, "mean": 0.0})["mean"],
    }


def _sweep_point(params, service, n_replicas: int, n_per_replica: int,
                 with_identity: bool):
    trace = make_cluster_load_trace(
        np.random.default_rng(42), n_per_replica, service_time=service,
        slots_per_replica=_ROWS, replicas=n_replicas, rho=_RHO,
    )
    reqs = _requests(trace, service)
    cluster = ClusterServer([_replica(params) for _ in range(n_replicas)])
    streams, _, ttfts, slo_att = _drive(cluster, reqs, (_SHORT_PROMPT, 48))
    stats = cluster.pool_stats()
    point = {
        "replicas": n_replicas,
        "n_requests": len(reqs),
        "ttft_mean_s": float(ttfts.mean()),
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p99_s": float(np.percentile(ttfts, 99)),
        "ttft_slo_attainment": slo_att,
        "routed_per_replica": list(cluster.routed),
        **_handoff_stats(stats),
    }
    if with_identity:
        mono = _mono(params, rows_x=max(1, n_replicas))
        m_streams, _, _, _ = _drive(mono, reqs, (_SHORT_PROMPT, 48))
        point["streams_identical"] = int(streams == m_streams)
    return point


def _two_box_mono(params, prefill_chunk=None) -> ClusterServer:
    return ClusterServer([
        _mono(params, prefill_chunk=prefill_chunk) for _ in range(2)
    ])


def _split(params) -> DisaggregatedServer:
    return DisaggregatedServer(
        _CFG, params, max_slots=_ROWS, max_len=_MAX_LEN,
        prefill_slots=_P_SLOTS, decode_slots=_D_SLOTS,
        decode_chunk=_DECODE_CHUNK, block_size=_BLOCK_SIZE,
        interconnect=InterconnectModel(),
    )


def _interference_point(params, service, n: int):
    trace = make_interference_trace(
        np.random.default_rng(43), n, service_time=service, slots=_ROWS,
        rho=_IRHO, short_prompt=_SHORT_PROMPT, short_new=_SHORT_NEW,
        long_prompt=_LONG_PROMPT, long_every=_LONG_EVERY, long_new=_LONG_NEW,
    )
    reqs = _requests(trace, service)
    kinds = ["long" if len(r.prompt) == _LONG_PROMPT else "short"
             for r in reqs]
    warm = (_SHORT_PROMPT, _LONG_PROMPT)

    out = {}
    streams = {}
    for mode, srv in (
        ("monolithic", _two_box_mono(params)),
        ("mono_chunked", _two_box_mono(params, prefill_chunk=_CHUNK_PIECE)),
        ("disaggregated", _split(params)),
    ):
        s, times, ttfts, slo_att = _drive(srv, reqs, warm)
        stall, recurring, pace = _stall_metrics(kinds, times)
        streams[mode] = s
        out[mode] = {
            "tbt_stall_p99_s": stall,
            "tbt_recurring_stall_p99_s": recurring,
            "tbt_p50_s": pace,
            "ttft_p99_s": float(np.percentile(ttfts, 99)),
            "ttft_slo_attainment": slo_att,
        }
        if mode == "disaggregated":
            out[mode].update(_handoff_stats(srv.pool_stats()))
    out["streams_identical"] = int(
        streams["disaggregated"] == streams["monolithic"]
        and streams["mono_chunked"] == streams["monolithic"]
    )
    return out


def run(smoke: bool = False) -> list[Row]:
    params = init_params(_CFG, jax.random.PRNGKey(1))
    service = _estimate_service_time(params)
    replicas = (1, 2) if smoke else _REPLICAS
    n_per = 6 if smoke else _N_PER_REPLICA

    rows: list[Row] = []
    sweep = {}
    for n_rep in replicas:
        t0 = time.perf_counter()
        point = _sweep_point(
            params, service, n_rep, n_per,
            with_identity=(n_rep == replicas[-1]),
        )
        wall = (time.perf_counter() - t0) * 1e6
        sweep[n_rep] = point
        extra = (f";identical={point['streams_identical']}"
                 if "streams_identical" in point else "")
        rows.append(Row(
            f"cluster/replicas{n_rep}", wall,
            f"n={point['n_requests']};"
            f"ttft_p99_ms={point['ttft_p99_s']*1e3:.1f};"
            f"slo_att={point['ttft_slo_attainment']:.2f};"
            f"handoffs={point['handoffs']}"
            f"{extra}",
        ))

    flat_x = sweep[replicas[-1]]["ttft_p99_s"] / max(
        sweep[replicas[0]]["ttft_p99_s"], 1e-9)

    t0 = time.perf_counter()
    interference = _interference_point(
        params, service, 12 if smoke else _N_INTERFERENCE)
    wall = (time.perf_counter() - t0) * 1e6
    dis = interference["disaggregated"]
    mono = interference["monolithic"]
    chk = interference["mono_chunked"]
    rows.append(Row(
        "cluster/interference", wall,
        f"recur_mono_ms={mono['tbt_recurring_stall_p99_s']*1e3:.2f};"
        f"recur_chunked_ms={chk['tbt_recurring_stall_p99_s']*1e3:.2f};"
        f"recur_disagg_ms={dis['tbt_recurring_stall_p99_s']*1e3:.2f};"
        f"seam_disagg_ms={dis['tbt_stall_p99_s']*1e3:.2f};"
        f"identical={interference['streams_identical']}",
    ))
    rows.append(Row(
        "cluster/headline", 0.0,
        f"p99_ttft_flat_x={flat_x:.2f}(r{replicas[0]}->r{replicas[-1]});"
        f"recur_disagg_ms={dis['tbt_recurring_stall_p99_s']*1e3:.2f}"
        f"(chunked={chk['tbt_recurring_stall_p99_s']*1e3:.2f});"
        f"identical={interference['streams_identical']}",
    ))

    if not smoke:
        _JSON_PATH.write_text(json.dumps({
            "bench": "cluster",
            "rows_per_worker": _ROWS,
            "block_size": _BLOCK_SIZE,
            "max_len": _MAX_LEN,
            "decode_chunk": _DECODE_CHUNK,
            "rho_per_replica": _RHO,
            "interconnect": {"latency_s": InterconnectModel().latency_s,
                             "bytes_per_s": InterconnectModel().bytes_per_s},
            "service_time_s": service,
            "samplers": "mixed greedy/top-p/top-k (temperature > 0)",
            "replica_sweep": {str(k): v for k, v in sweep.items()},
            "interference": interference,
            "headline": {
                "p99_ttft_flat_x": flat_x,
                "recurring_stall_disagg_s": dis["tbt_recurring_stall_p99_s"],
                "recurring_stall_chunked_s": chk["tbt_recurring_stall_p99_s"],
                "recurring_stall_mono_s": mono["tbt_recurring_stall_p99_s"],
                "handoff_seam_stall_s": dis["tbt_stall_p99_s"],
                "streams_identical": interference["streams_identical"],
            },
        }, indent=2) + "\n")
    return rows


def check(max_flat_x: float = 2.0, stall_tol_x: float = 1.5,
          stall_floor_s: float = 0.02) -> None:
    """CI gate (``--check-cluster``): disaggregated/cluster streams
    bit-identical to monolithic under mixed temperature>0 samplers, p99
    TTFT ~flat as offered load scales with replicas, and interference-trace
    streamer RECURRING TBT stall ~0 — at monolithic-with-chunking level or
    better — with the one-time hand-off seam bounded by the plain
    monolithic server's prefill stall.  Exits non-zero on any violation."""
    params = init_params(_CFG, jax.random.PRNGKey(1))
    service = _estimate_service_time(params)
    failures = []

    p1 = _sweep_point(params, service, 1, 8, with_identity=False)
    p2 = _sweep_point(params, service, 2, 8, with_identity=True)
    if not p2["streams_identical"]:
        failures.append("cluster streams differ from monolithic")
    flat_x = p2["ttft_p99_s"] / max(p1["ttft_p99_s"], 1e-9)
    # generous bound: p99 may wiggle with measured dispatch times, but a
    # broken router degrades super-linearly with the fleet
    if flat_x > max_flat_x and p2["ttft_p99_s"] - p1["ttft_p99_s"] > 0.05:
        failures.append(
            f"p99 TTFT not flat with replicas: {p1['ttft_p99_s']:.4f}s -> "
            f"{p2['ttft_p99_s']:.4f}s ({flat_x:.2f}x > {max_flat_x}x)")
    if p2["handoffs"] + p2["handoff_fallbacks"] == 0:
        failures.append("no KV hand-offs happened in the cluster sweep")

    inter = _interference_point(params, service, 16)
    if not inter["streams_identical"]:
        failures.append("interference streams differ from monolithic")
    dis_rec = inter["disaggregated"]["tbt_recurring_stall_p99_s"]
    chk_rec = inter["mono_chunked"]["tbt_recurring_stall_p99_s"]
    dis_seam = inter["disaggregated"]["tbt_stall_p99_s"]
    mono_worst = inter["monolithic"]["tbt_stall_p99_s"]
    if dis_rec > max(stall_tol_x * chk_rec, stall_floor_s):
        failures.append(
            f"disaggregated recurring TBT stall {dis_rec:.4f}s worse than "
            f"chunked monolithic {chk_rec:.4f}s (tol {stall_tol_x}x, "
            f"floor {stall_floor_s}s)")
    if dis_seam > max(stall_tol_x * mono_worst, 3 * stall_floor_s):
        failures.append(
            f"hand-off seam stall {dis_seam:.4f}s worse than the plain "
            f"monolithic prefill stall {mono_worst:.4f}s it replaces "
            f"(tol {stall_tol_x}x)")

    if failures:
        raise SystemExit("cluster gate FAILED:\n  " + "\n  ".join(failures))
    print(
        f"cluster OK: streams bit-identical (mixed samplers), p99 TTFT "
        f"{p1['ttft_p99_s']*1e3:.1f}ms -> {p2['ttft_p99_s']*1e3:.1f}ms "
        f"(1->2 replicas, 2x load, {flat_x:.2f}x), recurring stall "
        f"mono {inter['monolithic']['tbt_recurring_stall_p99_s']*1e3:.1f}ms /"
        f" chunked {chk_rec*1e3:.1f}ms / disagg {dis_rec*1e3:.1f}ms, "
        f"seam {dis_seam*1e3:.1f}ms (mono worst {mono_worst*1e3:.1f}ms)"
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two replica points, short traces, no JSON emission")
    ap.add_argument("--check", "--check-cluster", action="store_true",
                    dest="check",
                    help="CI gate: bit-identical streams + p99-flat + stall")
    args = ap.parse_args()
    if args.check:
        check()
    else:
        print("name,us_per_call,derived")
        for row in run(smoke=args.smoke):
            print(row.csv(), flush=True)
