"""Figure 9: scheduler overhead and scalability.

Paper (M1 MacBook): DiSCo-S 0.128/0.969/9.082 ms and DiSCo-D
0.486/1.741/14.856 ms for 1K/10K/100K requests. We measure policy
construction + batch dispatch decisions on synthetic log-normal workloads
(the paper's §5.3 methodology).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DevicePolicy, EmpiricalCDF, LengthDistribution, ServerPolicy

from .common import Row


def run() -> list[Row]:
    rows = []
    for n in (1_000, 10_000, 100_000):
        rng = np.random.default_rng(0)
        lengths = np.clip(np.round(rng.lognormal(3.3, 0.9, n)), 1, 4096).astype(int)
        ttfts = rng.lognormal(np.log(0.4), 0.5, n)
        ld = LengthDistribution.from_samples(lengths)
        cdf = EmpiricalCDF.from_samples(ttfts)

        t0 = time.perf_counter()
        pol_s = ServerPolicy(ld, budget=0.5)
        routed = pol_s.route_batch(lengths)
        dt_s = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        pol_d = DevicePolicy(cdf, ld, budget=0.5)
        waits = pol_d.wait_times_batch(lengths)
        dt_d = (time.perf_counter() - t0) * 1e3

        rows.append(Row(f"fig9/disco_s_{n}", dt_s * 1e3,
                        f"ms={dt_s:.3f} (paper: 0.13-9.1 ms)"))
        rows.append(Row(f"fig9/disco_d_{n}", dt_d * 1e3,
                        f"ms={dt_d:.3f} (paper: 0.49-14.9 ms)"))
    return rows
