"""Figures 2-3: TTFT / TBT stability — on-device is stable, on-server has
heavy tails (coefficient of variation + P99/median ratios).
"""
from __future__ import annotations

import numpy as np

from repro.sim import DEVICE_PROFILES, make_server_model

from .common import Row, timed


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(0)
    for trace in ("gpt", "deepseek", "command", "llama"):
        def stats():
            s = make_server_model(trace, np.random.default_rng(1))
            t = s.sample_ttft(np.random.default_rng(2), 1000)
            tbt = s.sample_tbt(np.random.default_rng(3), 1000)
            return (
                float(np.std(t) / np.mean(t)),
                float(np.percentile(t, 99) / np.median(t)),
                float(np.std(tbt) / np.mean(tbt)),
            )
        (cv, tailratio, tbt_cv), us = timed(stats)
        rows.append(Row(
            f"fig2_3/server_{trace}", us,
            f"ttft_cv={cv:.2f};p99_over_median={tailratio:.2f};tbt_cv={tbt_cv:.2f}",
        ))
    dev = DEVICE_PROFILES["xiaomi14-qwen05b"]
    def dstats():
        lengths = np.full(1000, 64)
        t = dev.ttft(lengths) + rng.normal(0, 0.01, 1000)
        return float(np.std(t) / np.mean(t))
    cv, us = timed(dstats)
    rows.append(Row("fig2_3/device_xiaomi14", us,
                    f"ttft_cv={cv:.3f} (stable, paper Fig.2)"))
    return rows
