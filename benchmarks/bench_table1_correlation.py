"""Table 1: Pearson correlation between prompt length and TTFT.

Paper: |rho| <= 0.04 for all four server traces; rho = 0.84 on-device.
"""
from __future__ import annotations

import numpy as np

from repro.sim import DEVICE_PROFILES, SERVER_TRACES, make_server_model, sample_prompt_lengths

from .common import Row, timed


def run() -> list[Row]:
    rng = np.random.default_rng(0)
    lengths = sample_prompt_lengths(rng, 1000)
    rows = []
    for trace in SERVER_TRACES:
        def corr():
            server = make_server_model(trace, np.random.default_rng(1))
            ttft = server.sample_ttft(np.random.default_rng(2), lengths.size)
            return float(np.corrcoef(lengths, ttft)[0, 1])
        r, us = timed(corr)
        rows.append(Row(f"table1/pearson_server_{trace}", us, f"rho={r:+.4f}"))
    dev = DEVICE_PROFILES["pixel7pro-bloom1b1"]
    def dev_corr():
        # multiplicative runtime noise (thermal/governor effects on phones)
        r3 = np.random.default_rng(3)
        jitter = r3.lognormal(0.0, 0.35, lengths.size)
        return float(np.corrcoef(lengths, dev.ttft(lengths) * jitter)[0, 1])
    r, us = timed(dev_corr)
    rows.append(Row("table1/pearson_device_bloom1b1", us, f"rho={r:+.4f} (paper: 0.8424)"))
    return rows
