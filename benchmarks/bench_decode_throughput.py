"""Engine-level decode throughput: the seed code's decode hot path
(reconstructed faithfully below) vs the current fused hot path.

Seed baseline (what commit 21bffb5 shipped), reconstructed in-module for the
dense-GQA bench model so both variants run in the same process:
  * seq-major (L, B, S, K, D) KV cache,
  * per-layer ``repeat_kv`` materialization of the whole cache every step,
  * one jitted dispatch + one ``block_until_ready`` + numpy round-trip per
    token, no buffer donation (full cache copy per step),
  * the configured bfloat16 compute dtype, software-emulated on CPU.

Current path: head-major (L, B, K, S, D) cache consumed in place (grouped
query heads, no repeat/transpose), ``decode_n`` fusing ``decode_chunk`` steps
per dispatch, donated cache buffers, one host sync per chunk, and the
engine's backend-aware compute dtype (float32 on CPU, bf16 on TPU).

The reconstruction is validated before timing: at equal dtype its greedy
token stream must match the fused ``decode_n`` path exactly — the baseline is
the same math, only the seed's data movement.

Emits ``BENCH_decode.json`` at the repo root — the first entry of the decode
perf trajectory — plus the usual CSV rows for ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import Row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_decode.json"

# (batch, prompt/context length, cache capacity) measurement points: the
# device regime (batch 1, short context) plus the server continuous-batching
# regime (batched, long cache) where the seed's per-step repeat_kv
# materialization scales with B*S*H and dominates.
_POINTS = [
    (1, 32, 256),
    (1, 128, 256),
    (4, 128, 256),
    (4, 512, 1024),
    (8, 512, 1024),
]
_REPEATS = 5                 # median-of-N, variants interleaved (noisy box)
_CHUNK = 8


def _steps_for(max_len: int) -> int:
    # longer timed runs at the cheap points for stabler medians
    return 48 if max_len <= 256 else 24


# ---------------------------------------------------------------------------
# Seed decode path reconstruction (dense GQA models only — the bench model)
# ---------------------------------------------------------------------------


def _seed_repeat_kv(x, n_rep):
    if n_rep == 1:
        return x
    b, s, k, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, k, n_rep, d)).reshape(
        b, s, k * n_rep, d
    )


def _seed_decode_attention(q, k_cache, v_cache, lengths):
    """Seed models.attention.decode_attention: seq-major cache, full
    repeat_kv materialization per call."""
    b, s, kh, d = k_cache.shape
    h = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    kr = _seed_repeat_kv(k_cache, h // kh)
    vr = _seed_repeat_kv(v_cache, h // kh)
    logits = jnp.einsum("bhd,bkhd->bhk", q, kr, preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", probs.astype(vr.dtype), vr)


def _make_seed_decode(cfg):
    """Jitted seed-style decode_step over a seq-major (L,B,S,K,D) cache."""
    from repro.models.layers import ffn_apply, rms_norm, _qkv
    from repro.models.model import _embed, _logits, window_vector
    from repro.models.rope import apply_rope

    def seed_decode_step(params, cache, token):
        lengths = cache["lengths"] + 1

        def body(x, xs):
            lp, window, cl = xs
            h = rms_norm(x, lp["mixer_norm"])
            q, k, v = _qkv(cfg, lp, h)
            pos = (lengths - 1)[:, None]
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
            idx = lengths - 1
            upd = lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0)
            kc = jax.vmap(upd)(cl["k"], k, idx)
            vc = jax.vmap(upd)(cl["v"], v, idx)
            o = _seed_decode_attention(q[:, 0], kc, vc, lengths)
            out = jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None, :]
            x = x + out.astype(x.dtype)
            f, _ = ffn_apply(cfg, lp, rms_norm(x, lp["ffn_norm"]))
            x = x + f.astype(x.dtype)
            return x, {"k": kc, "v": vc}

        h0 = _embed(params, cfg, token[:, None])
        h, new_caches = jax.lax.scan(
            body, h0,
            (params["layers"], window_vector(cfg),
             {"k": cache["k"], "v": cache["v"]}),
        )
        logits = _logits(params, cfg, h)[:, 0]
        new_caches["lengths"] = lengths
        return logits, new_caches

    @jax.jit  # seed had no donation: the cache is copied every step
    def step(params, cache, token):
        logits, cache = seed_decode_step(params, cache, token)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return step


def _seed_loop(step, params, cache, tok, steps):
    """Seed engine loop: one dispatch, one block_until_ready and one numpy
    conversion per token. Returns (tokens, seconds)."""
    out = []
    t0 = time.perf_counter()
    for _ in range(steps):
        tok, cache = step(params, cache, jnp.asarray(tok, jnp.int32))
        tok = np.asarray(jax.block_until_ready(tok))
        out.append(tok.copy())
    return out, time.perf_counter() - t0


def _fused_loop(engine, cache, tok, steps):
    """Current hot path: decode_n chunks, one host sync per chunk."""
    from repro.models import sampler_operands

    out = []
    tok_dev = jnp.asarray(tok, jnp.int32)
    keys = jnp.zeros((tok_dev.shape[0], 2), jnp.uint32)   # greedy: unused
    ops = sampler_operands([], batch=int(tok_dev.shape[0]))  # all-greedy rows
    t0 = time.perf_counter()
    done = 0
    while done < steps:
        toks, cache = engine._decode_n(
            engine.params, cache, tok_dev, keys, ops, _CHUNK
        )
        toks_np = np.asarray(jax.block_until_ready(toks))
        out.extend(toks_np[: min(_CHUNK, steps - done)])
        tok_dev = toks[-1]
        done += _CHUNK
    return out, time.perf_counter() - t0


def _validate_reconstruction(cfg, params, seed_step):
    """At equal dtype the seed reconstruction and the fused decode_n path
    must emit identical greedy streams: same math, different data movement."""
    from repro.models import decode_n, prefill

    steps, max_len = 12, 128
    prompt = (np.arange(2 * 24, dtype=np.int32) % cfg.vocab).reshape(2, 24)
    logits, cache = jax.jit(lambda p, t: prefill(p, cfg, t, max_len))(
        params, jnp.asarray(prompt)
    )
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    fused, _ = jax.jit(lambda p, c, t: decode_n(p, cfg, c, t, steps))(
        params, cache, tok
    )
    seed_cache = {
        "k": cache["k"].transpose(0, 1, 3, 2, 4),
        "v": cache["v"].transpose(0, 1, 3, 2, 4),
        "lengths": cache["lengths"],
    }
    seed_toks, _ = _seed_loop(seed_step, params, seed_cache, np.asarray(tok), steps)
    assert [list(t) for t in seed_toks] == [list(t) for t in np.asarray(fused)], (
        "seed-path reconstruction diverged from the fused decode path"
    )


def run() -> list[Row]:
    from repro.configs import paper_models
    from repro.models import init_params, prefill
    from repro.serving import InferenceEngine

    cfg = paper_models.TINY_SERVER            # bfloat16: what the seed ran
    params = init_params(cfg, jax.random.PRNGKey(0))
    seed_step = _make_seed_decode(cfg)
    _validate_reconstruction(cfg, params, seed_step)

    engines: dict[int, InferenceEngine] = {}
    rows: list[Row] = []
    points = []
    for batch, ctx, max_len in _POINTS:
        if max_len not in engines:
            engines[max_len] = InferenceEngine(
                cfg, params, max_len=max_len, decode_chunk=_CHUNK
            )
        engine = engines[max_len]
        prompt = (np.arange(batch * ctx, dtype=np.int32) % cfg.vocab).reshape(
            batch, ctx
        )
        seed_prefill = jax.jit(
            lambda p, t, ml=max_len: prefill(p, cfg, t, ml)
        )

        def fresh_fused():
            tok, cache = engine.prefill(prompt)
            return tok, cache

        def fresh_seed():
            logits, cache = seed_prefill(params, jnp.asarray(prompt))
            tok = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
            # seed stored the cache seq-major (transpose outside the timing)
            seed_cache = {
                "k": cache["k"].transpose(0, 1, 3, 2, 4),
                "v": cache["v"].transpose(0, 1, 3, 2, 4),
                "lengths": cache["lengths"],
            }
            return tok, seed_cache

        steps = _steps_for(max_len)
        # warm both paths at this shape
        tok, cache = fresh_fused()
        _fused_loop(engine, cache, tok, _CHUNK)
        tok, seed_cache = fresh_seed()
        _seed_loop(seed_step, params, seed_cache, tok, 1)

        seed_times, fused_times = [], []
        for rep in range(_REPEATS):
            # alternate variant order so machine-load drift cancels
            order = ("seed", "fused") if rep % 2 == 0 else ("fused", "seed")
            for variant in order:
                if variant == "seed":
                    tok, seed_cache = fresh_seed()
                    _, t = _seed_loop(seed_step, params, seed_cache, tok, steps)
                    seed_times.append(t)
                else:
                    tok, cache = fresh_fused()
                    _, t = _fused_loop(engine, cache, tok, steps)
                    fused_times.append(t)
        base_s = float(np.median(seed_times))
        fused_s = float(np.median(fused_times))

        n_tok = steps * batch
        point = {
            "batch": batch,
            "context": ctx,
            "max_len": max_len,
            "decode_tokens": n_tok,
            "seed_us_per_token": base_s / n_tok * 1e6,
            "fused_us_per_token": fused_s / n_tok * 1e6,
            "seed_tokens_per_s": n_tok / base_s,
            "fused_tokens_per_s": n_tok / fused_s,
            "speedup": base_s / fused_s,
        }
        points.append(point)
        rows.append(Row(
            f"decode_b{batch}_ctx{ctx}_seed", point["seed_us_per_token"],
            f"tok/s={point['seed_tokens_per_s']:.0f}",
        ))
        rows.append(Row(
            f"decode_b{batch}_ctx{ctx}_fused", point["fused_us_per_token"],
            f"tok/s={point['fused_tokens_per_s']:.0f};speedup={point['speedup']:.2f}x",
        ))

    # Zero-overhead telemetry guard: with no tracer attached the serving
    # layer still hits NULL_TRACER hooks (~2 per decode chunk: the decode
    # span + the cancel-lag instant; everything else is behind
    # ``if tracer.enabled`` so the args dicts are never built).  Time the
    # no-op hooks UNGUARDED (worst case) and bound the per-token cost
    # against the fastest fused decode point — the disabled path must stay
    # under 2% or telemetry is not free and the headline numbers lie.
    from repro.serving.telemetry import NULL_TRACER

    n_calls = 10_000
    t0 = time.perf_counter()
    for _ in range(n_calls):
        NULL_TRACER.span("server/row0", "decode", 0.0, 0.0)
        NULL_TRACER.instant("server/queue", "cancel_lag", 0.0)
    noop_s = time.perf_counter() - t0
    noop_us_per_token = (noop_s / n_calls) / _CHUNK * 1e6
    min_fused_us = min(p["fused_us_per_token"] for p in points)
    noop_pct = noop_us_per_token / min_fused_us * 100.0
    rows.append(Row(
        "decode_noop_tracer_guard", noop_us_per_token,
        f"pct_of_fused={noop_pct:.4f}%;budget=2%",
    ))
    if noop_pct >= 2.0:
        raise SystemExit(
            f"no-op tracer overhead {noop_pct:.3f}% of fused decode "
            f"({noop_us_per_token:.4f}us/token vs {min_fused_us:.2f}us/token) "
            "exceeds the 2% zero-overhead budget"
        )

    payload = {
        "bench": "engine_decode_throughput",
        "model": cfg.name,
        "decode_chunk": _CHUNK,
        "backend": jax.default_backend(),
        "seed_dtype": cfg.dtype,
        "engine_dtype": next(iter(engines.values())).cfg.dtype,
        "telemetry": {
            "enabled": False,
            "noop_tracer_overhead_us_per_token": noop_us_per_token,
            "noop_tracer_overhead_pct_of_fused": noop_pct,
            "budget_pct": 2.0,
        },
        "points": points,
        "min_speedup": min(p["speedup"] for p in points),
        "geomean_speedup": float(
            np.exp(np.mean([np.log(p["speedup"]) for p in points]))
        ),
    }
    _JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return rows
