"""Appendix E Tables 6-7: per-token FLOPs (Eq. 7-9) and component ratios —
the faithfulness anchor for the device cost model.

Paper Table 6 (GFLOPs): BLOOM-1.1B prefill 0.85/0.93/1.25 @ L=32/64/128,
decode 0.82 flat; Qwen-0.5B prefill 0.39/0.45/0.69, decode 0.37.
Paper Table 7 (L=128): BLOOM-1.1B embed 31.24%, attention 13.01%,
FFN 24.48%, output 31.24%.

Known paper inconsistency (documented): BLOOM-560M's stated dims
(d=512, ffn=2048) cannot reproduce its own Table 6 column (0.45 GFLOPs);
BLOOM-1.1B and Qwen reproduce within ~6%.
"""
from __future__ import annotations

from repro.core import BLOOM_1B1, QWEN_05B, flops_per_token

from .common import Row, timed

PAPER_TABLE6 = {
    ("bloom-1.1b", "prefill", 32): 0.85,
    ("bloom-1.1b", "prefill", 64): 0.93,
    ("bloom-1.1b", "prefill", 128): 1.25,
    ("bloom-1.1b", "decode", 128): 0.82,
    ("qwen1.5-0.5b", "prefill", 32): 0.39,
    ("qwen1.5-0.5b", "prefill", 64): 0.45,
    ("qwen1.5-0.5b", "prefill", 128): 0.69,
    ("qwen1.5-0.5b", "decode", 128): 0.37,
}


def run() -> list[Row]:
    rows = []
    errs = []
    for (model, phase, L), paper_g in PAPER_TABLE6.items():
        spec = BLOOM_1B1 if model.startswith("bloom") else QWEN_05B
        bd, us = timed(flops_per_token, spec, L, phase)
        ours = bd.total / 1e9
        rel = abs(ours - paper_g) / paper_g * 100
        errs.append(rel)
        rows.append(Row(
            f"table6/{model}_{phase}_L{L}", us,
            f"ours={ours:.3f}G;paper={paper_g:.2f}G;rel_err={rel:.1f}%",
        ))
    # Table 7 component ratios at L=128 for BLOOM-1.1B
    bd = flops_per_token(BLOOM_1B1, 128, "prefill")
    ratios = bd.ratios()
    rows.append(Row(
        "table7/bloom1.1b_ratios_L128", 0.0,
        f"emb={ratios['Embedding']*100:.2f}%(paper 31.24)"
        f";attn={ratios['Attention']*100:.2f}%(13.01)"
        f";ffn={ratios['FFN']*100:.2f}%(24.48)"
        f";out={ratios['Output']*100:.2f}%(31.24)",
    ))
    rows.append(Row("table6/max_rel_err", 0.0, f"{max(errs):.1f}%"))
    return rows
