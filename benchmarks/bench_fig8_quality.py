"""Figure 8 / Appendix D: migration preserves generation quality within the
bounds of the two endpoint models (Eq. 6).

The paper uses LLM judges (GPT-4o etc.) — unavailable offline — so we use a
log-likelihood quality proxy: score a generation by its mean per-token
log-probability under an independently-initialized reference model. For each
max-first-endpoint-length in {0, 4, 16, 64}, generate with migration
(small->large and large->small) and check Eq. 6:

    min(Q_A, Q_B) - tol <= Q_M <= max(Q_A, Q_B) + tol
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_models
from repro.models import forward, init_params
from repro.serving import InferenceEngine

from .common import Row, timed

MAX_LEN = 48
N_PROMPTS = 4


def _score(ref_params, ref_cfg, prompt: np.ndarray, generated: list[int]) -> float:
    """Mean log-prob of ``generated`` under the reference model."""
    toks = np.concatenate([prompt, np.asarray(generated, np.int32)])[None, :]
    logits, _ = forward(ref_params, ref_cfg, jnp.asarray(toks))
    logp = jax.nn.log_softmax(logits, axis=-1)
    idx = np.arange(len(prompt) - 1, len(toks[0]) - 1)
    sel = logp[0, idx, jnp.asarray(generated)]
    return float(sel.mean())


def run() -> list[Row]:
    dev_cfg, srv_cfg = paper_models.TINY_DEVICE, paper_models.TINY_SERVER
    ref_cfg = paper_models.TINY_SERVER
    a = InferenceEngine(dev_cfg, init_params(dev_cfg, jax.random.PRNGKey(0)), MAX_LEN)
    b = InferenceEngine(srv_cfg, init_params(srv_cfg, jax.random.PRNGKey(1)), MAX_LEN)
    ref_params = init_params(ref_cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(0)
    rows = []
    gen_len = 24
    for first, second, label in ((a, b, "small->large"), (b, a, "large->small")):
        def sweep():
            violations = 0
            qms = []
            for cut in (0, 4, 16):
                for p in range(N_PROMPTS):
                    prompt = rng.integers(0, 1024, size=8).astype(np.int32)
                    qa = _score(ref_params, ref_cfg,
                                prompt, first.generate(prompt, gen_len).tokens)
                    qb = _score(ref_params, ref_cfg,
                                prompt, second.generate(prompt, gen_len).tokens)
                    if cut == 0:
                        mtoks = second.generate(prompt, gen_len).tokens
                    else:
                        head = first.generate(prompt, cut).tokens
                        _, cont = second.replay_then_continue(
                            prompt, head, gen_len - cut
                        )
                        mtoks = head + list(cont)
                    qm = _score(ref_params, ref_cfg, prompt, mtoks)
                    qms.append(qm)
                    tol = 0.35 * abs(max(qa, qb) - min(qa, qb)) + 0.3
                    if not (min(qa, qb) - tol <= qm <= max(qa, qb) + tol):
                        violations += 1
            return violations, float(np.mean(qms))
        (viol, qmean), us = timed(sweep)
        rows.append(Row(
            f"fig8/quality_bounds_{label}", us,
            f"violations={viol}/{3*N_PROMPTS};mean_quality={qmean:.3f}",
        ))
    return rows
