"""Chunked prefill vs monolithic under mixed-length interference load.

The interference workload (``make_interference_trace``): a steady stream of
short-prompt decode-heavy background requests with a max-length prompt
injected every Nth arrival. A monolithic server freezes every streaming row
for the whole long prefill, so the background requests' TBT series grows a
prompt-sized stall on each long admission; chunked prefill
(``BatchedServer(prefill_chunk=...)``) splits the prompt into
piece-budget-bounded dispatches interleaved 1:1 with decode ticks, bounding
each stall to ONE piece.

Measured, per mode (monolithic + a sweep of piece budgets):

* ``tbt_stall_p99_s`` — p99 over background requests' WORST TBT gap, minus
  the pooled p50 pace: each streamer's worst interruption is the stall a
  long prefill injected (pooled-p99 would drown it in scheduling noise —
  a handful of prompt-sized gaps among hundreds of ordinary ticks), and
  subtracting the undisturbed pace isolates the stall component;
* ``decode_stall_max_s`` / ``decode_stall_total_s`` — the server's own
  ``decode_stall_s`` histogram: wall-clock prefill work that ran while
  decodable rows sat frozen (max = the worst single stall, the quantity
  chunking bounds);
* ``ttft_*`` + ``ttft_slo_attainment`` — chunking must not trade the TBT
  win for TTFT regressions (pieces run in the same virtual-time budget, and
  the EDF starvation bound runs pieces back-to-back when a deadline nears);
* ``streams_identical`` — delivered token streams bit-identical to the
  monolithic run under MIXED temperature>0 samplers (piecewise prefill
  computes bitwise-identical logits; scheduling must be invisible).

Headline: ``tbt_stall_p99_reduction`` (monolithic / chunked at the default
piece budget) — the ISSUE gate wants >= 3x on CPU — with
``ttft_slo_attainment`` no worse than monolithic beyond noise and
``streams_identical`` = 1. Emits ``BENCH_chunked_prefill.json`` at the repo
root plus CSV rows for ``benchmarks/run.py``.

    PYTHONPATH=src python -m benchmarks.bench_chunked_prefill [--smoke]
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import paper_models
from repro.models import init_params
from repro.serving import BatchedServer, Request, SamplerConfig, SLO
from repro.sim.traces import make_interference_trace

from .common import Row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_chunked_prefill.json"

_ROWS = 4
_BLOCK_SIZE = 16
_NUM_BLOCKS = 96             # roomy: interference is compute, not memory
_MAX_LEN = 1088
_DECODE_CHUNK = 4
_SHORT_PROMPT = 8            # background: decode-heavy streamers
_SHORT_NEW = 24
# 1024-token interfering prompts: long enough that a monolithic prefill costs
# many decode ticks (a 32-64 token piece is dispatch-overhead-bound on CPU,
# so short "long" prompts would hide the stall contrast the bench measures;
# the headline ratio is bounded by prompt/piece, so the prompt must dwarf
# the headline piece budget)
_LONG_PROMPT = 1024
_LONG_NEW = 8
_LONG_EVERY = 4
_N_REQUESTS = 24
_RHO = 0.8                   # backgrounds keep streaming while longs arrive
_PIECES = (32, 64, 128)      # swept piece budgets (tokens per piece)
# 128 balances the trade: small pieces bound each stall tighter but stretch
# the long prompt's own TTFT (more dispatch overhead per prompt); 128 keeps
# SLO attainment at the monolithic level while still cutting the stall tail
_HEADLINE_PIECE = 128
_TTFT_DEADLINE_X = 6.0       # deadline in background service times

# mixed per-request samplers: bit-identity must hold under stochastic
# sampling, not just greedy argmax
_SAMPLERS = (
    None,
    SamplerConfig(temperature=0.8, top_p=0.95),
    SamplerConfig(temperature=0.7, top_k=50),
)


def _estimate_service_time(params) -> float:
    """Virtual service time of one background request (calibrates arrivals)."""
    srv = BatchedServer(
        paper_models.TINY_SERVER, params, max_slots=1, max_len=_MAX_LEN,
        decode_chunk=_DECODE_CHUNK, block_size=_BLOCK_SIZE,
    )
    srv.warmup(prompt_lens=(_SHORT_PROMPT,))
    rng = np.random.default_rng(0)
    n = 3
    for _ in range(n):
        srv.submit(Request(
            rng.integers(1, 1024, size=_SHORT_PROMPT).astype(np.int32),
            _SHORT_NEW,
        ))
    srv.run_to_completion()
    return srv.clock / n


def _drive(params, trace, service: float, prefill_chunk: int):
    """Replay the interference trace through one BatchedServer; returns
    (streams, metrics). TBTs come from the retained per-request event
    times (nothing drains them in a direct drive)."""
    srv = BatchedServer(
        paper_models.TINY_SERVER, params, max_slots=_ROWS, max_len=_MAX_LEN,
        decode_chunk=_DECODE_CHUNK, block_size=_BLOCK_SIZE,
        num_blocks=_NUM_BLOCKS, prefill_chunk=prefill_chunk or None,
    )
    srv.warmup(prompt_lens=(_SHORT_PROMPT, _LONG_PROMPT))
    prompt_rng = np.random.default_rng(7)
    rids, kinds = [], []
    deadline = _TTFT_DEADLINE_X * service
    for i, (a, length, m) in enumerate(trace):
        rids.append(srv.submit(Request(
            prompt_rng.integers(1, 1024, size=length).astype(np.int32), m,
            arrival=a, sampler=_SAMPLERS[i % len(_SAMPLERS)],
            slo=SLO(ttft_deadline=deadline), seed=100 + i,
        )))
        kinds.append("long" if length == _LONG_PROMPT else "short")
    done = srv.run_to_completion()

    bg_tbts = []
    for rid, kind in zip(rids, kinds):
        if kind != "short":
            continue
        times = [t for _, t in srv.events[rid]]
        if len(times) > 1:
            bg_tbts.append(np.diff(times))
    tbts = np.concatenate(bg_tbts) if bg_tbts else np.array([0.0])
    # per-streamer worst gap: the stall each background user actually saw
    worst = (np.array([g.max() for g in bg_tbts]) if bg_tbts
             else np.array([0.0]))
    pace = float(np.percentile(tbts, 50))
    rel_ttfts = np.array([srv.ttft(r) for r in rids])   # arrival-relative
    stall = srv.metrics.histogram("decode_stall_s").summary()
    metrics = {
        "prefill_chunk": prefill_chunk,
        "tbt_p50_s": pace,
        "tbt_p99_s": float(np.percentile(tbts, 99)),
        "tbt_stall_p99_s": float(np.percentile(worst, 99) - pace),
        "ttft_mean_s": float(rel_ttfts.mean()),
        "ttft_p99_s": float(np.percentile(rel_ttfts, 99)),
        "ttft_slo_attainment": float(np.mean(rel_ttfts <= deadline)),
        "decode_stall_events": stall["count"],
        "decode_stall_total_s": stall["total"],
        "decode_stall_max_s": stall["max"] if stall["count"] else 0.0,
        "prefill_tokens_computed":
            srv.pool_stats()["prefill_tokens_computed"],
        "preemptions": srv.kv.preemptions,
    }
    return [done[r] for r in rids], metrics


def run(smoke: bool = False) -> list[Row]:
    params = init_params(paper_models.TINY_SERVER, jax.random.PRNGKey(1))
    service = _estimate_service_time(params)
    n_req = 10 if smoke else _N_REQUESTS
    trace = make_interference_trace(
        np.random.default_rng(42), n_req, service_time=service,
        slots=_ROWS, rho=_RHO, short_prompt=_SHORT_PROMPT,
        short_new=_SHORT_NEW, long_prompt=_LONG_PROMPT,
        long_every=_LONG_EVERY, long_new=_LONG_NEW,
    )

    rows: list[Row] = []
    t0 = time.perf_counter()
    mono_streams, mono = _drive(params, trace, service, prefill_chunk=0)
    mono_wall = (time.perf_counter() - t0) * 1e6
    rows.append(Row(
        "chunked_prefill/monolithic", mono_wall,
        f"tbt_stall_p99_ms={mono['tbt_stall_p99_s']*1e3:.2f};"
        f"stall_max_ms={mono['decode_stall_max_s']*1e3:.2f};"
        f"ttft_slo_att={mono['ttft_slo_attainment']:.2f}",
    ))

    sweep = {}
    pieces = (_HEADLINE_PIECE,) if smoke else _PIECES
    identical = True
    for piece in pieces:
        t0 = time.perf_counter()
        streams, m = _drive(params, trace, service, prefill_chunk=piece)
        wall = (time.perf_counter() - t0) * 1e6
        same = streams == mono_streams
        identical = identical and same
        m["streams_identical"] = int(same)
        m["tbt_stall_p99_reduction"] = mono["tbt_stall_p99_s"] / max(
            m["tbt_stall_p99_s"], 1e-9
        )
        m["decode_stall_max_reduction"] = mono["decode_stall_max_s"] / max(
            m["decode_stall_max_s"], 1e-9
        )
        sweep[piece] = m
        rows.append(Row(
            f"chunked_prefill/piece{piece}", wall,
            f"tbt_stall_p99_ms={m['tbt_stall_p99_s']*1e3:.2f};"
            f"stall_reduction_x={m['tbt_stall_p99_reduction']:.1f};"
            f"stall_max_ms={m['decode_stall_max_s']*1e3:.2f};"
            f"ttft_slo_att={m['ttft_slo_attainment']:.2f};"
            f"identical={m['streams_identical']}",
        ))

    pick = sweep[_HEADLINE_PIECE if _HEADLINE_PIECE in sweep else pieces[0]]
    headline = {
        "piece_budget": pick["prefill_chunk"],
        "tbt_stall_p99_reduction": pick["tbt_stall_p99_reduction"],
        "decode_stall_max_reduction": pick["decode_stall_max_reduction"],
        "ttft_slo_attainment_chunked": pick["ttft_slo_attainment"],
        "ttft_slo_attainment_monolithic": mono["ttft_slo_attainment"],
        "streams_identical": int(identical),
    }
    rows.append(Row(
        "chunked_prefill/headline", 0.0,
        f"stall_reduction_x={headline['tbt_stall_p99_reduction']:.1f};"
        f"slo_att={headline['ttft_slo_attainment_chunked']:.2f}"
        f"(mono={headline['ttft_slo_attainment_monolithic']:.2f});"
        f"identical={headline['streams_identical']}",
    ))

    if not smoke:
        _JSON_PATH.write_text(json.dumps({
            "bench": "chunked_prefill",
            "server_rows": _ROWS,
            "block_size": _BLOCK_SIZE,
            "num_blocks": _NUM_BLOCKS,
            "decode_chunk": _DECODE_CHUNK,
            "max_len": _MAX_LEN,
            "trace": {
                "kind": "interference",
                "n_requests": n_req,
                "rho": _RHO,
                "short_prompt": _SHORT_PROMPT,
                "short_new": _SHORT_NEW,
                "long_prompt": _LONG_PROMPT,
                "long_every": _LONG_EVERY,
                "long_new": _LONG_NEW,
                "service_time_s": service,
            },
            "samplers": "mixed greedy/top-p/top-k (temperature > 0)",
            "monolithic": mono,
            "sweep": {str(k): v for k, v in sweep.items()},
            "headline": headline,
        }, indent=2) + "\n")
    return rows


def check(min_reduction: float = 1.0) -> None:
    """CI gate: chunked streams bit-identical to monolithic under mixed
    temperature>0 samplers AND a real TBT-stall reduction. Exits non-zero
    on any violation."""
    params = init_params(paper_models.TINY_SERVER, jax.random.PRNGKey(1))
    service = _estimate_service_time(params)
    trace = make_interference_trace(
        np.random.default_rng(42), 12, service_time=service, slots=_ROWS,
        rho=_RHO, short_prompt=_SHORT_PROMPT, short_new=_SHORT_NEW,
        long_prompt=_LONG_PROMPT, long_every=_LONG_EVERY, long_new=_LONG_NEW,
    )
    mono_streams, mono = _drive(params, trace, service, prefill_chunk=0)
    chk_streams, chk = _drive(
        params, trace, service, prefill_chunk=_HEADLINE_PIECE
    )
    failures = []
    if chk_streams != mono_streams:
        bad = [i for i, (a, b) in enumerate(zip(mono_streams, chk_streams))
               if a != b]
        failures.append(f"streams differ (requests {bad})")
    reduction = mono["tbt_stall_p99_s"] / max(chk["tbt_stall_p99_s"], 1e-9)
    if not reduction > min_reduction:
        failures.append(
            f"tbt_stall_p99 reduction {reduction:.2f}x <= {min_reduction}x "
            f"(mono={mono['tbt_stall_p99_s']:.4f}s "
            f"chunked={chk['tbt_stall_p99_s']:.4f}s)"
        )
    if failures:
        raise SystemExit("chunked-prefill gate FAILED:\n  "
                         + "\n  ".join(failures))
    print(
        f"chunked-prefill OK: {len(trace)} requests bit-identical to "
        f"monolithic (mixed samplers), tbt_stall_p99 reduction "
        f"{reduction:.1f}x, stall_max {mono['decode_stall_max_s']*1e3:.1f}ms "
        f"-> {chk['decode_stall_max_s']*1e3:.1f}ms"
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one piece budget, short trace, no JSON emission")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: bit-identical streams + stall reduction")
    args = ap.parse_args()
    if args.check:
        check()
    else:
        print("name,us_per_call,derived")
        for row in run(smoke=args.smoke):
            print(row.csv(), flush=True)
