"""Appendix C / Table 5: lightweight TTFT predictors are NOT accurate enough
(MAPE 20-54% in the paper) — the negative result motivating DiSCo's
distribution-based scheduling.
"""
from __future__ import annotations

import numpy as np

from repro.core.predictors import (
    boosted_stumps_forecast,
    exponential_smoothing_forecast,
    mae,
    mape,
    moving_average_forecast,
)
from repro.sim import SERVER_TRACES

from .common import Row, timed


def run() -> list[Row]:
    rows = []
    methods = {
        "moving_average": moving_average_forecast,
        "exp_smoothing": exponential_smoothing_forecast,
        "boosted_stumps": boosted_stumps_forecast,
    }
    for trace, spec in SERVER_TRACES.items():
        series = spec.sample(np.random.default_rng(0), 1000)
        for mname, fn in methods.items():
            (preds), us = timed(fn, series)
            half = series.size // 2  # evaluate on the second half (held out)
            m1 = mape(series[half:], preds[half:])
            m2 = mae(series[half:], preds[half:])
            rows.append(Row(
                f"table5/{trace}_{mname}", us,
                f"MAPE={m1:.1f}%;MAE={m2:.3f}s",
            ))
    return rows
