"""Table 2: tail (P99) TTFT reduction vs stochastic dispatch, averaged over
the budget range — 4 traces × 3 device configs × 2 constraints.

Paper band: 0-52% (most cells 11-52%).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Endpoint,
    LengthDistribution,
    StochasticPolicy,
    make_policy,
    simulate_ttft,
)
from repro.sim import (
    DEVICE_PROFILES,
    build_cost_model,
    make_server_model,
    sample_prompt_lengths,
)

from .common import Row, pct_reduction, timed

BUDGETS = (0.1, 0.3, 0.5, 0.7, 0.9)
N_REQ = 2000


def run() -> list[Row]:
    rows = []
    for trace in ("gpt", "llama", "deepseek", "command"):
        for device_name, device in DEVICE_PROFILES.items():
            for constraint in ("server", "device"):
                def cell():
                    rng = np.random.default_rng(0)
                    server = make_server_model(trace, rng)
                    lengths = sample_prompt_lengths(rng, N_REQ)
                    ld = LengthDistribution.from_samples(lengths)
                    cm = build_cost_model(trace, device_name, constraint)
                    cons = (
                        Endpoint.SERVER if constraint == "server" else Endpoint.DEVICE
                    )
                    reds = []
                    for b in BUDGETS:
                        disco = make_policy(cm, server.ttft, ld, b)
                        stoch = StochasticPolicy(cons, b, seed=1)
                        p_d = np.percentile(
                            simulate_ttft(lengths, disco, server, device,
                                          np.random.default_rng(2))["ttft"], 99)
                        p_s = np.percentile(
                            simulate_ttft(lengths, stoch, server, device,
                                          np.random.default_rng(2))["ttft"], 99)
                        reds.append(pct_reduction(p_s, p_d))
                    return float(np.mean(reds))
                red, us = timed(cell)
                rows.append(Row(
                    f"table2/{trace}_{device_name}_{constraint}", us,
                    f"tail_ttft_reduction={red:.2f}%",
                ))
    return rows
