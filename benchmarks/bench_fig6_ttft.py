"""Figure 6: mean TTFT vs budget ratio — DiSCo vs Stoch-S/Stoch-D, vLLM
(all-server) and llama.cpp (all-device), on all four traces.

Paper: mean TTFT reductions of 6-78% vs stochastic dispatch across traces.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Endpoint,
    LengthDistribution,
    SingleEndpointPolicy,
    StochasticPolicy,
    make_policy,
    simulate_ttft,
)
from repro.sim import (
    DEVICE_PROFILES,
    build_cost_model,
    make_server_model,
    sample_prompt_lengths,
)

from .common import Row, pct_reduction, timed

BUDGETS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
N_REQ = 2000
DEVICE = "xiaomi14-qwen05b"


def _mean_ttft(lengths, policy, server, device, seed=0) -> float:
    r = simulate_ttft(lengths, policy, server, device, np.random.default_rng(seed))
    return float(r["ttft"].mean())


def run() -> list[Row]:
    rows = []
    device = DEVICE_PROFILES[DEVICE]
    for trace in ("gpt", "llama", "deepseek", "command"):
        for constraint in ("server", "device"):
            def sweep():
                rng = np.random.default_rng(0)
                server = make_server_model(trace, rng)
                lengths = sample_prompt_lengths(rng, N_REQ)
                ld = LengthDistribution.from_samples(lengths)
                cm = build_cost_model(trace, DEVICE, constraint)
                cons = Endpoint.SERVER if constraint == "server" else Endpoint.DEVICE
                reductions = []
                for b in BUDGETS:
                    disco = make_policy(cm, server.ttft, ld, b)
                    stoch = StochasticPolicy(cons, b, seed=1)
                    m_d = _mean_ttft(lengths, disco, server, device)
                    m_s = _mean_ttft(lengths, stoch, server, device)
                    reductions.append(pct_reduction(m_s, m_d))
                allsrv = _mean_ttft(lengths, SingleEndpointPolicy(Endpoint.SERVER), server, device)
                alldev = _mean_ttft(lengths, SingleEndpointPolicy(Endpoint.DEVICE), server, device)
                return reductions, allsrv, alldev
            (reds, allsrv, alldev), us = timed(sweep)
            rows.append(Row(
                f"fig6/{trace}_{constraint}", us,
                f"mean_ttft_reduction_vs_stoch={np.mean(reds):.1f}%"
                f";max={np.max(reds):.1f}%;vllm_ttft={allsrv:.3f}s"
                f";llamacpp_ttft={alldev:.3f}s",
            ))
    return rows
