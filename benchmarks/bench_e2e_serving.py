"""End-to-end serving under load: the paper's headline claims, measured.

Replays trace-driven arrival processes (Poisson, §3) through the full
event-driven stack — per-user device engines racing a shared contended
``BatchedServer`` — at several offered-load points ρ = λ·s̄/k, and reports
TTFT p50/p95/p99, mean TBT, wasted-tokens ratio, and unified cost for:

* ``disco``          — racing + loser cancellation + migration (§4)
* ``disco_nocancel`` — the control: race losers generate to completion;
                       the baseline against which cancellation's
                       wasted-compute saving (§4.2, up to 84% cost) shows
* ``server_only``    — the vLLM-style all-server baseline: TTFT tail grows
                       with queueing (§2.3)
* ``device_only``    — the llama.cpp-style baseline: no queueing, but TTFT
                       scales with prompt length (§3)

Compute times are real JAX wall-clock; queueing is emergent MEMORY
contention: the shared server runs the paged KV pool, admission is
block-capacity-driven (``_ROWS`` batch rows over ``_NUM_BLOCKS`` blocks of
``_BLOCK_SIZE`` tokens, fewer blocks than the rows could consume), so under
load requests queue because the pool is full — per point the systems report
``blocks_in_use_peak`` / ``queued_on_memory`` / ``preemptions``. Loser
cancellation crosses the uplink RTT before it lands (``cancel_lag_tokens``),
so even disco wastes the propagation window's tokens. Emits
``BENCH_e2e_serving.json`` at the repo root — the TTFT-tail-under-load perf
trajectory — plus CSV rows for ``benchmarks/run.py``.

Every request carries an SLO contract (``Request.slo``): half the trace is
"interactive" (tight TTFT deadline, finite TBT target), half "relaxed"
(loose deadline); both share one priority tier so the admission comparison
isolates pure deadline ordering. Per system the bench reports Andes-style
``qoe_score_mean``, ``slo_attainment`` (full contract held) and
``ttft_slo_attainment``/``slo_misses`` (TTFT deadline alone), and at each
load point it runs an EDF-vs-FIFO admission comparison on the server-only
stack: the deadline-aware (EDF with expired-deadline demotion) queue must
strictly improve tail-TTFT SLO attainment over FIFO under overload.

``--temperature T`` runs the whole stack under stochastic sampling (the
position-keyed replayable sampler; T=0 keeps greedy); ``--mixed-samplers``
gives every request its own SamplerConfig (greedy / temperature+top-p /
temperature+top-k cycling) so heterogeneous per-row sampling shares the
fused server batches. Neither overwrites the greedy trajectory JSON.
A shared-prefix / multi-turn load point replays conversations that all open
with one system prompt (``make_multiturn_trace``) through the server with
the radix prefix cache ON and a cold-cache control at the same offered
load, reporting ``prefix_hit_rate`` / ``blocks_saved`` / mean-TTFT and
prefill-compute reductions (``multiturn`` in the JSON). ``--check-prefix``
gates it for CI: the cache must fire and every delivered stream must be
bit-identical to the cold run.

A mixed-length interference point (``make_interference_trace``: steady
short-prompt streamers + a max-length prompt every Nth arrival) compares
chunked prefill (``prefill_chunk``) against the monolithic control at the
same offered load (``interference`` in the JSON; the full piece-budget
sweep is ``bench_chunked_prefill`` / ``BENCH_chunked_prefill.json``).
``--check-chunked`` gates it for CI: chunked streams must be bit-identical
to the monolithic run under mixed temperature>0 samplers with a real
TBT-stall reduction.

``--check-determinism`` instead runs a seed-determinism gate: identical
models on both endpoints, MIXED per-request sampler configs, the same trace
replayed through two independently-built stacks — every delivered stream
must be bit-identical across the runs AND equal to the no-race
single-engine generation with the same (seed, sampler) (wall-clock noise
changes race winners and migration points between runs; the streams must
not care). Exits non-zero on any mismatch.

    PYTHONPATH=src python -m benchmarks.bench_e2e_serving \
        [--smoke] [--temperature T] [--mixed-samplers] [--check-determinism]
        [--trace-out PATH]

``--trace-out PATH`` additionally replays the top load point through a fully
traced disco stack (the headline numbers above stay tracer-free) and writes
Perfetto-loadable Chrome trace JSON: one track per endpoint/server row, one
async span per request.  The trace must be schema-valid, reconcile exactly
against the registry-backed ``DiSCoServer.stats()`` snapshot, and project
back onto the delivered token streams; inspect it with
``tools/trace_report.py`` or at https://ui.perfetto.dev.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import paper_models
from repro.core import CostModel, DiSCoScheduler, Endpoint, MigrationConfig
from repro.core.dispatch import SingleEndpointPolicy
from repro.models import init_params
from repro.serving import (
    SLO,
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    Request,
    SamplerConfig,
    ServerEndpoint,
    Tracer,
    reconcile_trace,
    replay_projection,
    validate_trace,
)
from repro.sim.traces import (
    make_interference_trace,
    make_multiturn_trace,
    make_serving_trace,
)

from .common import Row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_e2e_serving.json"

_LOADS = (0.4, 1.2, 3.0)     # offered load ρ: relaxed / saturated / overloaded
_ROWS = 4                    # batch rows — NOT the binding constraint
_BLOCK_SIZE = 16
_NUM_BLOCKS = 11             # 10 usable: ~2-3 concurrent requests of memory
_CAL_SLOTS = 2               # effective memory concurrency, calibrates ρ
_MAX_LEN = 96
_MAX_NEW = 16
_MAX_PROMPT = 40             # prefill buckets 16/32/64 are pre-warmed
_LONG_FRACTION = 0.25        # max-length prompts: ragged block demand
_N_REQUESTS = 18
_RTT = 0.05
_INTERACTIVE_FRACTION = 0.5  # tight-deadline share of the trace
# interactive TTFT deadline sits between the un-queued server TTFT (~0.3x
# service incl. uplink) and the overloaded queueing tail (several x
# service): an immediately- or promptly-admitted tight request attains, a
# deeply-queued one misses — exactly the window where deadline-aware
# admission pays (EDF jumps salvageable tight requests over relaxed ones;
# expired deadlines are demoted, so doomed requests cannot domino)
_TIGHT_DEADLINE_X = 2.0      # interactive TTFT deadline, in service times
_LOOSE_DEADLINE_X = 10.0     # relaxed TTFT deadline, in service times
_TBT_TARGET = 0.1            # interactive smooth-delivery pace (seconds)
_ADMISSION_TRACE_SEEDS = (42, 43, 44)   # EDF-vs-FIFO aggregates 3 traces:
                                        # 54 requests beat 1/18 granularity

_SYSTEMS = ("disco", "disco_spec", "disco_nocancel", "server_only",
            "device_only")

# shared-prefix / multi-turn load point (prefix-cache ON vs cold control at
# the SAME offered load): conversations share a system prompt and replay
# their growing history every turn, so the radix prefix index turns most of
# each prefill into a refcount bump + suffix-only compute
_MT_RHO = 2.0                # saturated: admission pressure, no total collapse
_MT_NUM_BLOCKS = 28          # roomier pool: cached prefixes are the point
_MT_SYSTEM_LEN = 64          # 4 sealed blocks shared by every conversation
_MT_MAX_PROMPT = 96          # bucket 96 is pre-warmed (incl. suffix shapes)
_MT_MAX_NEW = 8              # short turns: prefill-heavy, where caching pays
_MT_USERS = 4
_MT_N_REQUESTS = 24          # ~5 turns/user: enough hits to clear run noise

# heterogeneous per-request sampler cycle (--mixed-samplers): greedy rows
# batch-share the fused dispatches with temperature/top-p and top-k rows
_MIXED_SAMPLERS = (
    None,
    SamplerConfig(temperature=0.8, top_p=0.95),
    SamplerConfig(temperature=0.7, top_k=50),
)


def _make_scheduler(rng: np.random.Generator) -> DiSCoScheduler:
    # server-constrained regime (App. E.2 pricing shape): racing spends the
    # server budget only on the long prompts where the device is slow
    cm = CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12)
    return DiSCoScheduler(
        cm,
        server_ttft_samples=rng.lognormal(np.log(0.08), 0.5, 400),
        prompt_length_samples=np.clip(
            rng.lognormal(3.3, 0.9, 400), 1, _MAX_PROMPT
        ).astype(int),
        # b=0.7 puts the racing threshold near the trace median, so roughly
        # half the requests race the server (Eq. 3); b=0.5 would sit above
        # the clipped max prompt length and race nothing
        budget=0.7,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )


def _build(system: str, dev_engine: InferenceEngine, srv_params,
           seed: int, admission: str = "edf", tracer=None) -> DiSCoServer:
    server = BatchedServer(
        paper_models.TINY_SERVER, srv_params,
        max_slots=_ROWS, max_len=_MAX_LEN, decode_chunk=4,
        block_size=_BLOCK_SIZE, num_blocks=_NUM_BLOCKS, admission=admission,
        speculative=(system == "disco_spec"),
    )
    server.warmup(prompt_lens=(16, 32, _MAX_PROMPT))
    sched = _make_scheduler(np.random.default_rng(seed))
    single = system in ("server_only", "device_only")
    disco = DiSCoServer(
        sched,
        DeviceEndpoint(dev_engine),
        ServerEndpoint(server, NetworkModel(rtt_mean=_RTT, rtt_jitter=0.005)),
        rng=np.random.default_rng(seed + 1),
        cancel_losers=(system != "disco_nocancel"),
        allow_migration=system in ("disco", "disco_nocancel"),
        # single-endpoint baselines stay pure: no SLO-driven racing
        slo_aware_dispatch=not single,
        mode="speculative" if system == "disco_spec" else "race",
        tracer=tracer,
    )
    if system == "server_only":
        disco.sched.policy = SingleEndpointPolicy(Endpoint.SERVER)
    elif system == "device_only":
        disco.sched.policy = SingleEndpointPolicy(Endpoint.DEVICE)
    return disco


def _estimate_service_time(dev_engine: InferenceEngine, srv_params) -> float:
    """Pilot: mean virtual per-request service time of the batched server
    (median prompt, _MAX_NEW tokens) — calibrates the load points."""
    server = BatchedServer(
        paper_models.TINY_SERVER, srv_params,
        max_slots=1, max_len=_MAX_LEN, decode_chunk=4,
        block_size=_BLOCK_SIZE,      # ample pool: pilot measures pure service
    )
    server.warmup(prompt_lens=(16, 32, _MAX_PROMPT))
    rng = np.random.default_rng(0)
    n = 3
    for _ in range(n):
        server.submit(
            Request(rng.integers(0, 1024, size=24).astype(np.int32), _MAX_NEW)
        )
    server.run_to_completion()
    return server.clock / n


def _metrics(results) -> dict:
    ttfts = np.array([r.ttft for r in results])
    tbts = np.concatenate(
        [r.tbt_series for r in results if r.tbt_series] or [np.array([0.0])]
    )
    generated = sum(r.generated_tokens for r in results)
    wasted = sum(r.wasted_tokens for r in results)
    n = max(len(results), 1)
    return {
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p95_s": float(np.percentile(ttfts, 95)),
        "ttft_p99_s": float(np.percentile(ttfts, 99)),
        "tbt_mean_s": float(tbts.mean()),
        "wasted_tokens": int(wasted),
        "generated_tokens": int(generated),
        "wasted_ratio": float(wasted / max(generated, 1)),
        "cost_mean": float(np.mean([r.cost for r in results])),
        "migrations": int(sum(r.migrated for r in results)),
        "delayed_tokens": int(sum(r.delayed_tokens for r in results)),
        # QoE contract accounting (serving.request.QoEReport, Andes-style)
        "qoe_score_mean": float(np.mean([r.qoe.qoe_score for r in results])),
        "slo_attainment": float(sum(r.qoe.slo_attained for r in results) / n),
        "ttft_slo_attainment": float(
            sum(r.qoe.ttft_attained for r in results) / n
        ),
        "slo_misses": int(sum(not r.qoe.ttft_attained for r in results)),
    }


def _slo_for(i: int, service: float) -> tuple[SLO, int]:
    """Deterministic interactive/relaxed SLO mix: tight deadline + TBT pace
    for interactive requests, loose deadline otherwise. Both stay in ONE
    priority tier so the EDF-vs-FIFO comparison isolates pure deadline
    ordering (a strict tier would let already-doomed interactive requests
    crowd out relaxed ones under overload — tiers are for workloads whose
    classes must never mix, and are covered by unit tests)."""
    if (i % int(round(1.0 / _INTERACTIVE_FRACTION))) == 0:
        return SLO(ttft_deadline=_TIGHT_DEADLINE_X * service,
                   tbt_target=_TBT_TARGET), 0
    return SLO(ttft_deadline=_LOOSE_DEADLINE_X * service), 0


def _make_requests(trace, service: float, samplers) -> list[Request]:
    prompt_rng = np.random.default_rng(7)
    reqs = []
    for i, (a, length, m) in enumerate(trace):
        slo, tier = _slo_for(i, service)
        reqs.append(Request(
            prompt_rng.integers(0, 1024, size=length).astype(np.int32), m,
            arrival=a, sampler=samplers[i % len(samplers)], slo=slo,
            priority=tier,
        ))
    return reqs


def _copies(requests: list[Request]) -> list[Request]:
    return [dataclasses.replace(q, prompt=q.prompt.copy()) for q in requests]


def _drive_multiturn(srv_params, trace, service: float, samplers,
                     prefix_cache: bool):
    """Replay a multi-turn trace straight through the shared BatchedServer
    (the prefix cache is a server-side mechanism; the device never holds
    another user's conversation). Returns (streams, metrics)."""
    server = BatchedServer(
        paper_models.TINY_SERVER, srv_params,
        max_slots=_ROWS, max_len=_MAX_LEN, decode_chunk=4,
        block_size=_BLOCK_SIZE, num_blocks=_MT_NUM_BLOCKS,
        prefix_cache=prefix_cache,
    )
    server.warmup(prompt_lens=(16, 32, _MT_MAX_PROMPT))
    rids = []
    for i, (a, toks, m) in enumerate(trace):
        slo, tier = _slo_for(i, service)
        rids.append(server.submit(Request(
            toks.copy(), m, arrival=a,
            sampler=samplers[i % len(samplers)], slo=slo, priority=tier,
        )))
    done = server.run_to_completion()
    ttfts = np.array([server.ttft(r) for r in rids])
    stats = server.pool_stats()
    metrics = {
        "ttft_mean_s": float(ttfts.mean()),
        "ttft_p95_s": float(np.percentile(ttfts, 95)),
        "prefix_hit_rate": stats.get("prefix_hit_rate", 0.0),
        "prefix_tokens_hit": stats.get("prefix_tokens_hit", 0),
        "blocks_saved": stats.get("blocks_saved", 0),
        "copy_ops": stats.get("copy_ops", 0),
        "prefix_evictions": stats.get("prefix_evictions", 0),
        "prefill_tokens_computed": stats["prefill_tokens_computed"],
        "prefill_tokens_admitted": stats["prefill_tokens_admitted"],
        "prefill_compute_per_admitted_token":
            stats["prefill_compute_per_admitted_token"],
        "queued_on_memory": stats["queued_on_memory"],
        "preemptions": stats["preemptions"],
    }
    return [done[r] for r in rids], metrics


def _multiturn_point(srv_params, service: float, samplers,
                     n_req: int) -> dict:
    """The shared-prefix load point: prefix-cache ON vs the cold-cache
    control on the SAME trace at the SAME offered load."""
    trace = make_multiturn_trace(
        np.random.default_rng(41), n_req, service_time=service,
        slots=_CAL_SLOTS, rho=_MT_RHO, n_users=_MT_USERS,
        system_len=_MT_SYSTEM_LEN, max_new=_MT_MAX_NEW,
        max_prompt=_MT_MAX_PROMPT,
    )
    warm_streams, warm = _drive_multiturn(
        srv_params, trace, service, samplers, prefix_cache=True)
    cold_streams, cold = _drive_multiturn(
        srv_params, trace, service, samplers, prefix_cache=False)
    return {
        "rho": _MT_RHO,
        "trace": "multiturn_shared_system_prompt",
        "n_requests": n_req,
        "n_users": _MT_USERS,
        "system_prompt_tokens": _MT_SYSTEM_LEN,
        "num_blocks": _MT_NUM_BLOCKS,
        "streams_identical": warm_streams == cold_streams,
        "warm": warm,
        "cold": cold,
        "ttft_mean_reduction": 1.0 - warm["ttft_mean_s"]
        / max(cold["ttft_mean_s"], 1e-9),
        "prefill_compute_reduction": 1.0 - warm["prefill_tokens_computed"]
        / max(cold["prefill_tokens_computed"], 1),
    }


def _interference_point(srv_params, n_req: int) -> dict:
    """Mixed-length interference: chunked prefill vs monolithic on the SAME
    trace at the SAME offered load. Steady short-prompt streamers with a
    max-length prompt injected every Nth arrival — the workload where a
    monolithic server's fused prefill freezes every streaming row for a
    whole prompt. Delegates to ``bench_chunked_prefill`` (the full piece-
    budget sweep and the emitted JSON live there)."""
    from . import bench_chunked_prefill as cp

    service = cp._estimate_service_time(srv_params)
    trace = make_interference_trace(
        np.random.default_rng(42), n_req, service_time=service,
        slots=cp._ROWS, rho=cp._RHO, short_prompt=cp._SHORT_PROMPT,
        short_new=cp._SHORT_NEW, long_prompt=cp._LONG_PROMPT,
        long_every=cp._LONG_EVERY, long_new=cp._LONG_NEW,
    )
    mono_streams, mono = cp._drive(srv_params, trace, service, 0)
    chk_streams, chk = cp._drive(
        srv_params, trace, service, cp._HEADLINE_PIECE)
    return {
        "rho": cp._RHO,
        "trace": "interference_mixed_length",
        "n_requests": n_req,
        "long_prompt": cp._LONG_PROMPT,
        "long_every": cp._LONG_EVERY,
        "piece_budget": cp._HEADLINE_PIECE,
        "streams_identical": chk_streams == mono_streams,
        "monolithic": mono,
        "chunked": chk,
        "tbt_stall_p99_reduction": mono["tbt_stall_p99_s"]
        / max(chk["tbt_stall_p99_s"], 1e-9),
        "decode_stall_max_reduction": mono["decode_stall_max_s"]
        / max(chk["decode_stall_max_s"], 1e-9),
    }


def run(smoke: bool = False, temperature: float = 0.0,
        mixed_samplers: bool = False, trace_out: str | None = None) -> list[Row]:
    dev_cfg = paper_models.TINY_DEVICE
    srv_cfg = paper_models.TINY_SERVER
    if mixed_samplers:
        samplers: tuple = _MIXED_SAMPLERS
    elif temperature > 0:
        samplers = (SamplerConfig(temperature=temperature),)
    else:
        samplers = (None,)
    dev_params = init_params(dev_cfg, jax.random.PRNGKey(0))
    dev_engine = InferenceEngine(dev_cfg, dev_params, max_len=_MAX_LEN)
    dev_engine.warmup(prompt_lens=(16, 32, _MAX_PROMPT))
    srv_params = init_params(srv_cfg, jax.random.PRNGKey(1))
    # disco_spec drafts MATCHED-MODEL (the device runs the server's weights,
    # i.e. self-speculation): rejection sampling is then lossless AND, under
    # the greedy standard trace, acceptance is exact — the mismatched-drafter
    # degradation is swept separately in bench_speculative's temperature-gap
    # axis. speculative=True pre-compiles the draft-window scans so no XLA
    # compile lands inside a virtual-timed round.
    spec_dev_engine = InferenceEngine(
        srv_cfg, srv_params, max_len=_MAX_LEN, speculative=True,
    )
    spec_dev_engine.warmup(prompt_lens=(16, 32, _MAX_PROMPT))

    service = _estimate_service_time(dev_engine, srv_params)
    loads = (_LOADS[-1],) if smoke else _LOADS
    n_req = 5 if smoke else _N_REQUESTS

    rows: list[Row] = []
    points = []
    for rho in loads:
        trace_rng = np.random.default_rng(42)
        trace = make_serving_trace(
            trace_rng, n_req, service_time=service, slots=_CAL_SLOTS, rho=rho,
            max_prompt=_MAX_PROMPT, max_new=_MAX_NEW,
            long_fraction=_LONG_FRACTION,
        )
        requests = _make_requests(trace, service, samplers)
        point = {"rho": rho, "systems": {}}
        for system in _SYSTEMS:
            engine = spec_dev_engine if system == "disco_spec" else dev_engine
            disco = _build(system, engine, srv_params, seed=3)
            t0 = time.perf_counter()
            results = disco.serve_many(_copies(requests))
            wall_us = (time.perf_counter() - t0) * 1e6
            m = _metrics(results)
            # memory-pressure accounting + driver ledgers, one registry-backed
            # snapshot (includes spec_requests/spec_fallbacks for disco_spec)
            m.update(disco.stats())
            point["systems"][system] = m
            rows.append(Row(
                f"e2e_serving/rho{rho:g}/{system}", wall_us,
                f"p99_ttft_ms={m['ttft_p99_s']*1e3:.1f};"
                f"tbt_ms={m['tbt_mean_s']*1e3:.1f};"
                f"wasted={m['wasted_ratio']:.3f};"
                f"qoe={m['qoe_score_mean']:.3f};"
                f"slo_att={m['ttft_slo_attainment']:.2f};"
                f"blk_peak={m.get('blocks_in_use_peak', 0)};"
                f"q_mem={m.get('queued_on_memory', 0)};"
                f"cost={m['cost_mean']:.2e}",
            ))
        # EDF-vs-FIFO admission comparison on the queueing-bound system at
        # this load: the deadline-aware queue should rescue tight-deadline
        # requests that FIFO leaves stuck behind relaxed ones. Aggregated
        # over several arrival traces so the gain is not a 1/n_req coin-flip
        # (smoke keeps one trace for speed).
        cmp_seeds = _ADMISSION_TRACE_SEEDS[:1] if smoke else _ADMISSION_TRACE_SEEDS
        admission_cmp = {a: {"attained": 0, "slo_attained": 0, "n": 0,
                             "qoe_sum": 0.0, "deadline_reorders": 0,
                             "server_slo_misses": 0, "ttfts": []}
                         for a in ("fifo", "edf")}
        for tseed in cmp_seeds:
            trace_k = make_serving_trace(
                np.random.default_rng(tseed), n_req, service_time=service,
                slots=_CAL_SLOTS, rho=rho, max_prompt=_MAX_PROMPT,
                max_new=_MAX_NEW, long_fraction=_LONG_FRACTION,
            )
            reqs_k = _make_requests(trace_k, service, samplers)
            for admission in ("fifo", "edf"):
                disco = _build("server_only", dev_engine, srv_params, seed=3,
                               admission=admission)
                res = disco.serve_many(_copies(reqs_k))
                agg = admission_cmp[admission]
                agg["n"] += len(res)
                agg["attained"] += sum(r.qoe.ttft_attained for r in res)
                agg["slo_attained"] += sum(r.qoe.slo_attained for r in res)
                agg["qoe_sum"] += sum(r.qoe.qoe_score for r in res)
                agg["ttfts"] += [r.ttft for r in res]
                stats = disco.stats()
                agg["deadline_reorders"] += stats["deadline_reorders"]
                agg["server_slo_misses"] += stats["server_slo_misses"]
        for admission, agg in admission_cmp.items():
            n = max(agg.pop("n"), 1)
            agg["ttft_slo_attainment"] = agg.pop("attained") / n
            agg["slo_attainment"] = agg.pop("slo_attained") / n
            agg["qoe_score_mean"] = agg.pop("qoe_sum") / n
            agg["slo_misses"] = n - int(round(agg["ttft_slo_attainment"] * n))
            agg["ttft_p99_s"] = float(np.percentile(agg.pop("ttfts"), 99))
            agg["n_requests"] = n
        point["admission_comparison"] = admission_cmp
        rows.append(Row(
            f"e2e_serving/rho{rho:g}/admission_edf_vs_fifo", 0.0,
            f"edf_slo_att={admission_cmp['edf']['ttft_slo_attainment']:.2f};"
            f"fifo_slo_att={admission_cmp['fifo']['ttft_slo_attainment']:.2f};"
            f"reorders={admission_cmp['edf']['deadline_reorders']}",
        ))
        points.append(point)

    if trace_out:
        # Extra traced pass of the disco stack at the top load point: the
        # headline numbers above were measured tracer-free, so tracing cost
        # never taints them.  The trace must be schema-valid, reconcile
        # exactly against the registry snapshot, and project back onto the
        # delivered token streams.
        tracer = Tracer()
        disco = _build("disco", dev_engine, srv_params, seed=3, tracer=tracer)
        results = disco.serve_many(_copies(requests))
        stats = disco.stats()
        trace = tracer.export()
        problems = validate_trace(trace) + reconcile_trace(trace, stats)
        proj = replay_projection(trace)
        for r in results:
            if proj.get(r.rid, {}).get("tokens") != r.tokens:
                problems.append(
                    f"request {r.rid}: trace tokens != delivered stream")
        if problems:
            raise SystemExit(
                "traced e2e pass FAILED:\n  " + "\n  ".join(problems))
        tracer.save(trace_out, metadata={
            "bench": "e2e_serving", "system": "disco", "rho": loads[-1],
            "n_requests": n_req, "stats": stats,
        })
        rows.append(Row(
            f"e2e_serving/rho{loads[-1]:g}/trace", 0.0,
            f"events={len(trace['traceEvents'])};"
            f"requests={len(results)};reconciled=1",
        ))

    # shared-prefix / multi-turn point: prefix cache vs cold-cache control
    mt = _multiturn_point(srv_params, service, samplers,
                          n_req=6 if smoke else _MT_N_REQUESTS)
    rows.append(Row(
        f"e2e_serving/multiturn_rho{_MT_RHO:g}/prefix_cache", 0.0,
        f"hit_rate={mt['warm']['prefix_hit_rate']:.2f};"
        f"blocks_saved={mt['warm']['blocks_saved']};"
        f"ttft_mean_reduction={mt['ttft_mean_reduction']:.2f};"
        f"prefill_compute_reduction={mt['prefill_compute_reduction']:.2f};"
        f"identical={int(mt['streams_identical'])}",
    ))

    # mixed-length interference point: chunked prefill vs the monolithic
    # control on the same trace (the full piece sweep is BENCH_chunked_prefill)
    ip = _interference_point(srv_params, n_req=8 if smoke else 16)
    rows.append(Row(
        f"e2e_serving/interference_rho{ip['rho']:g}/chunked_prefill", 0.0,
        f"stall_reduction_x={ip['tbt_stall_p99_reduction']:.1f};"
        f"stall_max_ms={ip['monolithic']['decode_stall_max_s']*1e3:.1f}"
        f"->{ip['chunked']['decode_stall_max_s']*1e3:.1f};"
        f"slo_att={ip['chunked']['ttft_slo_attainment']:.2f}"
        f"(mono={ip['monolithic']['ttft_slo_attainment']:.2f});"
        f"identical={int(ip['streams_identical'])}",
    ))

    # headline: contention point (highest load). The reduction denominator is
    # floored at "one wasted token" so a perfectly clean disco run reports a
    # finite, token-count-scaled reduction instead of dividing by zero.
    top = points[-1]["systems"]
    low = points[0]["systems"]
    disco_floor = max(
        top["disco"]["wasted_ratio"],
        1.0 / max(top["disco"]["generated_tokens"], 1),
    )
    wasted_reduction = top["disco_nocancel"]["wasted_ratio"] / disco_floor
    adm = points[-1]["admission_comparison"]
    headline = {
        "p99_ttft_disco_s": top["disco"]["ttft_p99_s"],
        "p99_ttft_server_only_s": top["server_only"]["ttft_p99_s"],
        "p99_ttft_reduction_vs_server_only": 1.0
        - top["disco"]["ttft_p99_s"] / max(top["server_only"]["ttft_p99_s"], 1e-9),
        "wasted_ratio_reduction_vs_nocancel": wasted_reduction,
        "cost_vs_nocancel": top["disco"]["cost_mean"]
        / max(top["disco_nocancel"]["cost_mean"], 1e-30),
        "qoe_score_disco": top["disco"]["qoe_score_mean"],
        "slo_attainment_disco": top["disco"]["ttft_slo_attainment"],
        # deadline-aware admission under overload: EDF vs FIFO tail-TTFT
        # SLO attainment on the queueing-bound server-only stack
        "edf_ttft_slo_attainment": adm["edf"]["ttft_slo_attainment"],
        "fifo_ttft_slo_attainment": adm["fifo"]["ttft_slo_attainment"],
        "edf_slo_attainment_gain": adm["edf"]["ttft_slo_attainment"]
        - adm["fifo"]["ttft_slo_attainment"],
        # shared-prefix serving: the radix prefix cache vs cold control
        "prefix_hit_rate_multiturn": mt["warm"]["prefix_hit_rate"],
        "prefix_blocks_saved_multiturn": mt["warm"]["blocks_saved"],
        "prefix_ttft_mean_reduction": mt["ttft_mean_reduction"],
        "prefix_prefill_compute_reduction": mt["prefill_compute_reduction"],
        # chunked prefill under mixed-length interference: bounded decode
        # stalls with the stream bit-identical to the monolithic schedule
        "chunked_tbt_stall_p99_reduction": ip["tbt_stall_p99_reduction"],
        "chunked_decode_stall_max_reduction":
            ip["decode_stall_max_reduction"],
        "chunked_streams_identical": int(ip["streams_identical"]),
        # device-draft / server-verify on the same traces. Two honest
        # comparisons, reported at the relaxed load point (points[0]):
        #  * vs race-and-cancel — spec converts the race's wasted loser
        #    tokens into accepted drafts (lower wasted_ratio), but in this
        #    free-device testbed the race's residual waste is already tiny
        #    (~1 token of cancel lag per loser), so the verify premium
        #    (every token scored at input price) can exceed it;
        #  * vs server_only — the like-for-like LOSSLESS comparison: both
        #    deliver the identical server-distributed stream, and spec gets
        #    it at input-token verify prices instead of output-token decode
        #    prices. Race cannot make this claim (a device winner's stream
        #    is device-distributed).
        "spec_cost_vs_race": low["disco_spec"]["cost_mean"]
        / max(low["disco"]["cost_mean"], 1e-30),
        "spec_cost_vs_server_only": low["disco_spec"]["cost_mean"]
        / max(low["server_only"]["cost_mean"], 1e-30),
        "spec_tbt_vs_race": low["disco_spec"]["tbt_mean_s"]
        / max(low["disco"]["tbt_mean_s"], 1e-9),
        "spec_wasted_ratio": low["disco_spec"]["wasted_ratio"],
        "race_wasted_ratio": low["disco"]["wasted_ratio"],
        "spec_acceptance_rate": low["disco_spec"].get("acceptance_rate", 0.0),
        "spec_fallbacks": low["disco_spec"].get("spec_fallbacks", 0),
        "spec_p99_ttft_s": low["disco_spec"]["ttft_p99_s"],
    }
    rows.append(Row(
        "e2e_serving/headline", 0.0,
        f"p99_vs_server_only={headline['p99_ttft_reduction_vs_server_only']:.2f};"
        f"wasted_reduction_x={wasted_reduction:.1f};"
        f"edf_gain={headline['edf_slo_attainment_gain']:.2f}",
    ))
    rows.append(Row(
        "e2e_serving/speculative", 0.0,
        f"cost_vs_server_only={headline['spec_cost_vs_server_only']:.2f};"
        f"cost_vs_race={headline['spec_cost_vs_race']:.2f};"
        f"acceptance={headline['spec_acceptance_rate']:.2f};"
        f"wasted={headline['spec_wasted_ratio']:.3f}"
        f"(race={headline['race_wasted_ratio']:.3f})",
    ))

    if not smoke and temperature == 0.0 and not mixed_samplers:
        # never clobber the greedy trajectory
        _JSON_PATH.write_text(json.dumps({
            "bench": "e2e_serving",
            "server_rows": _ROWS,
            "num_blocks": _NUM_BLOCKS,
            "block_size": _BLOCK_SIZE,
            "calibration_slots": _CAL_SLOTS,
            "admission": "paged_block_capacity+edf",
            "long_prompt_fraction": _LONG_FRACTION,
            "n_requests": n_req,
            "max_new": _MAX_NEW,
            "service_time_s": service,
            "arrival_process": "poisson",
            # headline numbers are always measured with telemetry disabled;
            # --trace-out adds a separate traced pass that never feeds them
            "telemetry": "off",
            "slo": {
                "interactive_fraction": _INTERACTIVE_FRACTION,
                "tight_ttft_deadline_s": _TIGHT_DEADLINE_X * service,
                "loose_ttft_deadline_s": _LOOSE_DEADLINE_X * service,
                "tbt_target_s": _TBT_TARGET,
            },
            "points": points,
            "multiturn": mt,
            "interference": ip,
            "headline": headline,
        }, indent=2) + "\n")
    return rows


def check_determinism(temperature: float = 0.8, n_requests: int = 4) -> None:
    """Seed-determinism gate (CI): identical endpoint models, MIXED
    per-request sampler configs (greedy + temperature/top-p + top-k rows
    sharing the fused server batches), same trace through two
    independently-built stacks. Wall-clock noise moves race winners,
    migration points, and preemptions between the runs — the delivered
    streams must be bit-identical anyway, and equal to the no-race
    single-engine generation with the same per-request (seed, sampler)
    (the driver seeds requests by rid = arrival index).

    Both stacks run fully traced: the traces must each be schema-valid and
    their :func:`replay_projection` — per-request delivered tokens + terminal
    outcome — must be identical (timestamps legitimately differ: compute is
    measured wall-clock, so race winners and migration points can move)."""
    cfg = paper_models.TINY_DEVICE
    params = init_params(cfg, jax.random.PRNGKey(0))
    samplers = [
        SamplerConfig(temperature=temperature, top_p=0.95),
        None,                                   # a greedy row in the batch
        SamplerConfig(temperature=temperature, top_k=40),
        SamplerConfig(temperature=0.8 * temperature, top_p=0.9),
    ]
    dev_engine = InferenceEngine(cfg, params, max_len=_MAX_LEN)
    dev_engine.warmup(prompt_lens=(12,))

    def build(tracer=None):
        server = BatchedServer(
            cfg, params, max_slots=2, max_len=_MAX_LEN, decode_chunk=4,
            block_size=_BLOCK_SIZE, num_blocks=_NUM_BLOCKS,
        )
        server.warmup(prompt_lens=(12,))
        # device-constrained pricing: decode is expensive on the winner, so
        # the driver migrates mid-stream — the gate must cover the
        # consistent-prefix hand-off, not just the race
        rng0 = np.random.default_rng(3)
        sched = DiSCoScheduler(
            CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6),
            server_ttft_samples=rng0.lognormal(np.log(0.3), 0.5, 400),
            prompt_length_samples=np.clip(
                rng0.lognormal(2.5, 0.8, 400), 1, 64
            ).astype(int),
            budget=0.5,
            migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.005),
        )
        return DiSCoServer(
            sched, DeviceEndpoint(dev_engine),
            ServerEndpoint(server, NetworkModel(rtt_mean=0.01, rtt_jitter=0.0)),
            rng=np.random.default_rng(4),
            tracer=tracer,
        )

    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=12).astype(np.int32)
               for _ in range(n_requests)]
    reqs = [
        Request(p, _MAX_NEW, arrival=0.002 * i,
                sampler=samplers[i % len(samplers)])
        for i, p in enumerate(prompts)
    ]
    baseline = [
        dev_engine.generate(p, _MAX_NEW, seed=i,
                            sampler=samplers[i % len(samplers)]).tokens
        for i, p in enumerate(prompts)
    ]
    tr1, tr2 = Tracer(), Tracer()
    run1 = build(tr1).serve_many(_copies(reqs))
    run2 = build(tr2).serve_many(_copies(reqs))
    failures = []
    for i, (r1, r2, base) in enumerate(zip(run1, run2, baseline)):
        if r1.tokens != r2.tokens:
            failures.append(f"request {i}: run1 != run2")
        if r1.tokens != base:
            failures.append(f"request {i}: delivered != same-seed baseline")
    # trace-level determinism: schema-valid traces whose replay projections
    # (delivered tokens + outcomes, NOT timestamps) are bit-identical
    for label, tr in (("run1", tr1), ("run2", tr2)):
        for p in validate_trace(tr.export()):
            failures.append(f"{label} trace invalid: {p}")
    proj1 = replay_projection(tr1.export())
    proj2 = replay_projection(tr2.export())
    if proj1 != proj2:
        diff = [str(rid) for rid in proj1 if proj1[rid] != proj2.get(rid)]
        failures.append(
            "trace replay projections differ (requests: "
            + ", ".join(diff or ["<id sets>"]) + ")"
        )
    for r in run1:
        if proj1.get(r.rid, {}).get("tokens") != r.tokens:
            failures.append(
                f"request {r.rid}: trace projection != delivered stream")
    if failures:
        raise SystemExit(
            "seed-determinism FAILED (temperature="
            f"{temperature}, mixed samplers):\n  " + "\n  ".join(failures)
        )
    print(
        f"seed-determinism OK: {n_requests} requests x 2 runs bit-identical "
        f"(mixed per-request samplers, base temperature={temperature}, "
        f"migrations run1/run2: {sum(r.migrated for r in run1)}/"
        f"{sum(r.migrated for r in run2)}; trace replay projections "
        f"identical across {len(tr1.events)}/{len(tr2.events)}-event traces)"
    )


def check_speculative(temperature: float = 0.8, n_requests: int = 6) -> None:
    """Speculative-decoding gate (CI): matched endpoint models (the
    lossless configuration), stochastic sampling, one arrival trace through
    ``mode="speculative"`` and ``mode="race"`` stacks. Requires (1) the
    draft/verify path actually engaged (``spec_requests`` > 0), (2) drafts
    actually accepted (``acceptance_rate`` > 0), and (3) every delivered
    stream bit-identical to the race run AND to the no-race single-engine
    generation with the same (seed, sampler) — rejection sampling plus the
    salted accept/residual streams must never change WHAT is sampled.
    Exits non-zero on any mismatch."""
    cfg = paper_models.TINY_SERVER
    params = init_params(cfg, jax.random.PRNGKey(1))
    samp = SamplerConfig(temperature=temperature)

    def build(mode: str) -> DiSCoServer:
        server = BatchedServer(
            cfg, params, max_slots=_ROWS, max_len=_MAX_LEN, decode_chunk=4,
            block_size=_BLOCK_SIZE, speculative=(mode == "speculative"),
        )
        server.warmup(prompt_lens=(16, 32))
        dev = InferenceEngine(
            cfg, params, max_len=_MAX_LEN, paged=True, kv_rows=n_requests,
            speculative=(mode == "speculative"),
        )
        dev.warmup(prompt_lens=(16, 32))
        rng0 = np.random.default_rng(0)
        sched = DiSCoScheduler(
            CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12),
            server_ttft_samples=rng0.lognormal(np.log(0.3), 0.5, 400),
            prompt_length_samples=np.clip(
                rng0.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
            budget=0.9,       # most requests race -> most take the spec path
            migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
        )
        return DiSCoServer(
            sched, DeviceEndpoint(dev),
            ServerEndpoint(server, NetworkModel(rtt_mean=_RTT)),
            rng=np.random.default_rng(7), mode=mode,
        )

    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32)
               for n in rng.integers(8, 32, size=n_requests)]
    reqs = [Request(p, _MAX_NEW, arrival=0.1 * i, seed=50 + i, sampler=samp)
            for i, p in enumerate(prompts)]

    spec = build("speculative")
    res_spec = spec.serve_many(_copies(reqs))
    stats = spec.stats()
    res_race = build("race").serve_many(_copies(reqs))
    single = InferenceEngine(cfg, params, max_len=_MAX_LEN)
    single.warmup(prompt_lens=(16, 32))
    baseline = [single.generate(p, _MAX_NEW, seed=50 + i, sampler=samp).tokens
                for i, p in enumerate(prompts)]

    failures = []
    if not spec.spec_requests > 0:
        failures.append("no request took the draft/verify path")
    if not stats.get("acceptance_rate", 0.0) > 0:
        failures.append(
            f"no draft accepted (acceptance_rate="
            f"{stats.get('acceptance_rate')})"
        )
    for i, (rs, rr, base) in enumerate(zip(res_spec, res_race, baseline)):
        if rs.tokens != rr.tokens:
            failures.append(f"request {i}: speculative != race")
        if rs.tokens != base:
            failures.append(f"request {i}: speculative != same-seed baseline")
    if failures:
        raise SystemExit(
            f"speculative gate FAILED (temperature={temperature}):\n  "
            + "\n  ".join(failures)
        )
    print(
        f"speculative OK: {n_requests} requests bit-identical to race AND "
        f"single-engine baseline (spec_requests={spec.spec_requests}, "
        f"fallbacks={spec.spec_fallbacks}, "
        f"acceptance_rate={stats['acceptance_rate']:.2f}, "
        f"verify_rounds={stats['verify_rounds']}, "
        f"temperature={temperature})"
    )


def check_prefix(temperature: float = 0.8, n_requests: int = 10) -> None:
    """Prefix-cache gate (CI): a multi-turn shared-system-prompt trace with
    MIXED per-request samplers through a prefix-cached server and a
    cold-cache control. The cache must actually fire (``prefix_hit_rate``
    > 0) AND every delivered stream must be bit-identical to the cold run —
    a hit changes what is computed, never what is sampled. Exits non-zero
    on any mismatch."""
    srv_params = init_params(paper_models.TINY_SERVER, jax.random.PRNGKey(1))
    service = 0.05           # identity must not depend on the load point
    trace = make_multiturn_trace(
        np.random.default_rng(41), n_requests, service_time=service,
        slots=_CAL_SLOTS, rho=_MT_RHO, n_users=3,
        system_len=_MT_SYSTEM_LEN, max_new=_MAX_NEW,
        max_prompt=_MT_MAX_PROMPT,
    )
    samplers = (
        SamplerConfig(temperature=temperature, top_p=0.95),
        None,                                   # a greedy row in the batch
        SamplerConfig(temperature=temperature, top_k=40),
    )
    warm_streams, warm = _drive_multiturn(
        srv_params, trace, service, samplers, prefix_cache=True)
    cold_streams, cold = _drive_multiturn(
        srv_params, trace, service, samplers, prefix_cache=False)
    failures = []
    if not warm["prefix_hit_rate"] > 0:
        failures.append(
            f"prefix cache never fired (hit_rate={warm['prefix_hit_rate']})"
        )
    for i, (w, c) in enumerate(zip(warm_streams, cold_streams)):
        if w != c:
            failures.append(f"request {i}: warm stream != cold stream")
    if failures:
        raise SystemExit(
            "prefix-cache gate FAILED (temperature="
            f"{temperature}, mixed samplers):\n  " + "\n  ".join(failures)
        )
    print(
        f"prefix-cache OK: {n_requests} multi-turn requests bit-identical "
        f"warm vs cold (hit_rate={warm['prefix_hit_rate']:.2f}, "
        f"blocks_saved={warm['blocks_saved']}, "
        f"prefill computed {warm['prefill_tokens_computed']} vs "
        f"{cold['prefill_tokens_computed']} cold, copies={warm['copy_ops']})"
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single load point, 5 requests, no JSON emission")
    ap.add_argument("--temperature", type=float, default=None,
                    help="sampling temperature (default: greedy for the "
                         "bench, 0.8 for the determinism gate; stochastic "
                         "runs never overwrite the greedy trajectory JSON)")
    ap.add_argument("--mixed-samplers", action="store_true",
                    help="give every request its own SamplerConfig (greedy/"
                         "top-p/top-k cycle): exercises heterogeneous "
                         "per-row sampling in one fused batch; never "
                         "overwrites the greedy trajectory JSON")
    ap.add_argument("--check-determinism", action="store_true",
                    help="run the seed-determinism gate instead of the bench "
                         "(also diffs the replay projections of two same-"
                         "seed traces)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="run an EXTRA fully-traced disco pass at the top "
                         "load point and write Perfetto-loadable Chrome "
                         "trace JSON there (headline numbers stay "
                         "tracer-free); the trace must validate and "
                         "reconcile against the stats registry")
    ap.add_argument("--check-prefix", action="store_true",
                    help="run the prefix-cache gate instead of the bench: "
                         "multi-turn trace, prefix_hit_rate > 0, streams "
                         "bit-identical to a cold-cache run")
    ap.add_argument("--check-chunked", action="store_true",
                    help="run the chunked-prefill gate instead of the bench: "
                         "interference trace under mixed temperature>0 "
                         "samplers, chunked streams bit-identical to the "
                         "monolithic run and a real TBT-stall reduction")
    ap.add_argument("--check-speculative", action="store_true",
                    help="run the speculative-decoding gate instead of the "
                         "bench: matched models, drafts must be accepted "
                         "(acceptance_rate > 0) and every stream must be "
                         "bit-identical to the race run and the same-seed "
                         "single-engine baseline")
    args = ap.parse_args()
    if args.check_chunked:
        if args.smoke:
            ap.error("--smoke does not apply to --check-chunked")
        from .bench_chunked_prefill import check as _check_chunked

        _check_chunked()
    elif args.check_speculative:
        t = 0.8 if args.temperature is None else args.temperature
        if t <= 0:
            ap.error("--check-speculative requires --temperature > 0")
        if args.smoke:
            ap.error("--smoke does not apply to --check-speculative")
        check_speculative(temperature=t)
    elif args.check_prefix:
        t = 0.8 if args.temperature is None else args.temperature
        if t <= 0:
            ap.error("--check-prefix requires --temperature > 0")
        if args.smoke:
            ap.error("--smoke does not apply to --check-prefix")
        check_prefix(temperature=t)
    elif args.check_determinism:
        t = 0.8 if args.temperature is None else args.temperature
        if t <= 0:
            ap.error("--check-determinism requires --temperature > 0")
        if args.smoke:
            ap.error("--smoke does not apply to --check-determinism")
        check_determinism(temperature=t)
    else:
        print("name,us_per_call,derived")
        for row in run(smoke=args.smoke, temperature=args.temperature or 0.0,
                       mixed_samplers=args.mixed_samplers,
                       trace_out=args.trace_out):
            print(row.csv(), flush=True)
