"""Figure 7: end-to-end cost with vs without token-level migration
(DiSCo-D / DiSCo-S vs their no-migration ablations).

Paper: cost reductions up to 72.7% (device-constr.) / 83.6% (server-constr.).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    LengthDistribution,
    MigrationConfig,
    make_policy,
    simulate_full,
    summarize,
)
from repro.sim import (
    DEVICE_PROFILES,
    build_cost_model,
    make_requests,
    make_server_model,
)

from .common import Row, pct_reduction, timed

N_REQ = 120
BUDGET = 0.7


def run() -> list[Row]:
    rows = []
    for trace in ("gpt", "llama", "deepseek", "command"):
        for constraint, label in (("device", "DiSCo-D"), ("server", "DiSCo-S")):
            for device_name in ("xiaomi14-qwen05b", "pixel7pro-bloom1b1"):
                def cell():
                    rng = np.random.default_rng(0)
                    server = make_server_model(trace, rng)
                    device = DEVICE_PROFILES[device_name]
                    cm = build_cost_model(trace, device_name, constraint)
                    lengths_profile = np.random.default_rng(1)
                    from repro.sim import sample_prompt_lengths
                    ld = LengthDistribution.from_samples(
                        sample_prompt_lengths(lengths_profile, 2000)
                    )
                    pol = make_policy(cm, server.ttft, ld, BUDGET)
                    reqs = make_requests(np.random.default_rng(2), N_REQ)
                    base = summarize(simulate_full(
                        reqs, pol, cm, server, device,
                        np.random.default_rng(3), migration=None,
                    ))
                    mig = summarize(simulate_full(
                        reqs, pol, cm, server, device,
                        np.random.default_rng(3), migration=MigrationConfig(),
                    ))
                    return base.mean_cost, mig.mean_cost, mig.p99_tbt
                (c0, c1, tbt), us = timed(cell)
                rows.append(Row(
                    f"fig7/{label}_{trace}_{device_name}", us,
                    f"cost_reduction={pct_reduction(c0, c1):.1f}%"
                    f";tbt_p99={tbt:.3f}s",
                ))
    return rows
