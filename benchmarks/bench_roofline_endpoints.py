"""Integration: dry-run rooflines → DiSCo endpoint models.

Derives each architecture's prefill/decode token rates from its dry-run
roofline terms (time/step = max(compute, memory, collective)), builds a
DiSCo deployment with gemma3-1b as the device endpoint and nemotron-4-340b
(post-§Perf shmap-decode) as the server endpoint behind the usual
network/queue process, and reports the TTFT/cost effect — closing the loop
between the substrate analysis and the paper's scheduler.

Requires experiments/dryrun/*.json (run the dry-runs first); rows are
skipped gracefully if absent.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import (
    CostModel,
    EmpiricalCDF,
    Endpoint,
    LengthDistribution,
    StochasticPolicy,
    make_policy,
    simulate_ttft,
)
from repro.core.simulator import DeviceModel, ServerModel
from repro.sim import sample_prompt_lengths

from .common import Row, pct_reduction, timed

DRYRUN_DIR = "experiments/dryrun"


def _load(tag: str):
    path = os.path.join(DRYRUN_DIR, tag + ".json")
    if not os.path.exists(path):
        return None
    r = json.load(open(path))
    return r if r.get("status") == "ok" else None


def _step_seconds(rec: dict) -> float:
    rl = rec["roofline"]
    return max(rl["compute_s"], rl["memory_s"], rl["collective_s"])


def run() -> list[Row]:
    rows = []
    dev_prefill = _load("gemma3-1b__prefill_32k__single__dp-cache-noremat") or _load(
        "gemma3-1b__prefill_32k__single"
    )
    dev_decode = _load("gemma3-1b__decode_32k__single")
    srv_decode = _load("nemotron-4-340b__decode_32k__single__shmap-decode") or _load(
        "nemotron-4-340b__decode_32k__single"
    )
    if not (dev_prefill and dev_decode and srv_decode):
        return [Row("roofline_endpoints/skipped", 0.0, "dry-run JSONs missing")]

    # device = gemma3 on ONE v5e chip (the "device endpoint" is a single
    # accelerator, not the pod): analytic single-chip roofline.
    from repro.configs import get_config
    from repro.launch.analytic import analytic_costs
    from repro.launch.mesh import HW
    cfg_dev = get_config("gemma3-1b")
    ac_p = analytic_costs(cfg_dev, "prefill", 1, 2048, 1, model_shard=1)
    t_prefill = max(ac_p.flops_per_device / HW.PEAK_FLOPS_BF16,
                    ac_p.bytes_per_device / HW.HBM_BW)
    prefill_rate = 2048 / t_prefill
    ac_d = analytic_costs(cfg_dev, "decode", 1, 2048, 1, model_shard=1)
    t_dec = max(ac_d.flops_per_device / HW.PEAK_FLOPS_BF16,
                ac_d.bytes_per_device / HW.HBM_BW)
    decode_rate = 1.0 / t_dec
    sd = srv_decode
    srv_tbt = _step_seconds(sd)  # batched: one step serves the whole batch

    rows.append(Row(
        "roofline_endpoints/device_gemma3_1chip", 0.0,
        f"prefill={prefill_rate:.0f}tok/s;decode={decode_rate:.1f}tok/s",
    ))
    rows.append(Row(
        "roofline_endpoints/server_nemotron", 0.0,
        f"tbt={srv_tbt*1e3:.1f}ms/step (batch {sd['global_batch']})",
    ))

    def sim(derate: float = 1.0):
        rng = np.random.default_rng(0)
        device = DeviceModel(prefill_rate=prefill_rate / derate,
                             decode_rate=max(decode_rate / derate, 1.0),
                             name="gemma3-1b@v5e")
        # server TTFT = queueing spikes + network + (prefill step per §3,
        # length-insensitive at server batch sizes)
        base = 0.15 + np.abs(rng.normal(0, 0.05, 4000))
        spikes = np.where(rng.random(4000) < 0.06, rng.exponential(1.5, 4000), 0.0)
        server = ServerModel(ttft=EmpiricalCDF.from_samples(base + spikes),
                             tbt_mean=srv_tbt)
        lengths = sample_prompt_lengths(rng, 3000)
        ld = LengthDistribution.from_samples(lengths)
        cm = CostModel(1e-6, 4e-6, 500.0, 450.0, exchange_rate=1e-12)  # server-constrained
        reds = []
        for b in (0.3, 0.6, 0.9):
            disco = make_policy(cm, server.ttft, ld, b)
            stoch = StochasticPolicy(Endpoint.SERVER, b, seed=1)
            m_d = simulate_ttft(lengths, disco, server, device, np.random.default_rng(2))["ttft"]
            m_s = simulate_ttft(lengths, stoch, server, device, np.random.default_rng(2))["ttft"]
            reds.append(pct_reduction(np.percentile(m_s, 99), np.percentile(m_d, 99)))
        return float(np.mean(reds))
    red, us = timed(sim)
    rows.append(Row(
        "roofline_endpoints/disco_tail_ttft_reduction_edge_tpu", us,
        f"{red:.1f}% — an edge-TPU device wins every race (94k tok/s prefill"
        " >> server queue floor), so both policies saturate at device TTFT",
    ))
    red100, us = timed(sim, 100.0)
    rows.append(Row(
        "roofline_endpoints/disco_tail_ttft_reduction_mobile_npu", us,
        f"{red100:.1f}% (device derated 100x to mobile-NPU class: the paper's"
        " racing trade-off reappears)",
    ))
    return rows
