"""Device-draft / server-verify speculative decoding, measured.

The race (§4.2) burns the loser's tokens; the draft/verify protocol turns
them into accepted ones: the device drafts k tokens per round, the server
scores all k+1 positions in ONE fused teacher-forced dispatch and accepts
a lossless prefix by rejection sampling (``min(1, p_server/p_device)`` +
residual resample). This bench measures the protocol itself, engine level
(no event loop):

* accepted-tokens-per-dispatch and acceptance rate at matched models —
  the headline: every server dispatch commits ~k+1 tokens instead of 1;
* acceptance rate vs the draft/verify temperature GAP — the device drafts
  at T_draft, the server verifies at T_verify; the overlap
  ``sum(min(p_s, p_d))`` (hence the accepted prefix) degrades smoothly as
  the distributions separate;
* per-committed-token latency (TBT) and unified cost vs plain server
  decode on the same request — verify positions are batch-scored
  (prefill-priced), not sequentially decoded;
* full-stack race-vs-speculative unified cost across uplink RTTs
  (``spec_cost_vs_race``): which strategy is cheaper depends on WHO wins
  the race — under the paper's device-favoured exchange rate the device
  wins, racing is near-free, and draft/verify's fixed overdraft overhead
  shows up as a >1 ratio that the sweep quantifies per RTT point.

Matched models + equal temperatures must be bit-identical to the plain
server-only stream with the same seed AND accept every draft — asserted
here, gated in CI via ``bench_e2e_serving --check-speculative``.

Emits ``BENCH_speculative.json`` at the repo root plus CSV rows for
``benchmarks/run.py``.

    PYTHONPATH=src python -m benchmarks.bench_speculative [--smoke]
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import paper_models
from repro.core import (
    CostModel,
    DiSCoScheduler,
    Endpoint,
    MigrationConfig,
)
from repro.models import init_params
from repro.serving import (
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    Request,
    SamplerConfig,
    ServerEndpoint,
)

from .common import Row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_speculative.json"

_MAX_LEN = 128
_MAX_NEW = 32
_PROMPT_LEN = 16
_K = 4
_T_VERIFY = 0.8
# draft-temperature sweep: gap 0 is the matched/lossless point; the rest
# separate the device distribution from the server's (sharper AND flatter)
_T_DRAFTS = (0.8, 0.5, 0.3, 1.2, 2.0)
_N_SEEDS = 4                 # acceptance averaged over request seeds
# unified-cost pricing (App. E.2 shape, same constants as bench_e2e_serving):
# verify positions are batch-scored like prefill, plain decode pays the
# sequential rate; the device pays its own (exchange-rated) decode price
_COST = CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12)


def _run_spec(srv: BatchedServer, dev: InferenceEngine, seed: int,
              t_draft: float, t_verify: float, k: int,
              max_new: int = _MAX_NEW):
    """One draft/verify request on the SHARED engines (jit caches stay warm
    across the sweep): device drafts at ``t_draft``, server verifies at
    ``t_verify``. Returns per-request protocol stats."""
    cfg = paper_models.TINY_SERVER
    samp_v = SamplerConfig(temperature=t_verify)
    samp_d = SamplerConfig(temperature=t_draft)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab, size=_PROMPT_LEN).astype(np.int32)

    rid = srv.submit(Request(prompt, max_new, seed=seed, sampler=samp_v),
                     verify=True)
    srv.run_until(srv.clock + 1e-9)            # admission prefill
    tok0 = srv.pop_events(rid)[0][0]

    st = dev.open_stream(Request(prompt, max_new, seed=seed, sampler=samp_d))
    st.draft_prefill()
    st.force_pending(tok0)

    got = [tok0]
    rounds = accepted = scored = 0
    draft_s = verify_s = 0.0
    while not srv.is_finished(rid):
        w = st.draft_window(k)
        if w is None:
            break
        drafts, dev_probs, dur = w
        draft_s += dur
        t0 = time.perf_counter()
        res = srv.verify_step(rid, drafts, dev_probs)
        verify_s += time.perf_counter() - t0
        if res is None:
            srv.end_verify(rid)
            srv.run_to_completion()
            got.extend(t for t, _ in srv.pop_events(rid))
            break
        st.draft_rewind(res["accepted"], res["tokens"][-1])
        got.extend(res["tokens"])
        rounds += 1
        accepted += res["accepted"]
        scored += res["k"]
        srv.pop_events(rid)
    st.cancel()
    return {
        "tokens": got,
        "rounds": rounds,
        "accepted": accepted,
        "scored": scored,
        "draft_s": draft_s,
        "verify_s": verify_s,
        "verify_positions": scored + rounds,   # k+1 per round
    }


_RTTS = (0.01, 0.05, 0.15)   # uplink RTT axis for the spec-vs-race economics
_RTT_N_REQ = 6


def _build_stack(params, mode: str, rtt: float, n_requests: int) -> DiSCoServer:
    """Full driver stack (device endpoint + batched server behind an uplink
    of ``rtt``) in ``race`` or ``speculative`` mode — the same matched-model
    configuration the CI speculative gate uses."""
    cfg = paper_models.TINY_SERVER
    server = BatchedServer(cfg, params, max_slots=2, max_len=_MAX_LEN,
                           decode_chunk=4,
                           speculative=(mode == "speculative"))
    server.warmup(prompt_lens=(16, 32))
    dev = InferenceEngine(cfg, params, max_len=_MAX_LEN, paged=True,
                          kv_rows=n_requests,
                          speculative=(mode == "speculative"))
    dev.warmup(prompt_lens=(16, 32))
    rng0 = np.random.default_rng(0)
    sched = DiSCoScheduler(
        _COST,
        server_ttft_samples=rng0.lognormal(np.log(0.3), 0.5, 400),
        prompt_length_samples=np.clip(
            rng0.lognormal(2.5, 0.8, 400), 1, 64).astype(int),
        budget=0.9,       # most requests race -> most take the spec path
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.01),
    )
    return DiSCoServer(
        sched, DeviceEndpoint(dev),
        ServerEndpoint(server, NetworkModel(rtt_mean=rtt, rtt_jitter=0.0)),
        rng=np.random.default_rng(7), mode=mode,
    )


def _rtt_sweep(params, rtts, n_requests: int) -> list[dict]:
    """Race-vs-speculative unified cost across uplink RTTs.

    The race pays the loser's wasted server tokens plus one cancel
    round-trip per win; draft/verify replaces the second stream with
    batch-scored verify dispatches but overdrafts ~k tokens past every
    accept boundary.  Which side wins depends on WHO wins the race: under
    the paper's device-favoured exchange rate the device wins, the
    server-side waste window is short (and SHRINKS with RTT — a slower
    uplink delays the server stream's start more than the cancel), so
    ``spec_cost_vs_race`` sits above 1 and the sweep records how far, per
    RTT point, alongside the TTFT price speculative pays as every verify
    round crosses the slower uplink.  The ratio is the regime marker, not
    a one-sided claim — a server-favoured deployment flips it."""
    cfg = paper_models.TINY_SERVER
    rng = np.random.default_rng(3)
    samp = SamplerConfig(temperature=_T_VERIFY)
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32)
               for n in rng.integers(8, 32, size=n_requests)]

    def fresh_reqs():
        return [Request(p, _MAX_NEW, arrival=0.1 * i, seed=50 + i,
                        sampler=samp)
                for i, p in enumerate(prompts)]

    points = []
    for rtt in rtts:
        spec = _build_stack(params, "speculative", rtt, n_requests)
        res_spec = spec.serve_many(fresh_reqs())
        stats = spec.stats()
        race = _build_stack(params, "race", rtt, n_requests)
        res_race = race.serve_many(fresh_reqs())

        cost_spec = float(np.mean([r.cost for r in res_spec]))
        cost_race = float(np.mean([r.cost for r in res_race]))
        waste = lambda rs: (sum(r.wasted_tokens for r in rs)
                            / max(sum(r.generated_tokens for r in rs), 1))
        points.append({
            "rtt_s": rtt,
            "spec_cost_vs_race": cost_spec / max(cost_race, 1e-12),
            "cost_mean_speculative": cost_spec,
            "cost_mean_race": cost_race,
            "wasted_ratio_speculative": waste(res_spec),
            "wasted_ratio_race": waste(res_race),
            "ttft_p50_speculative_s": float(np.percentile(
                [r.ttft for r in res_spec], 50)),
            "ttft_p50_race_s": float(np.percentile(
                [r.ttft for r in res_race], 50)),
            "spec_requests": spec.spec_requests,
            "acceptance_rate": stats.get("acceptance_rate", 0.0),
            "streams_identical": int(all(
                a.tokens == b.tokens for a, b in zip(res_spec, res_race))),
        })
    return points


def _server_only(srv: BatchedServer, seed: int, t_verify: float,
                 max_new: int = _MAX_NEW):
    """Same request decoded plainly on the SHARED baseline server: the
    stream the speculative run must be bit-identical to (matched models,
    equal temperatures) and the per-token cost/latency reference."""
    cfg = paper_models.TINY_SERVER
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab, size=_PROMPT_LEN).astype(np.int32)
    rid = srv.submit(Request(prompt, max_new,
                             seed=seed, sampler=SamplerConfig(temperature=t_verify)))
    t0 = time.perf_counter()
    tokens = srv.run_to_completion()[rid]
    wall = time.perf_counter() - t0
    return tokens, wall


def run(smoke: bool = False) -> list[Row]:
    cfg = paper_models.TINY_SERVER
    srv_params = init_params(cfg, jax.random.PRNGKey(1))
    seeds = range(100, 100 + (1 if smoke else _N_SEEDS))
    t_drafts = _T_DRAFTS[:2] if smoke else _T_DRAFTS
    max_new = 16 if smoke else _MAX_NEW

    # ONE stack for the whole sweep: jit caches stay warm, the warmup
    # compile cost is paid once and the sweep measures steady-state rounds
    srv = BatchedServer(cfg, srv_params, max_slots=2, max_len=_MAX_LEN,
                        decode_chunk=4, speculative=True)
    srv.warmup(prompt_len=_PROMPT_LEN)
    dev = InferenceEngine(cfg, srv_params, max_len=_MAX_LEN, paged=True,
                          speculative=True)
    dev.warmup(prompt_len=_PROMPT_LEN)
    base = BatchedServer(cfg, srv_params, max_slots=2, max_len=_MAX_LEN,
                         decode_chunk=4)
    base.warmup(prompt_len=_PROMPT_LEN)

    rows: list[Row] = []
    sweep = []
    matched = None
    for t_d in t_drafts:
        accepted = scored = rounds = 0
        tok_per_dispatch = []
        identical = 0
        draft_s = verify_s = 0.0
        delivered = 0
        for seed in seeds:
            r = _run_spec(srv, dev, seed, t_d, _T_VERIFY, _K,
                          max_new=max_new)
            ref, _ = _server_only(base, seed, _T_VERIFY, max_new=max_new)
            accepted += r["accepted"]
            scored += r["scored"]
            rounds += r["rounds"]
            draft_s += r["draft_s"]
            verify_s += r["verify_s"]
            delivered += len(r["tokens"])
            if r["rounds"]:
                tok_per_dispatch.append(
                    (r["accepted"] + r["rounds"]) / r["rounds"]
                )
            identical += int(r["tokens"] == ref)
        rate = accepted / max(scored, 1)
        point = {
            "t_draft": t_d,
            "t_verify": _T_VERIFY,
            "temperature_gap": abs(t_d - _T_VERIFY),
            "acceptance_rate": rate,
            "accepted_tokens_per_dispatch": float(np.mean(tok_per_dispatch))
            if tok_per_dispatch else 0.0,
            "rounds": rounds,
            "drafts_scored": scored,
            "accepted_draft_tokens": accepted,
            "streams_identical_to_server_only": identical,
            "n_requests": len(list(seeds)),
            "tbt_committed_s": (draft_s + verify_s) / max(delivered, 1),
        }
        sweep.append(point)
        if t_d == _T_VERIFY:
            matched = point
        rows.append(Row(
            f"speculative/gap{abs(t_d - _T_VERIFY):g}", 0.0,
            f"acceptance={rate:.3f};"
            f"tok_per_dispatch={point['accepted_tokens_per_dispatch']:.2f};"
            f"identical={identical}/{point['n_requests']}",
        ))

    assert matched is not None
    # matched models + equal temperatures: the lossless point
    assert matched["acceptance_rate"] > 0.5, (
        f"matched-model acceptance {matched['acceptance_rate']:.3f} <= 0.5"
    )
    assert (matched["streams_identical_to_server_only"]
            == matched["n_requests"]), (
        "matched-model speculative streams diverged from server-only"
    )

    # unified cost per committed token, speculative vs plain server decode:
    # verify positions are batch-scored (prefill-priced); the device pays
    # its exchange-rated decode price for every draft, accepted or not
    verify_positions = matched["drafts_scored"] + matched["rounds"]  # k+1/round
    spec_cost = (
        _COST.prefill_cost(Endpoint.SERVER) * verify_positions
        + _COST.decode_cost(Endpoint.DEVICE) * matched["drafts_scored"]
    )
    spec_delivered = matched["accepted_draft_tokens"] + matched["rounds"]
    base_cost_per_tok = _COST.decode_cost(Endpoint.SERVER)
    spec_cost_per_tok = spec_cost / max(spec_delivered, 1)
    headline = {
        "acceptance_rate_matched": matched["acceptance_rate"],
        "accepted_tokens_per_dispatch_matched":
            matched["accepted_tokens_per_dispatch"],
        "tbt_committed_s_matched": matched["tbt_committed_s"],
        "cost_per_token_speculative": spec_cost_per_tok,
        "cost_per_token_server_decode": base_cost_per_tok,
        "cost_reduction_vs_server_decode":
            1.0 - spec_cost_per_tok / base_cost_per_tok,
        "k": _K,
    }
    rows.append(Row(
        "speculative/headline", 0.0,
        f"acceptance={headline['acceptance_rate_matched']:.3f};"
        f"tok_per_dispatch="
        f"{headline['accepted_tokens_per_dispatch_matched']:.2f};"
        f"cost_reduction={headline['cost_reduction_vs_server_decode']:.2f}",
    ))

    # uplink-RTT axis: the full-stack race-vs-speculative economics
    rtts = _RTTS[1:2] if smoke else _RTTS
    rtt_sweep = _rtt_sweep(srv_params, rtts, 3 if smoke else _RTT_N_REQ)
    for p in rtt_sweep:
        rows.append(Row(
            f"speculative/rtt{p['rtt_s']:g}", 0.0,
            f"spec_cost_vs_race={p['spec_cost_vs_race']:.3f};"
            f"waste_race={p['wasted_ratio_race']:.3f};"
            f"waste_spec={p['wasted_ratio_speculative']:.3f};"
            f"identical={p['streams_identical']}",
        ))
    headline["spec_cost_vs_race"] = {
        str(p["rtt_s"]): p["spec_cost_vs_race"] for p in rtt_sweep
    }

    if not smoke:
        _JSON_PATH.write_text(json.dumps({
            "bench": "speculative",
            "model": cfg.name,
            "k": _K,
            "max_new": _MAX_NEW,
            "prompt_len": _PROMPT_LEN,
            "n_seeds": _N_SEEDS,
            "temperature_sweep": sweep,
            "rtt_sweep": rtt_sweep,
            "headline": headline,
        }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one seed, two temperature points, no JSON emission")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)
