"""Paged-decode trajectory: paged vs dense decode µs/token at large batch.

ROADMAP flags the missing decode trajectory for the *paged* hot path: the
prefill bench covers admission and ``BENCH_decode.json`` covers the dense
fused scan, but nothing tracked what the block-pool indirection costs per
decoded token as the batch grows. This bench times the two fused
multi-token decode dispatches the serving stack actually runs:

* ``dense`` — ``decode_n`` over the head-major ``(L, B, K, max_len, D)``
  cache with the max_len/active row guard (the ``BatchedServer`` dense
  tick).
* ``paged`` — ``paged_decode_n`` over the shared ``(L, N, K, bs, D)``
  block pool through per-row page tables (the paged tick; XLA gather
  reference on CPU — on TPU the Pallas kernel turns the table into a DMA
  index map instead of materializing the gather).

Both decode a full chunk per dispatch; µs/token divides the median chunk
wall-clock by chunk * batch. Emits ``BENCH_paged_decode.json`` at the repo
root — the paged-decode perf trajectory — plus CSV rows.

    PYTHONPATH=src python -m benchmarks.bench_paged_decode [--smoke]
"""
from __future__ import annotations

import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_models
from repro.models import (
    decode_n,
    init_paged_pages,
    init_params,
    paged_decode_n,
    prefill,
)

from .common import Row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_paged_decode.json"

_MAX_LEN = 256
_BLOCK_SIZE = 16
_CHUNK = 8
_POINTS = ((4, 64), (8, 64), (8, 128), (16, 64))   # (batch, context)
_REPS = 5


def _prefill_states(cfg, params, batch: int, ctx: int):
    """Build matching dense + paged decode states holding a real ``ctx``-token
    prefix per row (same prompts, so both paths decode identical content)."""
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(batch, ctx)).astype(np.int32)
    logits, cache = jax.jit(
        lambda p, t: prefill(p, cfg, t, _MAX_LEN)
    )(params, jnp.asarray(prompts))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    mb = _MAX_LEN // _BLOCK_SIZE
    num_blocks = batch * mb + 1                     # block 0 = trash
    pages = init_paged_pages(cfg, num_blocks, _BLOCK_SIZE)
    # every row owns a full contiguous table up front: the bench times the
    # decode dispatch, not the allocator (kv_pool owns that host-side)
    tables = np.arange(1, num_blocks, dtype=np.int32).reshape(batch, mb)
    nb = ctx // _BLOCK_SIZE
    new_pages = dict(pages)
    for key in ("k", "v"):
        arr = cache[key]                            # (L, B, K, max_len, D)
        l, b, kh, _, d = arr.shape
        blocks = (
            arr[:, :, :, :ctx]
            .reshape(l, b, kh, nb, _BLOCK_SIZE, d)
            .transpose(0, 1, 3, 2, 4, 5)
            .reshape(l, b * nb, kh, _BLOCK_SIZE, d)
        )
        ids = tables[:, :nb].reshape(-1)
        new_pages[key] = pages[key].at[:, ids].set(blocks)
    return cache, new_pages, jnp.asarray(tables), tok


def _median_chunk_us(step, state, tok, reps: int = _REPS):
    """Median wall-clock of one fused chunk; the donated state threads
    through so every rep decodes a fresh chunk (lengths advance)."""
    times = []
    for i in range(reps + 1):
        t0 = time.perf_counter()
        toks, state = step(state, tok)
        jax.block_until_ready(toks)
        if i:                                       # rep 0 re-warms
            times.append(time.perf_counter() - t0)
        tok = toks[-1]
    return float(np.median(times) * 1e6), state


def run(smoke: bool = False) -> list[Row]:
    cfg = paper_models.TINY_SERVER
    params = init_params(cfg, jax.random.PRNGKey(0))
    points = _POINTS[:1] if smoke else _POINTS

    rows: list[Row] = []
    out_points = []
    for batch, ctx in points:
        cache, pages, tables, tok = _prefill_states(cfg, params, batch, ctx)
        active = jnp.ones((batch,), bool)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def dense_step(cache, tok, active=active):
            toks, cache = decode_n(
                params, cfg, cache, tok, _CHUNK, max_len=_MAX_LEN, active=active
            )
            return toks, cache

        @functools.partial(jax.jit, donate_argnums=(0,))
        def paged_step(pages, lengths, tok, tables=tables, active=active):
            return paged_decode_n(
                params, cfg, pages, tables, lengths, tok,
                _CHUNK, max_len=_MAX_LEN, active=active,
            )

        def paged_rep(state, tok):
            pages, lengths = state
            # lengths thread through across reps: honest cache growth
            toks, pages, lengths = paged_step(pages, lengths, tok)
            return toks, (pages, lengths)

        dense_us, cache = _median_chunk_us(dense_step, cache, tok)
        paged_us, _ = _median_chunk_us(
            paged_rep, (pages, jnp.full((batch,), ctx, jnp.int32)), tok
        )
        tokens = _CHUNK * batch
        point = {
            "batch": batch,
            "context": ctx,
            "chunk": _CHUNK,
            "dense_us_per_token": dense_us / tokens,
            "paged_us_per_token": paged_us / tokens,
            "dense_tokens_per_s": tokens / (dense_us * 1e-6),
            "paged_tokens_per_s": tokens / (paged_us * 1e-6),
            "paged_vs_dense": dense_us / paged_us,
        }
        out_points.append(point)
        rows.append(Row(
            f"paged_decode/b{batch}_ctx{ctx}/dense", dense_us / tokens,
            f"tokens_per_s={point['dense_tokens_per_s']:.0f}",
        ))
        rows.append(Row(
            f"paged_decode/b{batch}_ctx{ctx}/paged", paged_us / tokens,
            f"tokens_per_s={point['paged_tokens_per_s']:.0f};"
            f"vs_dense={point['paged_vs_dense']:.2f}",
        ))

    ratios = np.array([p["paged_vs_dense"] for p in out_points])
    headline = {
        "geomean_paged_vs_dense": float(np.exp(np.log(ratios).mean())),
        "min_paged_vs_dense": float(ratios.min()),
    }
    rows.append(Row(
        "paged_decode/headline", 0.0,
        f"geomean_paged_vs_dense={headline['geomean_paged_vs_dense']:.2f}",
    ))
    if not smoke:
        _JSON_PATH.write_text(json.dumps({
            "bench": "paged_decode",
            "model": cfg.name,
            "max_len": _MAX_LEN,
            "block_size": _BLOCK_SIZE,
            "decode_chunk": _CHUNK,
            "kernel": "xla_gather_reference",   # TPU runs flip to Pallas DMA
            "points": out_points,
            "headline": headline,
        }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single point, no JSON emission")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)
