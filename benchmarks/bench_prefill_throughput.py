"""Prefill-throughput trajectory: paged vs dense cache-construction paths.

Measures prefill tokens/s at several (batch, prompt-length) points for the
two cache write paths the serving stack can take:

* ``dense`` — one batched prefill dispatch writing a dense head-major
  ``(L, B, K, max_len, D)`` cache (the per-slot reservation the paged pool
  replaces).
* ``paged`` — per-row prefills scattering K/V into pool blocks through the
  block allocator (admit -> scatter -> release), exactly the admission path
  ``BatchedServer`` runs per request. Rows dispatch one at a time because
  that is how continuous batching admits them (no global barrier).
* ``shared`` — the same per-row admission path with the radix prefix cache
  ON and all rows sharing a common prompt prefix: after the first (cold)
  pass each admission maps the matched sealed blocks by refcount bump and
  ``paged_suffix_prefill`` computes only the unmatched tail. Per point the
  bench reports ``prefix_hit_rate``, ``blocks_saved``, and the
  prefill-tokens-computed-per-admitted-token ratio.

The paged path pays a per-row dispatch and the block scatter but only
allocates the blocks the prompt needs; the dense path amortizes one big
dispatch but reserves ``max_len`` per row. Emits ``BENCH_prefill.json`` at
the repo root — the prefill-throughput perf trajectory — plus CSV rows.

    PYTHONPATH=src python -m benchmarks.bench_prefill_throughput [--smoke]
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import paper_models
from repro.models import init_params
from repro.serving import InferenceEngine

from .common import Row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_prefill.json"

_MAX_LEN = 256
_BLOCK_SIZE = 16
_POINTS = ((1, 64), (4, 64), (1, 128), (4, 128), (8, 64))
_REPS = 5


def _median_us(fn, reps: int = _REPS) -> float:
    fn()                                   # one extra warm call
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def run(smoke: bool = False) -> list[Row]:
    cfg = paper_models.TINY_SERVER
    params = init_params(cfg, jax.random.PRNGKey(0))
    points = _POINTS[:1] if smoke else _POINTS
    max_batch = max(b for b, _ in points)

    dense = InferenceEngine(cfg, params, max_len=_MAX_LEN)
    paged = InferenceEngine(
        cfg, params, max_len=_MAX_LEN, paged=True,
        block_size=_BLOCK_SIZE, kv_rows=max_batch,
    )
    shared = InferenceEngine(
        cfg, params, max_len=_MAX_LEN, paged=True,
        block_size=_BLOCK_SIZE, kv_rows=max_batch, prefix_cache=True,
    )
    lengths = sorted({length for _, length in points})
    dense.warmup(batch=1, prompt_lens=tuple(lengths))
    for b in sorted({b for b, _ in points}):
        if b > 1:
            dense.warmup(batch=b, prompt_lens=tuple(lengths))
    paged.warmup(prompt_lens=tuple(lengths))
    shared.warmup(prompt_lens=tuple(lengths))

    rng = np.random.default_rng(0)
    rows: list[Row] = []
    out_points = []
    for batch, length in points:
        prompts = rng.integers(0, cfg.vocab, size=(batch, length)).astype(np.int32)

        def run_dense():
            tok, _ = dense.prefill(prompts)
            return tok

        # all rows share the longest sealed-block prefix (the cap leaves one
        # block of tail so the last real position is always computed)
        n_shared = (length - 1) // _BLOCK_SIZE * _BLOCK_SIZE
        shared_prompts = prompts.copy()
        shared_prompts[:, :n_shared] = shared_prompts[0, :n_shared]

        def run_paged():
            # the continuous-batching admission path: per-row admit+scatter,
            # blocks released after timing (steady-state pool)
            for i in range(batch):
                rid = paged._next_rid
                paged._next_rid += 1
                paged._paged_admit_prefill(rid, prompts[i])
            for rid in list(paged.kv.tables):
                paged.kv.release(rid)

        def run_shared():
            # same path, prefix cache ON: release-with-registration seals
            # the row's blocks into the radix index, so after the cold first
            # pass every admission is a hit and only the tail is computed
            rids = []
            for i in range(batch):
                rid = shared._next_rid
                shared._next_rid += 1
                shared._paged_admit_prefill(rid, shared_prompts[i])
                rids.append((rid, shared_prompts[i]))
            for rid, toks in rids:
                shared.kv.release(rid, cache_tokens=toks)

        dense_us = _median_us(run_dense)
        paged_us = _median_us(run_paged)
        q0, h0 = shared.kv.prefix_queries, shared.kv.prefix_hits
        s0, c0 = shared.kv.blocks_saved, shared.kv.prefix_tokens_hit
        shared_us = _median_us(run_shared)
        dq = max(shared.kv.prefix_queries - q0, 1)
        tokens = batch * length
        admitted = (_REPS + 1) * tokens
        computed = admitted - (shared.kv.prefix_tokens_hit - c0)
        point = {
            "batch": batch,
            "length": length,
            "dense_us": dense_us,
            "paged_us": paged_us,
            "shared_us": shared_us,
            "dense_tokens_per_s": tokens / (dense_us * 1e-6),
            "paged_tokens_per_s": tokens / (paged_us * 1e-6),
            "shared_tokens_per_s": tokens / (shared_us * 1e-6),
            "paged_vs_dense": dense_us / paged_us,
            "shared_vs_paged": paged_us / shared_us,
            "prefix_hit_rate": (shared.kv.prefix_hits - h0) / dq,
            "blocks_saved": int(shared.kv.blocks_saved - s0),
            "prefill_compute_per_admitted_token": computed / admitted,
            "paged_blocks_per_row": paged.kv.prefill_demand(length, length),
            "dense_reserved_tokens_per_row": _MAX_LEN,
        }
        shared.kv.flush_prefix_cache()       # points stay independent
        out_points.append(point)
        rows.append(Row(
            f"prefill/b{batch}_s{length}/dense", dense_us,
            f"tokens_per_s={point['dense_tokens_per_s']:.0f}",
        ))
        rows.append(Row(
            f"prefill/b{batch}_s{length}/paged", paged_us,
            f"tokens_per_s={point['paged_tokens_per_s']:.0f};"
            f"vs_dense={point['paged_vs_dense']:.2f}",
        ))
        rows.append(Row(
            f"prefill/b{batch}_s{length}/shared_prefix", shared_us,
            f"tokens_per_s={point['shared_tokens_per_s']:.0f};"
            f"vs_paged={point['shared_vs_paged']:.2f};"
            f"hit_rate={point['prefix_hit_rate']:.2f};"
            f"blocks_saved={point['blocks_saved']};"
            f"compute_per_tok={point['prefill_compute_per_admitted_token']:.2f}",
        ))

    ratios = np.array([p["paged_vs_dense"] for p in out_points])
    shared_ratios = np.array([p["shared_vs_paged"] for p in out_points])
    headline = {
        "geomean_paged_vs_dense": float(np.exp(np.log(ratios).mean())),
        "min_paged_vs_dense": float(ratios.min()),
        "geomean_shared_vs_paged": float(np.exp(np.log(shared_ratios).mean())),
        "prefix_hit_rate": float(np.mean(
            [p["prefix_hit_rate"] for p in out_points]
        )),
        "prefill_compute_per_admitted_token": float(np.mean(
            [p["prefill_compute_per_admitted_token"] for p in out_points]
        )),
    }
    rows.append(Row(
        "prefill/headline", 0.0,
        f"geomean_paged_vs_dense={headline['geomean_paged_vs_dense']:.2f};"
        f"geomean_shared_vs_paged={headline['geomean_shared_vs_paged']:.2f}",
    ))
    if not smoke:
        _JSON_PATH.write_text(json.dumps({
            "bench": "prefill_throughput",
            "model": cfg.name,
            "max_len": _MAX_LEN,
            "block_size": _BLOCK_SIZE,
            "points": out_points,
            "headline": headline,
        }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single point, no JSON emission")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv(), flush=True)
