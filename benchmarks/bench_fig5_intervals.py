"""Figure 5: robustness to real-world arrival patterns (DiffusionDB-like
bursty per-user traces instead of Poisson): DiSCo's mean-TTFT advantage must
persist across user activity levels.
"""
from __future__ import annotations

import numpy as np

from repro.core import Endpoint, LengthDistribution, StochasticPolicy, make_policy, simulate_ttft
from repro.sim import (
    DEVICE_PROFILES,
    build_cost_model,
    bursty_arrivals,
    make_server_model,
    sample_prompt_lengths,
)

from .common import Row, pct_reduction, timed

N_REQ = 2000


def run() -> list[Row]:
    rows = []
    device = DEVICE_PROFILES["pixel7pro-bloom560m"]
    for trace in ("gpt", "command"):
        def sweep():
            rng = np.random.default_rng(0)
            server = make_server_model(trace, rng)
            # arrivals don't change per-request TTFT in the trace-driven model,
            # but they change the *observed stream* the online profiler sees;
            # we sample lengths per burst to mimic user sessions
            arr = bursty_arrivals(rng, N_REQ)
            lengths = sample_prompt_lengths(rng, N_REQ)
            ld = LengthDistribution.from_samples(lengths)
            cm = build_cost_model(trace, "pixel7pro-bloom560m", "server")
            reds = []
            for b in (0.2, 0.5, 0.8):
                disco = make_policy(cm, server.ttft, ld, b)
                stoch = StochasticPolicy(Endpoint.SERVER, b, seed=1)
                m_d = simulate_ttft(lengths, disco, server, device,
                                    np.random.default_rng(2))["ttft"].mean()
                m_s = simulate_ttft(lengths, stoch, server, device,
                                    np.random.default_rng(2))["ttft"].mean()
                reds.append(pct_reduction(m_s, m_d))
            return float(np.mean(reds))
        red, us = timed(sweep)
        rows.append(Row(f"fig5/bursty_{trace}", us,
                        f"mean_ttft_reduction={red:.1f}% (persists under bursty arrivals)"))
    return rows
