"""Table 3: migration impact on token delivery — number of delayed tokens
per migrated request and P99 TBT.

Paper: 3-17 delayed tokens on average; P99 TBT 0.209/0.217 s at r_c≈4.8.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Endpoint,
    MigrationConfig,
    SingleEndpointPolicy,
    simulate_full,
    summarize,
)
from repro.sim import build_cost_model, make_requests, make_server_model, DEVICE_PROFILES

from .common import Row, timed

N_REQ = 150
DEVICE = "xiaomi14-qwen05b"


def run() -> list[Row]:
    rows = []
    for trace in ("gpt", "llama", "deepseek", "command"):
        for constraint in ("server", "device"):
            def cell():
                rng = np.random.default_rng(0)
                server = make_server_model(trace, rng)
                device = DEVICE_PROFILES[DEVICE]
                cm = build_cost_model(trace, DEVICE, constraint)
                # start on the *constrained* endpoint so migration triggers
                start = (
                    Endpoint.SERVER if constraint == "server" else Endpoint.DEVICE
                )
                reqs = make_requests(np.random.default_rng(1), N_REQ)
                res = simulate_full(
                    reqs, SingleEndpointPolicy(start), cm, server, device,
                    np.random.default_rng(2),
                    # Table 3 reports the freeze-at-handoff regime (the
                    # sequence the target replays is fixed): delays appear
                    # when the t_m estimate undershoots (see MigrationConfig)
                    migration=MigrationConfig(source_continues=False),
                )
                s = summarize(res)
                migrated = [r for r in res if r.migrated]
                stalls = [r.delayed_tokens for r in migrated]
                deferred = [r.deferred_tokens for r in migrated]
                return (
                    s.migration_rate,
                    float(np.mean(deferred)) if deferred else 0.0,
                    float(np.percentile(deferred, 99)) if deferred else 0.0,
                    float(np.mean(stalls)) if stalls else 0.0,
                    s.p99_tbt,
                )
            (mrate, dmean, dp99, stall, tbt99), us = timed(cell)
            rows.append(Row(
                f"table3/{trace}_{constraint}", us,
                f"mean_delay_num={dmean:.2f};p99_delay_num={dp99:.2f}"
                f";stalled={stall:.2f};tbt_p99={tbt99:.3f}s;migration_rate={mrate:.2f}",
            ))
    return rows
