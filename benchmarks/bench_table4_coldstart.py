"""Appendix B / Table 4: cold starts can dominate device TTFT.

Paper (Qwen-2.5, RTX3060/A40): load time 1.29-13.43 s vs prefill TTFT
0.025-0.145 s — a cold model pays 10-500x its warm TTFT. We reproduce the
structural claim and measure the dispatch-policy consequence: with cold
starts, the device-side race loses value and DiSCo's server-budget policy
keeps the tail flat while all-device TTFT degrades sharply.
"""
from __future__ import annotations

import numpy as np

from repro.core import Endpoint, LengthDistribution, SingleEndpointPolicy, make_policy
from repro.core.simulator import DeviceModel
from repro.sim import build_cost_model, make_server_model, sample_prompt_lengths

from .common import Row, timed

# paper Table 4 (Qwen-2.5 load-time anchors, seconds)
PAPER_LOADS = {"0.5B@3060": 1.29, "3B@3060": 4.45, "7B@A40": 13.43}


def run() -> list[Row]:
    rows = []
    for label, load_s in PAPER_LOADS.items():
        warm = DeviceModel(prefill_rate=79.9, decode_rate=21.5)
        cold = DeviceModel(prefill_rate=79.9, decode_rate=21.5,
                           cold_start_s=load_s, cold_prob=0.2)
        ratio = (load_s + 64 / 79.9) / (64 / 79.9)
        rows.append(Row(
            f"table4/coldstart_{label}", 0.0,
            f"load={load_s:.2f}s;cold/warm_ttft_ratio={ratio:.0f}x@64tok",
        ))

    def policy_effect():
        rng = np.random.default_rng(0)
        server = make_server_model("gpt", rng)
        lengths = sample_prompt_lengths(rng, 2000)
        ld = LengthDistribution.from_samples(lengths)
        cm = build_cost_model("gpt", "xiaomi14-qwen05b", "server")
        disco = make_policy(cm, server.ttft, ld, 0.5)
        alldev = SingleEndpointPolicy(Endpoint.DEVICE)
        out = {}
        for tag, prob in (("warm", 0.0), ("cold20", 0.2)):
            dev = DeviceModel(prefill_rate=79.9, decode_rate=21.5,
                              cold_start_s=4.45, cold_prob=prob)
            # inject cold starts into the race by sampling device TTFT with rng
            r = np.random.default_rng(1)
            d_ttft = dev.ttft(lengths, r)
            s_ttft = server.sample_ttft(np.random.default_rng(2), lengths.size)
            race, solo = [], []
            for i, l in enumerate(lengths):
                dec = disco.decide(int(l))
                t_s = s_ttft[i] if dec.use_server else np.inf
                race.append(min(t_s, d_ttft[i]))
                solo.append(d_ttft[i])
            out[tag] = (np.percentile(race, 99), np.percentile(solo, 99))
        return out
    out, us = timed(policy_effect)
    (d_w, s_w), (d_c, s_c) = out["warm"], out["cold20"]
    rows.append(Row(
        "table4/policy_under_coldstart", us,
        f"p99_disco warm={d_w:.2f}s cold20%={d_c:.2f}s; "
        f"p99_alldevice warm={s_w:.2f}s cold20%={s_c:.2f}s "
        "(racing absorbs cold starts; device-only does not)",
    ))
    return rows
