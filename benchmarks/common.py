"""Shared benchmark helpers: timing + row emission.

Every bench module exposes ``run() -> list[Row]``; ``benchmarks/run.py``
prints one CSV line per row: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, repeats: int = 1, **kwargs):
    """Returns (result_of_last_call, microseconds_per_call)."""
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def pct_reduction(base: float, new: float) -> float:
    return 100.0 * (base - new) / max(base, 1e-12)
