"""hubert-xlarge [audio] — encoder-only transformer backbone: 48L,
d_model 1280, 16 heads (kv=16), d_ff 5120, vocab 504 (k-means cluster codes
for masked prediction). The conv/mel frontend is a STUB — ``input_specs``
provides precomputed frame embeddings of shape (batch, frames, d_model).
Bidirectional attention; no decode phase. [arXiv:2106.07447]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    vocab=504,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    act="gelu",
    is_encoder=True,
    embed_inputs=False,   # frontend embeddings come in directly
    num_microbatches=2,
)

SMOKE = ModelConfig(
    name="hubert-smoke",
    family="audio",
    n_layers=2,
    d_model=128,
    vocab=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    act="gelu",
    is_encoder=True,
    embed_inputs=False,
    remat=False,
)
