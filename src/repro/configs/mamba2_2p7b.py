"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality): 64L,
d_model 2560, vocab 50280, d_state 128, expand 2 (d_inner 5120, 80 heads of
dim 64). [arXiv:2405.21060]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    vocab=50280,
    n_heads=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=128,
    num_microbatches=1,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=0,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=16,
    remat=False,
)
