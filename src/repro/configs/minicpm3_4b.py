"""minicpm3-4b [dense, MLA] — 62L, d_model 2560, 40 heads, d_ff 6400,
vocab 73448, multi-head latent attention (q_lora 768, kv_lora 256,
qk_nope 64, qk_rope 32, v_head 64). The MLA-compressed KV cache makes this
the smallest decode memory footprint among the dense archs.
[hf:openbmb/MiniCPM3-4B]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    vocab=73448,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    act="swiglu",
    use_mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    num_microbatches=2,
)

SMOKE = ModelConfig(
    name="minicpm3-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    act="swiglu",
    use_mla=True,
    q_lora_rank=48,
    kv_lora_rank=32,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
    remat=False,
)
