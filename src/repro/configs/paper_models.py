"""The paper's own endpoint models (§5.1 / App. E.1), as runnable configs.

FULL configs mirror the paper's stated hyperparameters (BLOOM-1.1B/560M,
Qwen1.5-0.5B — all 24 layers; see App. E.1). TINY variants are CPU-runnable
models used by the end-to-end serving examples, where an actual small JAX
model plays the device endpoint and a larger one plays the server endpoint.
"""
from repro.models.config import ModelConfig

BLOOM_1B1 = ModelConfig(
    name="bloom-1.1b", family="dense", n_layers=24, d_model=1024, vocab=250880,
    n_heads=16, n_kv_heads=16, d_ff=4096, act="gelu",
)
BLOOM_560M = ModelConfig(
    name="bloom-560m", family="dense", n_layers=24, d_model=512, vocab=250880,
    n_heads=8, n_kv_heads=8, d_ff=2048, act="gelu",
)
QWEN_05B = ModelConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=768, vocab=151936,
    n_heads=12, n_kv_heads=12, d_ff=2048, act="swiglu",
)

# CPU-runnable stand-ins for the serving examples (device = small, server = big)
TINY_DEVICE = ModelConfig(
    name="tiny-device", family="dense", n_layers=2, d_model=128, vocab=1024,
    n_heads=4, n_kv_heads=2, d_ff=256, act="swiglu", remat=False,
)
TINY_SERVER = ModelConfig(
    name="tiny-server", family="dense", n_layers=4, d_model=256, vocab=1024,
    n_heads=8, n_kv_heads=4, d_ff=512, act="swiglu", remat=False,
)
