"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

from repro.models.config import ModelConfig

from . import (
    arctic_480b,
    chameleon_34b,
    codeqwen1p5_7b,
    gemma3_1b,
    hubert_xlarge,
    hymba_1p5b,
    mamba2_2p7b,
    minicpm3_4b,
    nemotron_4_340b,
    olmoe_1b_7b,
    paper_models,
)
from .shapes import INPUT_SHAPES, InputShape, input_specs, shape_supported

_MODULES = {
    "arctic-480b": arctic_480b,
    "chameleon-34b": chameleon_34b,
    "gemma3-1b": gemma3_1b,
    "mamba2-2.7b": mamba2_2p7b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "hubert-xlarge": hubert_xlarge,
    "nemotron-4-340b": nemotron_4_340b,
    "minicpm3-4b": minicpm3_4b,
    "codeqwen1.5-7b": codeqwen1p5_7b,
    "hymba-1.5b": hymba_1p5b,
}

ARCH_IDS: tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.FULL


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "InputShape",
    "get_config",
    "input_specs",
    "shape_supported",
    "paper_models",
]
