"""arctic-480b [moe] — Snowflake Arctic base: 35L, d_model 7168, 56 heads
(GQA kv=8), per-expert d_ff 4864, vocab 32000, MoE 128 experts top-2 with a
dense FFN residual branch. [hf:Snowflake/snowflake-arctic-base]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    vocab=32000,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    act="swiglu",
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    num_microbatches=8,
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=8,
    n_kv_heads=2,
    d_ff=96,
    act="swiglu",
    n_experts=4,
    experts_per_token=2,
    moe_dense_residual=True,
    capacity_factor=2.0,  # = E/k: drop-free for exact decode/forward parity
    remat=False,
)
