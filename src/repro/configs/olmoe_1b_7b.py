"""olmoe-1b-7b [moe] — 16L, d_model 2048, 16 heads (kv=16), per-expert
d_ff 1024, vocab 50304, 64 experts top-8 (1B active / 7B total).
[arXiv:2409.02060]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    vocab=50304,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    act="swiglu",
    n_experts=64,
    experts_per_token=8,
    num_microbatches=2,
)

SMOKE = ModelConfig(
    name="olmoe-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    act="swiglu",
    n_experts=4,
    experts_per_token=2,
    capacity_factor=2.0,  # = E/k: drop-free for exact decode/forward parity
    remat=False,
)
