"""chameleon-34b [vlm] — early-fusion mixed-modal decoder: 48L, d_model 8192,
64 heads (GQA kv=8), d_ff 22016, vocab 65536 (text + VQ image codes in one
codebook — image tokens are ordinary ids, so the frontend "stub" is simply
token ids from the extended vocab). QK-norm per the Chameleon recipe.
[arXiv:2405.09818]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    vocab=65536,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    act="swiglu",
    qk_norm=True,
    num_microbatches=8,
)

SMOKE = ModelConfig(
    name="chameleon-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    act="swiglu",
    qk_norm=True,
    remat=False,
)
