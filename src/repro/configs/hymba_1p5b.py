"""hymba-1.5b [hybrid] — 32L, d_model 1600, 25 attention heads (GQA kv=5,
head_dim 64) in PARALLEL with Mamba(SSD) heads in every layer, d_ff 5504,
vocab 32001, ssm_state 16. Attention uses a sliding window (Hymba keeps a
few global layers; we window all attention heads — the SSM path carries
global context — noted as a TPU-adaptation in DESIGN.md). [arXiv:2411.13676]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    vocab=32001,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    act="swiglu",
    hybrid=True,
    attention="window",
    window=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=128,
    num_microbatches=1,
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=5,
    n_kv_heads=1,
    head_dim=16,
    d_ff=256,
    act="swiglu",
    hybrid=True,
    attention="window",
    window=8,
    ssm_state=8,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=16,
    remat=False,
)
