"""codeqwen1.5-7b [dense] — 32L, d_model 4096, 32 heads (kv=32), d_ff 13440,
vocab 92416 (Qwen1.5 architecture). This is the paper's "7B-class on-device"
regime (§2.3: an iPhone running a 7B LLM lasts < 2 h).
[hf:Qwen/CodeQwen1.5-7B]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    vocab=92416,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    act="swiglu",
    num_microbatches=2,
)

SMOKE = ModelConfig(
    name="codeqwen-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    act="swiglu",
    remat=False,
)
