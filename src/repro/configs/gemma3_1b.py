"""gemma3-1b [dense] — 26L, d_model 1152, 4 heads (GQA kv=1), d_ff 6912,
vocab 262144; 5:1 local:global attention pattern (sliding window 512 on local
layers), 128k+ context. [hf:google/gemma-3-1b-pt]

This is the canonical *device endpoint* for DiSCo serving examples.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    vocab=262144,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    act="swiglu",
    attention="pattern",
    window=512,
    global_interval=6,   # layers 6,12,18,24 are global (5 local : 1 global)
    rope_theta=1_000_000.0,
    num_microbatches=1,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=1,
    d_ff=256,
    act="swiglu",
    attention="pattern",
    window=8,
    global_interval=2,
    remat=False,
)
