"""nemotron-4-340b [dense] — 96L, d_model 18432, 96 heads (GQA kv=8),
d_ff 73728, vocab 256000, squared-ReLU MLP. The largest dense config;
exercises 340B-parameter sharding + Adafactor training states.
[arXiv:2402.16819]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    vocab=256000,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    act="squared_relu",
    num_microbatches=16,
)

SMOKE = ModelConfig(
    name="nemotron-smoke",
    family="dense",
    n_layers=2,
    d_model=192,
    vocab=512,
    n_heads=8,
    n_kv_heads=2,
    d_ff=768,
    act="squared_relu",
    remat=False,
)
