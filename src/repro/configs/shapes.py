"""The four assigned input shapes + per-(arch,shape) applicability rules and
ShapeDtypeStruct input specs for the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import init_cache

__all__ = ["InputShape", "INPUT_SHAPES", "shape_supported", "input_specs"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(supported, reason-if-not). Skips are documented in DESIGN.md."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False, f"{cfg.name} is encoder-only: no autoregressive decode"
    if shape.name == "long_500k":
        subquadratic = cfg.has_ssm or cfg.attention in ("window", "pattern")
        if not subquadratic:
            return False, (
                f"{cfg.name} is pure full-attention; long_500k requires "
                "sub-quadratic attention (SSM/hybrid/sliding-window)"
            )
    return True, ""


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the step function
    selected by ``shape.kind`` (weak-type-correct, shardable, no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    f = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    S = jax.ShapeDtypeStruct

    def token_inputs():
        if cfg.embed_inputs:
            return S((b, s), i32)
        return S((b, s, cfg.d_model), f)  # audio/VLM frontend embeddings (stub)

    if shape.kind == "train":
        specs = {"inputs": token_inputs(), "targets": S((b, s), i32)}
        if cfg.is_encoder:
            specs["loss_mask"] = S((b, s), jnp.bool_)  # HuBERT masked prediction
        return specs
    if shape.kind == "prefill":
        return {"inputs": token_inputs()}
    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
        # decode starts from a full cache: lengths = seq_len (the dry-run
        # measures one new token against a KV/state of `seq_len` context)
        return {"token": S((b,), i32), "cache": cache}
    raise ValueError(shape.kind)
