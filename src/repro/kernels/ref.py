"""Pure-jnp oracles for every Pallas kernel (independent, naive math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["mha_reference", "decode_reference", "ssd_reference"]

NEG_INF = -1e30


def mha_reference(
    q: jnp.ndarray,   # (B, Sq, H, D)
    k: jnp.ndarray,   # (B, Sk, K, D)
    v: jnp.ndarray,   # (B, Sk, K, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    rep = h // kh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(float(d))
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    diff = qpos[:, None] - kpos[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    logits = jnp.where(ok[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_reference(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, S, K, D)
    v_cache: jnp.ndarray,  # (B, S, K, D)
    lengths: jnp.ndarray,  # (B,)
    *,
    window: int = 0,
) -> jnp.ndarray:
    b, s, kh, d = k_cache.shape
    h = q.shape[1]
    rep = h // kh
    k = jnp.repeat(k_cache, rep, axis=2).astype(jnp.float32)
    v = jnp.repeat(v_cache, rep, axis=2).astype(jnp.float32)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), k) / jnp.sqrt(float(d))
    kpos = jnp.arange(s)[None, :]
    ok = kpos < lengths[:, None]
    if window > 0:
        ok &= (lengths[:, None] - 1 - kpos) < window
    logits = jnp.where(ok[:, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v).astype(q.dtype)


def ssd_reference(
    x: jnp.ndarray,    # (B, T, H, P)
    dt: jnp.ndarray,   # (B, T, H)
    A: jnp.ndarray,    # (H,)
    Bm: jnp.ndarray,   # (B, T, G, N)
    Cm: jnp.ndarray,   # (B, T, G, N)
    initial_state: jnp.ndarray | None = None,
):
    """Sequential (token-at-a-time) SSD recurrence — the ground truth.
    Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    b, t, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * Af[None, :])                  # (B, H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], bt
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    final, ys = jax.lax.scan(
        step,
        init,
        (
            xf.transpose(1, 0, 2, 3),
            dtf.transpose(1, 0, 2),
            Bh.transpose(1, 0, 2, 3),
            Ch.transpose(1, 0, 2, 3),
        ),
    )
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
