"""Version-tolerant shims over the Pallas/TPU API surface.

The Pallas TPU names moved across jax releases (``TPUCompilerParams`` →
``CompilerParams``); the kernels route through these helpers so they lower
on whichever jax the container ships.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

__all__ = ["on_tpu", "tpu_compiler_params"]


def on_tpu() -> bool:
    """True when the default jax backend is a real TPU (kernels lower
    natively); False on CPU/GPU where Pallas-TPU must run interpreted."""
    return jax.default_backend() == "tpu"


def tpu_compiler_params(**kwargs):
    """Construct TPU compiler params under either API name."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
