from .ops import decode_attention_op, flash_prefill_op, on_tpu, ssd_scan_op

__all__ = ["decode_attention_op", "flash_prefill_op", "on_tpu", "ssd_scan_op"]
