from .ops import (
    decode_attention_op,
    flash_prefill_op,
    on_tpu,
    paged_decode_attention_op,
    ssd_scan_op,
)

__all__ = [
    "decode_attention_op", "flash_prefill_op", "on_tpu",
    "paged_decode_attention_op", "ssd_scan_op",
]
