"""Jit'd wrappers around the Pallas kernels with automatic path selection.

``use_pallas`` semantics: on a real TPU the kernels lower natively; on this
CPU container they run via interpret=True (Python-level execution of the
kernel body — correct but slow, so only tests exercise them). The pure-jnp
paths in ``repro.models.attention`` / ``repro.models.ssm`` are the production
CPU/dry-run fallbacks and the numerical oracles live in ``ref.py``.
"""
from __future__ import annotations

import jax

from .decode_attention import decode_attention as decode_attention_kernel
from .flash_prefill import flash_prefill as flash_prefill_kernel
from .ssd_scan import ssd_scan as ssd_scan_kernel

__all__ = ["flash_prefill_op", "decode_attention_op", "ssd_scan_op", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_prefill_op(q, k, v, *, causal=True, window=0,
                     block_q=128, block_k=128, interpret=None):
    """Fused causal/sliding-window GQA attention. (B,Sq,H,D)x(B,Sk,K,D)->(B,Sq,H,D)."""
    if interpret is None:
        interpret = not on_tpu()
    return flash_prefill_kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def decode_attention_op(q, k_cache, v_cache, lengths, *, window=0,
                        block_k=256, interpret=None):
    """Flash-decode: (B,H,D) against (B,S,K,D) caches with valid lengths."""
    if interpret is None:
        interpret = not on_tpu()
    return decode_attention_kernel(
        q, k_cache, v_cache, lengths, window=window,
        block_k=block_k, interpret=interpret,
    )


def ssd_scan_op(x, dt, A, Bm, Cm, *, chunk=64, interpret=None):
    """Mamba2 SSD chunked scan: returns (y, final_state)."""
    if interpret is None:
        interpret = not on_tpu()
    return ssd_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
