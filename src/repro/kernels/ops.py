"""Jit'd wrappers around the Pallas kernels with automatic path selection.

``use_pallas`` semantics: on a real TPU the kernels lower natively; on this
CPU container they run via interpret=True (Python-level execution of the
kernel body — correct but slow, so only tests exercise them). The pure-jnp
paths in ``repro.models.attention`` / ``repro.models.ssm`` are the production
CPU/dry-run fallbacks and the numerical oracles live in ``ref.py``.

All kernels auto-detect the backend when ``interpret`` is left as None —
``interpret`` is resolved through :func:`on_tpu`, never hardcoded, so a real
TPU always gets the native lowering.
"""
from __future__ import annotations

from .compat import on_tpu
from .decode_attention import decode_attention as decode_attention_kernel
from .flash_prefill import flash_prefill as flash_prefill_kernel
from .paged_decode_attention import (
    paged_decode_attention as paged_decode_attention_kernel,
)
from .ssd_scan import ssd_scan as ssd_scan_kernel

__all__ = [
    "flash_prefill_op", "decode_attention_op", "paged_decode_attention_op",
    "ssd_scan_op", "on_tpu",
]


def flash_prefill_op(q, k, v, *, causal=True, window=0, q_offset=0,
                     block_q=128, block_k=128, interpret=None):
    """Fused causal/sliding-window GQA attention. (B,Sq,H,D)x(B,Sk,K,D)->(B,Sq,H,D).

    ``q_offset`` shifts the query positions for chunked (piecewise) prefill:
    a piece's queries sit at absolute positions ``q_offset + arange(Sq)``
    over the full key axis, so each piece attends causally to every prior
    piece — the kernel twin of ``models.paged.paged_piece_prefill``."""
    return flash_prefill_kernel(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def decode_attention_op(q, k_cache, v_cache, lengths, *, window=0,
                        block_k=256, interpret=None):
    """Flash-decode: (B,H,D) against head-major (B,K,S,D) caches with valid
    lengths. The cache layout matches ``models.model.init_cache`` so no
    per-step copy happens between the model cache and the kernel."""
    return decode_attention_kernel(
        q, k_cache, v_cache, lengths, window=window,
        block_k=block_k, interpret=interpret,
    )


def paged_decode_attention_op(q, k_pages, v_pages, block_tables, lengths, *,
                              window=0, interpret=None):
    """Paged flash-decode: (B,H,D) against a shared (N,K,bs,D) block pool
    addressed through (B,MB) page tables. The pool layout matches
    ``models.paged.init_paged_pages``; the page table rides in via scalar
    prefetch and becomes the kernel's DMA index map (gather-free)."""
    return paged_decode_attention_kernel(
        q, k_pages, v_pages, block_tables, lengths,
        window=window, interpret=interpret,
    )


def ssd_scan_op(x, dt, A, Bm, Cm, *, chunk=64, interpret=None):
    """Mamba2 SSD chunked scan: returns (y, final_state)."""
    return ssd_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
