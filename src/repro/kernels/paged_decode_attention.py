"""Paged flash-decode kernel (Pallas TPU): one query token per sequence
against a block-pooled KV cache addressed through per-request page tables.

Physical KV storage is a pool of fixed-size token blocks ``(N, K, bs, D)``
shared by all requests (``repro.serving.kv_pool`` owns the allocation); each
batch row reads its sequence through a ``(B, MB)`` block table. The kernel
gathers K/V blocks *by index map*: the page table rides in via scalar
prefetch (SMEM) and the K/V BlockSpecs address ``k_pages[bt[bi, si]]``
directly, so the gather happens as DMA block selection — no materialized
``(B, MB·bs, ...)`` copy of the cache ever exists (the XLA reference path
below pays exactly that copy).

Grid: (batch, kv_heads, table_blocks) with the page dimension innermost.
Per (batch, kv_head) the n_rep grouped query heads are processed together as
a (n_rep, D) x (D, bs) MXU matmul with online-softmax state in VMEM scratch.
Padding table entries point at the reserved NULL block (0): the DMA stays
in-range and the valid-length mask zeroes the contribution.

TARGET: TPU v5e. Validated with interpret=True against the gather reference
and ``ref.decode_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import on_tpu, tpu_compiler_params

NEG_INF = -1e30

__all__ = ["paged_decode_attention", "paged_decode_attention_ref", "paged_gather_kv"]


def _kernel(
    lengths_ref,                       # SMEM (B,)
    bt_ref,                            # SMEM (B, MB) page table
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    window: int,
    block_size: int,
    n_blocks: int,
):
    bi = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (n_rep, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_size, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (block_size, D)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (n_rep, block_size)

    # logical position of each pool entry = table slot * block_size + offset;
    # NULL-padded slots land beyond ``length`` and are masked here
    length = lengths_ref[bi]
    k_pos = si * block_size + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1
    )
    ok = k_pos < length
    if window > 0:
        ok &= (length - 1 - k_pos) < window
    logits = jnp.where(ok, logits, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(si == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def _paged_decode_attention_impl(
    q: jnp.ndarray,             # (B, H, D)
    k_pages: jnp.ndarray,       # (N, K, bs, D) shared block pool
    v_pages: jnp.ndarray,       # (N, K, bs, D)
    block_tables: jnp.ndarray,  # (B, MB) int32 — NULL-padded page tables
    lengths: jnp.ndarray,       # (B,) int32 valid entries incl. current token
    *,
    window: int,
    interpret: bool,
) -> jnp.ndarray:
    n, kh, bs, d = k_pages.shape
    b, h, _ = q.shape
    mb = block_tables.shape[1]
    assert h % kh == 0
    n_rep = h // kh

    qg = q.reshape(b, kh, n_rep, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # lengths + page tables land in SMEM
        grid=(b, kh, mb),
        in_specs=[
            pl.BlockSpec((1, 1, n_rep, d), lambda bi, ki, si, *_: (bi, ki, 0, 0)),
            # the page table IS the index map: block si of row bi reads
            # physical block bt[bi, si] — gather-by-DMA, no copy
            pl.BlockSpec(
                (1, 1, bs, d), lambda bi, ki, si, lens, bt: (bt[bi, si], ki, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, bs, d), lambda bi, ki, si, lens, bt: (bt[bi, si], ki, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, n_rep, d), lambda bi, ki, si, *_: (bi, ki, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((n_rep,), jnp.float32),
            pltpu.VMEM((n_rep,), jnp.float32),
            pltpu.VMEM((n_rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=1.0 / (d**0.5),
            window=window,
            block_size=bs,
            n_blocks=mb,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, n_rep, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(b, h, d)


def paged_decode_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    window: int = 0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Paged flash-decode over a (N, K, bs, D) block pool.

    ``interpret=None`` auto-detects the backend: native lowering on TPU,
    interpreter elsewhere (never silently interprets on real hardware).
    """
    if interpret is None:
        interpret = not on_tpu()
    return _paged_decode_attention_impl(
        q, k_pages, v_pages, block_tables, lengths,
        window=window, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# XLA gather reference path
# ---------------------------------------------------------------------------


def paged_gather_kv(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """Materialize per-row head-major sequences from the block pool:
    (N, K, bs, D) gathered through (B, MB) tables -> (B, K, MB*bs, D).

    This is the production CPU path and the oracle the kernel is validated
    against; on TPU the kernel's index map does the same selection as DMA
    without the copy.
    """
    b, mb = block_tables.shape
    n, kh, bs, d = pages.shape
    g = pages[block_tables]                       # (B, MB, K, bs, D)
    return g.transpose(0, 2, 1, 3, 4).reshape(b, kh, mb * bs, d)


def paged_decode_attention_ref(
    q: jnp.ndarray,             # (B, H, D)
    k_pages: jnp.ndarray,       # (N, K, bs, D)
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, MB)
    lengths: jnp.ndarray,       # (B,)
    *,
    window: int = 0,
) -> jnp.ndarray:
    """Gather-then-attend reference: identical math, materialized gather."""
    k_seq = paged_gather_kv(k_pages, block_tables).astype(jnp.float32)
    v_seq = paged_gather_kv(v_pages, block_tables).astype(jnp.float32)
    b, kh, s, d = k_seq.shape
    h = q.shape[1]
    n_rep = h // kh
    qg = q.reshape(b, kh, n_rep, d).astype(jnp.float32)
    logits = jnp.einsum("bgrd,bgsd->bgrs", qg, k_seq) / jnp.sqrt(float(d))
    k_pos = jnp.arange(s)[None, :]
    ok = k_pos < lengths[:, None]
    if window > 0:
        ok &= (lengths[:, None] - 1 - k_pos) < window
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", p, v_seq)
    return out.reshape(b, h, d).astype(q.dtype)
