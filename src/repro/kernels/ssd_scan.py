"""Mamba2 SSD chunked-scan kernel (Pallas TPU).

Grid: (batch, heads, n_chunks), chunks innermost. Per step the kernel
computes the intra-chunk quadratic ("dual attention") term — (Q,Q) and
(Q,N)×(N,P) MXU matmuls — and carries the (P,N) inter-chunk state in VMEM
scratch across chunk iterations (sequential innermost dimension). This is
the TPU-native rethink of the Mamba2 CUDA scan: instead of a warp-level
associative scan, the chunk recurrence is a short sequential grid dimension
and all heavy math is MXU matmuls over VMEM tiles.

TARGET: TPU v5e. Validated with interpret=True against ``ref.ssd_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import on_tpu, tpu_compiler_params

__all__ = ["ssd_scan"]


def _kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,     # inputs
    y_ref, state_ref,                        # outputs
    h_scr,                                   # (P, N) carried state
    *,
    chunk: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q,)   [laid out (1,1,Q)]
    A = a_ref[0].astype(jnp.float32)          # scalar [laid out (1,)]
    Bm = b_ref[0, 0].astype(jnp.float32)      # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)      # (Q, N)

    dA = dt * A                                # (Q,) <= 0
    seg = jnp.cumsum(dA)                       # (Q,)
    xdt = x * dt[:, None]                      # (Q, P)

    # intra-chunk: y_intra[i] = sum_{j<=i} exp(seg_i - seg_j) (C_i.B_j) xdt_j
    li = seg[:, None]
    lj = seg[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iq >= jq, jnp.exp(li - lj), 0.0)       # (Q, Q)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (Q, Q)
    y_intra = jax.lax.dot_general(
        scores * L, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (Q, P)

    # inter-chunk: y_inter[i] = exp(seg_i) * C_i . h_prev^T   (h_prev: (P,N))
    h_prev = h_scr[...]
    y_inter = jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(seg)[:, None]                             # (Q, P)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h_new = exp(sum dA) h_prev + sum_j exp(seg_Q - seg_j) xdt_j B_j^T
    decay_out = jnp.exp(seg[-1] - seg)                    # (Q,)
    new_contrib = jax.lax.dot_general(
        xdt * decay_out[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (P, N)
    h_scr[...] = jnp.exp(seg[-1]) * h_prev + new_contrib

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_ref[0, 0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,    # (B, T, H, P)
    dt: jnp.ndarray,   # (B, T, H) — softplus-ed step sizes
    A: jnp.ndarray,    # (H,) negative decay
    Bm: jnp.ndarray,   # (B, T, G, N); G must divide H
    Cm: jnp.ndarray,   # (B, T, G, N)
    *,
    chunk: int = 64,
    interpret: bool | None = None,
):
    """Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    if interpret is None:
        interpret = not on_tpu()
    b, t, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    n_rep = h // g

    xt = x.transpose(0, 2, 1, 3)                    # (B, H, T, P)
    dtt = dt.transpose(0, 2, 1)                     # (B, H, T)
    bt = Bm.transpose(0, 2, 1, 3)                   # (B, G, T, N)
    ct = Cm.transpose(0, 2, 1, 3)

    grid = (b, h, nc)
    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec(
                (1, 1, chunk, n),
                lambda bi, hi, ci, n_rep=n_rep: (bi, hi // n_rep, ci, 0),
            ),
            pl.BlockSpec(
                (1, 1, chunk, n),
                lambda bi, hi, ci, n_rep=n_rep: (bi, hi // n_rep, ci, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xt, dtt, A, bt, ct)
    return y.transpose(0, 2, 1, 3), state
