"""Flash-attention prefill kernel (Pallas TPU).

Causal (+ optional sliding-window) GQA attention with online softmax,
VMEM-tiled via BlockSpec: the grid is (batch, q_heads, q_blocks, kv_blocks)
with the kv dimension innermost; running (max, sum, acc) live in VMEM
scratch that persists across the kv iterations of one q block (TPU grid
execution is sequential over the last dimension; "arbitrary" dimension
semantics on a real TPU). GQA is expressed in the K/V index_map
(head -> head // n_rep), so KV blocks are fetched once per group.

Block shapes default to (block_q, head_dim) × (block_k, head_dim) with
MXU-aligned 128-multiples where the head_dim allows.

``q_offset`` (static) shifts the query positions for chunked prefill: a
piece of ``Sq`` queries at absolute positions ``q_offset + arange(Sq)``
attends causally over the full ``Sk`` key axis (all prior pieces plus its
own), matching the XLA paths in ``models.attention`` and the piecewise
write path ``models.paged.paged_piece_prefill``.

TARGET: TPU v5e. Validated with interpret=True on CPU against
``ref.mha_reference`` (the CPU backend cannot lower TPU Pallas kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import on_tpu, tpu_compiler_params

NEG_INF = -1e30

__all__ = ["flash_prefill"]


def _kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    q_offset: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (bq, bk)

    q_pos = (
        q_offset + qi * block_q
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    diff = q_pos - k_pos
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    logits = jnp.where(ok, logits, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "block_q", "block_k", "interpret"
    ),
)
def flash_prefill(
    q: jnp.ndarray,   # (B, Sq, H, D)
    k: jnp.ndarray,   # (B, Sk, K, D)
    v: jnp.ndarray,   # (B, Sk, K, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = not on_tpu()
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0, (h, kh)
    n_rep = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k

    qt = q.transpose(0, 2, 1, 3)   # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)   # (B, K, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=1.0 / (d**0.5),
            causal=causal,
            window=window,
            q_offset=q_offset,
            block_q=block_q,
            block_k=block_k,
            n_kv_blocks=nk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda bi, hi, qi, ki, n_rep=n_rep: (bi, hi // n_rep, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda bi, hi, qi, ki, n_rep=n_rep: (bi, hi // n_rep, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
