"""Flash-decode kernel (Pallas TPU): one query token per sequence against a
long (padded) KV cache — the serving hot spot behind decode_32k / long_500k.

The cache is HEAD-MAJOR ``(B, K, S, D)`` — the same layout the model keeps it
in (``init_cache``) — so the kernel's BlockSpecs slice the seq dimension
directly and no per-step transpose/copy of the cache ever happens.

Grid: (batch, kv_heads, kv_blocks) with the KV-length dimension innermost.
Per (batch, kv_head) the n_rep grouped query heads are processed together as
a (n_rep, D) × (D, block_k) MXU matmul. Online softmax state (m, l, acc)
lives in VMEM scratch across kv iterations. Valid-length + sliding-window
masking uses the per-row ``lengths`` passed via scalar prefetch (SMEM).

TARGET: TPU v5e. Validated with interpret=True against ``ref.decode_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import on_tpu, tpu_compiler_params

NEG_INF = -1e30

__all__ = ["decode_attention"]


def _kernel(
    lengths_ref,                       # SMEM (B,)
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    window: int,
    block_k: int,
    n_kv_blocks: int,
):
    bi = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (n_rep, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (block_k, D)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (n_rep, block_k)

    length = lengths_ref[bi]
    k_pos = si * block_k + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1
    )
    ok = k_pos < length
    if window > 0:
        ok &= (length - 1 - k_pos) < window
    logits = jnp.where(ok, logits, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(si == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret")
)
def _decode_attention_impl(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, K, S, D) head-major
    v_cache: jnp.ndarray,  # (B, K, S, D)
    lengths: jnp.ndarray,  # (B,) int32 — valid entries incl. current token
    *,
    window: int,
    block_k: int,
    interpret: bool,
) -> jnp.ndarray:
    b, kh, s, d = k_cache.shape
    h = q.shape[1]
    assert h % kh == 0
    n_rep = h // kh
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    ns = s // block_k

    # zero-copy: the (B, K, S, D) cache feeds the BlockSpecs directly; only
    # the single query token is reshaped (O(H·D) — no cache-sized movement).
    qg = q.reshape(b, kh, n_rep, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, ns),
        in_specs=[
            pl.BlockSpec((1, 1, n_rep, d), lambda bi, ki, si, *_: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, ki, si, *_: (bi, ki, si, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, ki, si, *_: (bi, ki, si, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, n_rep, d), lambda bi, ki, si, *_: (bi, ki, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((n_rep,), jnp.float32),
            pltpu.VMEM((n_rep,), jnp.float32),
            pltpu.VMEM((n_rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=1.0 / (d**0.5),
            window=window,
            block_k=block_k,
            n_kv_blocks=ns,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, n_rep, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, h, d)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    window: int = 0,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Flash-decode over a head-major (B, K, S, D) cache.

    ``interpret=None`` auto-detects the backend: native lowering on TPU,
    interpreter elsewhere (never silently interprets on real hardware).
    """
    if interpret is None:
        interpret = not on_tpu()
    return _decode_attention_impl(
        q, k_cache, v_cache, lengths,
        window=window, block_k=block_k, interpret=interpret,
    )
