"""FLOPs-based device cost model (Appendix E, Eq. 7-9).

Reproduces the paper's per-token FLOPs accounting exactly (Tables 6-7):

    FLOPs_total = FLOPs_attn + FLOPs_ffn + FLOPs_ln + FLOPs_emb + FLOPs_out

Prefill attention (per token, per layer):     Eq. (8)
    3 d^2 + L^2 d / n_heads + L d + d^2
Decode attention (KV cache kills the quadratic term): Eq. (9)
    3 d^2 + L d / n_heads + L d + d^2

FLOPs here follow the paper's multiply-accumulate counting (one MAC = one
FLOP), which is what makes Table 6 reproduce (BLOOM-1.1B @ L=32 prefill
≈ 0.85 GFLOPs with ~31% embed + ~31% output share, Table 7).
"""
from __future__ import annotations

import dataclasses

__all__ = ["DeviceModelSpec", "FlopsBreakdown", "flops_per_token", "BLOOM_1B1",
           "BLOOM_560M", "QWEN_05B", "energy_cost_per_token"]


@dataclasses.dataclass(frozen=True)
class DeviceModelSpec:
    """Architecture hyperparameters entering Eq. 7-9."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int


# The paper's three on-device reference models (App. E.1). NOTE: the paper
# states these exact hyperparameters (all 24 layers); we follow the paper, not
# the upstream model cards, because Table 6/7 are computed from these numbers.
BLOOM_1B1 = DeviceModelSpec("bloom-1.1b", 24, 1024, 16, 4096, 250880)
BLOOM_560M = DeviceModelSpec("bloom-560m", 24, 512, 8, 2048, 250880)
QWEN_05B = DeviceModelSpec("qwen1.5-0.5b", 24, 768, 12, 2048, 151936)


@dataclasses.dataclass(frozen=True)
class FlopsBreakdown:
    attn: float
    ffn: float
    ln: float
    emb: float
    out: float

    @property
    def total(self) -> float:
        return self.attn + self.ffn + self.ln + self.emb + self.out

    def ratios(self) -> dict[str, float]:
        t = self.total
        return {
            "Embedding": self.emb / t,
            "Attention": self.attn / t,
            "FFN": self.ffn / t,
            "LayerNorm": self.ln / t,
            "Output": self.out / t,
        }


def flops_per_token(spec: DeviceModelSpec, seq_len: int, phase: str) -> FlopsBreakdown:
    """Per-token FLOPs (Eq. 7-9) for ``phase`` in {"prefill", "decode"} at
    context length ``seq_len`` (the paper's L)."""
    d, L, nl, nh = spec.d_model, seq_len, spec.n_layers, spec.n_heads
    if phase == "prefill":
        attn = nl * (3 * d * d + (L * L * d) / nh + L * d + d * d)  # Eq. (8)
    elif phase == "decode":
        attn = nl * (3 * d * d + (L * d) / nh + L * d + d * d)      # Eq. (9)
    else:
        raise ValueError(f"phase must be prefill|decode, got {phase!r}")
    ffn = nl * 2 * d * spec.d_ff        # two projections, MAC-counted
    ln = nl * 2 * d + d                 # 2 norms/layer + final norm (tiny)
    emb = spec.vocab * d                # input embedding projection
    out = spec.vocab * d                # output logits projection
    return FlopsBreakdown(attn=attn, ffn=ffn, ln=ln, emb=emb, out=out)


def energy_cost_per_token(
    spec: DeviceModelSpec,
    seq_len: int,
    phase: str,
    energy_to_money: float,
) -> float:
    """Unified per-token device cost: FLOPs × (USD per MFLOP) (App. E).

    The paper sets energy_to_money = 0.3 $/MFLOP (server-constrained runs)
    or 5 $/MFLOP (device-constrained runs).
    """
    return flops_per_token(spec, seq_len, phase).total / 1e6 * energy_to_money
