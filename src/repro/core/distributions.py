"""Empirical distributions used by the DiSCo dispatch policies.

The paper (§4.2) models server TTFT as "a known distribution, obtained either
from server-provided information or device-side profiling", and prompt lengths
as an empirical distribution p(l). Both are represented here as sample-backed
empirical distributions with CDF / inverse-CDF / partial-expectation queries —
exactly the primitives Algorithms 1-3 need.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "EmpiricalCDF",
    "LengthDistribution",
    "lognormal_fit",
]


@dataclasses.dataclass(frozen=True)
class EmpiricalCDF:
    """Empirical CDF F(t) over nonnegative samples (e.g. server TTFT seconds).

    ``F(t)``      -> P[X <= t]
    ``quantile(q)`` -> F^{-1}(q)  (the paper's w_tail = F^{-1}(1 - min(a, b)))
    """

    sorted_samples: np.ndarray

    @classmethod
    def from_samples(cls, samples: Sequence[float]) -> "EmpiricalCDF":
        arr = np.asarray(samples, dtype=np.float64)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError("EmpiricalCDF needs a non-empty 1-D sample array")
        if np.any(~np.isfinite(arr)) or np.any(arr < 0):
            raise ValueError("samples must be finite and nonnegative")
        return cls(np.sort(arr))

    @property
    def n(self) -> int:
        return int(self.sorted_samples.size)

    def cdf(self, t) -> np.ndarray:
        """F(t) = fraction of samples <= t (right-continuous)."""
        t = np.asarray(t, dtype=np.float64)
        idx = np.searchsorted(self.sorted_samples, t, side="right")
        return idx / self.n

    def quantile(self, q) -> np.ndarray:
        """F^{-1}(q), clipped to [0, 1]."""
        q = np.clip(np.asarray(q, dtype=np.float64), 0.0, 1.0)
        return np.quantile(self.sorted_samples, q, method="inverted_cdf")

    def mean(self) -> float:
        return float(self.sorted_samples.mean())

    def sample(self, rng: np.random.Generator, size=None) -> np.ndarray:
        return rng.choice(self.sorted_samples, size=size, replace=True)


@dataclasses.dataclass(frozen=True)
class LengthDistribution:
    """Empirical prompt-length distribution p(l) with the partial-expectation
    queries needed by Eq. (2) and Eq. (3).

    Lengths are integer token counts; ties are allowed (weights accumulate).
    """

    lengths: np.ndarray  # sorted unique lengths
    probs: np.ndarray    # p(l), same shape

    @classmethod
    def from_samples(cls, samples: Sequence[int]) -> "LengthDistribution":
        arr = np.asarray(samples)
        if arr.size == 0:
            raise ValueError("need at least one length sample")
        if np.any(arr <= 0):
            raise ValueError("prompt lengths must be positive")
        lengths, counts = np.unique(arr, return_counts=True)
        return cls(lengths.astype(np.float64), counts / counts.sum())

    def mean(self) -> float:
        """E[l]."""
        return float(np.dot(self.lengths, self.probs))

    def partial_token_mass(self, l_th: float) -> float:
        """∫_0^{l_th} l p(l) dl  — expected tokens from prompts shorter than l_th.

        Strict inequality (l < l_th) matches Algorithm 3's routing test.
        """
        mask = self.lengths < l_th
        return float(np.dot(self.lengths[mask], self.probs[mask]))

    def token_mass_threshold(self, target_mass: float) -> float:
        """Solve Eq. (3): the smallest l_th with ∫_0^{l_th} l p(l) dl >= target.

        Returns +inf if even the full distribution cannot reach the target
        (then every prompt routes device-only / below-threshold).
        """
        if target_mass <= 0.0:
            return 0.0
        cum = np.cumsum(self.lengths * self.probs)
        idx = np.searchsorted(cum, target_mass - 1e-12, side="left")
        if idx >= self.lengths.size:
            return float("inf")
        # threshold strictly above lengths[idx] so that prompts of that length
        # (inclusive) fall below the threshold.
        return float(self.lengths[idx]) + 0.5

    def sample(self, rng: np.random.Generator, size=None) -> np.ndarray:
        return rng.choice(self.lengths, size=size, p=self.probs)

    def support(self) -> np.ndarray:
        return self.lengths


def lognormal_fit(samples: Sequence[float]) -> tuple[float, float]:
    """Fit (mu, sigma) of a log-normal by moment matching on log-samples.

    The paper's scalability study (§5.3) generates synthetic workloads by
    "fitting log-normal distributions to the prompt lengths and TTFT from the
    real trace by following the mean and standard deviation of the logarithm".
    """
    arr = np.asarray(samples, dtype=np.float64)
    logs = np.log(arr[arr > 0])
    return float(logs.mean()), float(logs.std())
