"""Dispatch controller: cost-aware request routing (§4.2, Algorithms 1-3).

Two policies, selected by the cost regime (Algorithm 1):

* Device-constrained (Algorithm 2 / Eq. 1-2): a *wait-time* policy. Every
  request goes to the server immediately; the device starts local inference
  only after a per-length wait w(l). Short prompts (cheap on device) start
  immediately (w=0); the rest wait, with a hard cap w_tail reserved for tail
  protection so that worst-case TTFT is bounded.

* Server-constrained (Algorithm 3 / Eq. 3): a *length-threshold* policy.
  Prompts shorter than l_th run device-only (server budget saved where the
  device is fast anyway); longer prompts race both endpoints.

Both satisfy the budget constraint E[I_c(l) * l] <= b * E[l] on the
constrained endpoint c, where I_c(l) indicates that endpoint executing
*prefill* for a prompt of length l.

Deviation from the paper, documented: Algorithm 2 line 18 of the paper's
pseudocode ("F(w*)·length_cost + (b − available_budget) = b") is dimensionally
garbled. We implement the budget-exhaustion intent exactly: at the boundary
length, pick w* so the *incremental* expected device-token spend over the
w_tail baseline equals the remaining budget:

    p(l)·l·(F(w_tail) − F(w*)) / E[l] = available_budget

which reduces to w* = F^{-1}( F(w_tail) − available·E[l] / (p(l)·l) ), and has
the right limits (available→0 ⇒ w*→w_tail; available→full ⇒ w*→0).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .cost import CostModel, Endpoint, Regime
from .distributions import EmpiricalCDF, LengthDistribution

__all__ = [
    "DispatchDecision",
    "DevicePolicy",
    "ServerPolicy",
    "StochasticPolicy",
    "SingleEndpointPolicy",
    "make_policy",
    "DEFAULT_TAIL_RATIO",
]

DEFAULT_TAIL_RATIO = 0.05  # α — budget slice reserved for tail protection


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """What to do with one request.

    use_server / use_device: whether each endpoint runs prefill at all.
    device_wait: seconds the device waits before starting local inference
        (0 = start immediately; only meaningful when use_device).
    """

    use_server: bool
    use_device: bool
    device_wait: float = 0.0

    def __post_init__(self):
        if not (self.use_server or self.use_device):
            raise ValueError("a request must run on at least one endpoint")
        if self.device_wait < 0:
            raise ValueError("device_wait must be nonnegative")


class DispatchPolicy:
    """Interface: map prompt length -> DispatchDecision."""

    def decide(self, length: int, rng: Optional[np.random.Generator] = None) -> DispatchDecision:
        raise NotImplementedError

    # vectorized convenience used by the benchmarks; policies override with
    # closed-form array versions (the paper's Fig. 9 overhead is measured on
    # exactly this path)
    def decide_batch(self, lengths: np.ndarray, rng: Optional[np.random.Generator] = None):
        return [self.decide(int(l), rng) for l in lengths]

    def wait_times_batch(self, lengths: np.ndarray) -> np.ndarray:
        return np.array([self.decide(int(l)).device_wait for l in lengths])


# ---------------------------------------------------------------------------
# Device-constrained: wait-time policy (Algorithm 2, Eq. 1-2)
# ---------------------------------------------------------------------------


class DevicePolicy(DispatchPolicy):
    """Device-constrained scheduling (Algorithm 2).

    Budget semantics: expected device-prefill tokens <= b * E[l]. The device
    runs prefill for a prompt of length l iff the server has not produced its
    first token within w(l) — probability 1 - F(w(l)).
    """

    def __init__(
        self,
        server_ttft: EmpiricalCDF,
        lengths: LengthDistribution,
        budget: float,
        tail_ratio: float = DEFAULT_TAIL_RATIO,
    ):
        if not (0.0 <= budget <= 1.0):
            raise ValueError(f"budget ratio must be in [0,1], got {budget}")
        if not (0.0 < tail_ratio < 1.0):
            raise ValueError(f"tail ratio must be in (0,1), got {tail_ratio}")
        self.server_ttft = server_ttft
        self.lengths = lengths
        self.budget = float(budget)
        self.tail_ratio = float(tail_ratio)
        self._build()

    def _build(self) -> None:
        F = self.server_ttft
        b, alpha = self.budget, self.tail_ratio
        # Phase 1 — tail protection: device joins after w_tail at the latest,
        # spending min(alpha, b) of the budget on the slowest server tail.
        eff_alpha = min(alpha, b)
        self.w_tail = float(F.quantile(1.0 - eff_alpha)) if b > 0 else float("inf")

        ls = self.lengths.support()
        ps = self.lengths.probs
        mean_l = self.lengths.mean()
        wait = np.full(ls.shape, self.w_tail, dtype=np.float64)

        if b > alpha and np.isfinite(self.w_tail):
            # Phase 2 — spend the remaining (b - alpha) on immediate starts for
            # the cheapest (shortest) lengths first; fractional wait at the
            # boundary length. Costs normalized by E[l] so budget is a ratio.
            available = b - alpha
            F_wtail = float(F.cdf(self.w_tail))
            for i in range(ls.size):
                # incremental spend of dropping this length's wait to 0:
                # device-run prob rises from (1 - F(w_tail)) ~= alpha to 1.
                length_cost = ps[i] * ls[i] * F_wtail / mean_l
                if available >= length_cost:
                    wait[i] = 0.0
                    available -= length_cost
                else:
                    # boundary: spend exactly `available`
                    target_F = F_wtail - available * mean_l / (ps[i] * ls[i])
                    target_F = float(np.clip(target_F, 0.0, 1.0))
                    wait[i] = float(F.quantile(target_F))
                    break
        self._wait_table = dict(zip(ls.tolist(), wait.tolist()))
        # Eq. (1) parameters for out-of-support lengths: l_th = largest length
        # with w=0; beta = slope fitted through the first nonzero wait.
        zero_ls = ls[wait == 0.0]
        self.l_th = float(zero_ls.max()) if zero_ls.size else 0.0
        nonzero = wait > 0.0
        if np.any(nonzero & (wait < self.w_tail)):
            j = int(np.argmax(nonzero & (wait < self.w_tail)))
            self.beta = float(wait[j] / ls[j])
        else:
            self.beta = float("inf")  # jump straight to w_tail

    def wait_time(self, length: int) -> float:
        """w(l) — Eq. (1), generalized to unseen lengths."""
        w = self._wait_table.get(float(length))
        if w is not None:
            return w
        if length <= self.l_th:
            return 0.0
        return float(min(self.beta * length, self.w_tail))

    def decide(self, length: int, rng=None) -> DispatchDecision:
        return DispatchDecision(
            use_server=True, use_device=True, device_wait=self.wait_time(length)
        )

    def wait_times_batch(self, lengths: np.ndarray) -> np.ndarray:
        """Vectorized w(l): table lookup via searchsorted + Eq. 1 for unseen
        lengths. O(n log m) — the Fig. 9 scalability path."""
        lengths = np.asarray(lengths, dtype=np.float64)
        table_l = self.lengths.support()
        table_w = np.array([self._wait_table[float(l)] for l in table_l])
        idx = np.searchsorted(table_l, lengths)
        hit = (idx < table_l.size) & (table_l[np.minimum(idx, table_l.size - 1)] == lengths)
        eq1 = np.where(
            lengths <= self.l_th, 0.0, np.minimum(self.beta * lengths, self.w_tail)
        )
        return np.where(hit, table_w[np.minimum(idx, table_w.size - 1)], eq1)

    def expected_budget_use(self) -> float:
        """E[I_d(l)·l] / E[l] under the policy — should be <= b (+ CDF granularity)."""
        ls, ps = self.lengths.support(), self.lengths.probs
        waits = np.array([self.wait_time(int(l)) for l in ls])
        p_device = 1.0 - self.server_ttft.cdf(waits)
        return float(np.dot(ps * p_device, ls) / self.lengths.mean())


# ---------------------------------------------------------------------------
# Server-constrained: length-threshold policy (Algorithm 3, Eq. 3)
# ---------------------------------------------------------------------------


class ServerPolicy(DispatchPolicy):
    """Server-constrained scheduling (Algorithm 3).

    Eq. (3): choose l_th s.t. prompts shorter than l_th carry (1-b) of the
    expected token mass; those run device-only. Longer prompts race both
    endpoints, consuming exactly b·E[l] expected server-prefill tokens.
    """

    def __init__(self, lengths: LengthDistribution, budget: float):
        if not (0.0 <= budget <= 1.0):
            raise ValueError(f"budget ratio must be in [0,1], got {budget}")
        self.lengths = lengths
        self.budget = float(budget)
        self.l_th = lengths.token_mass_threshold((1.0 - budget) * lengths.mean())

    def decide(self, length: int, rng=None) -> DispatchDecision:
        if length < self.l_th:
            return DispatchDecision(use_server=False, use_device=True)
        return DispatchDecision(use_server=True, use_device=True)

    def route_batch(self, lengths: np.ndarray) -> np.ndarray:
        """Vectorized routing: True where the server participates. O(n)."""
        return np.asarray(lengths) >= self.l_th

    def expected_budget_use(self) -> float:
        """E[I_s(l)·l] / E[l] — should be <= b (+ granularity of one length bin)."""
        ls, ps = self.lengths.support(), self.lengths.probs
        mask = ls >= self.l_th
        return float(np.dot(ps[mask], ls[mask]) / self.lengths.mean())


# ---------------------------------------------------------------------------
# Baselines (§5.1): Stoch-S / Stoch-D, vLLM (all-server), llama.cpp (all-device)
# ---------------------------------------------------------------------------


class StochasticPolicy(DispatchPolicy):
    """Stoch-S / Stoch-D: include the constrained endpoint with probability b
    (independent of prompt length), capping its expected token budget at
    b·E[l]; otherwise run the unconstrained endpoint alone."""

    def __init__(self, constrained: Endpoint, budget: float, seed: int = 0):
        if not (0.0 <= budget <= 1.0):
            raise ValueError(f"budget ratio must be in [0,1], got {budget}")
        self.constrained = constrained
        self.budget = float(budget)
        self._rng = np.random.default_rng(seed)

    def decide(self, length: int, rng=None) -> DispatchDecision:
        r = (rng or self._rng).random()
        include = r < self.budget
        if self.constrained is Endpoint.SERVER:
            return DispatchDecision(use_server=include, use_device=True)
        return DispatchDecision(use_server=True, use_device=include)


class SingleEndpointPolicy(DispatchPolicy):
    """vLLM baseline (all-server) or llama.cpp baseline (all-device)."""

    def __init__(self, endpoint: Endpoint):
        self.endpoint = endpoint

    def decide(self, length: int, rng=None) -> DispatchDecision:
        return DispatchDecision(
            use_server=self.endpoint is Endpoint.SERVER,
            use_device=self.endpoint is Endpoint.DEVICE,
        )


def make_policy(
    cost_model: CostModel,
    server_ttft: EmpiricalCDF,
    lengths: LengthDistribution,
    budget: float,
    tail_ratio: float = DEFAULT_TAIL_RATIO,
) -> DispatchPolicy:
    """Algorithm 1: pick the policy for the dominant cost regime."""
    if cost_model.regime() is Regime.DEVICE_CONSTRAINED:
        return DevicePolicy(server_ttft, lengths, budget, tail_ratio)
    return ServerPolicy(lengths, budget)
