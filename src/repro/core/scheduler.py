"""DiSCo middleware facade (Fig. 1).

``DiSCoScheduler`` is the object an application embeds: it owns the cost
model, the fitted distributions, the regime-appropriate dispatch policy
(Algorithm 1) and the migration controller, and exposes three calls:

    plan_request(prompt_len)          -> DispatchDecision
    plan_migration(...)               -> Optional[MigrationPlan]
    observe_server_ttft(seconds)      -> online CDF refresh

The online refresh matters: §4.2 models server TTFT as "a known distribution,
obtained either from server-provided information or device-side profiling" —
profiling is continuous in deployment, so the policy is rebuilt on a sliding
window of observations.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from .cost import CostModel, Endpoint, Regime
from .dispatch import (
    DEFAULT_TAIL_RATIO,
    DispatchDecision,
    DispatchPolicy,
    make_policy,
)
from .distributions import EmpiricalCDF, LengthDistribution
from .migration import MigrationConfig, MigrationController, MigrationPlan

__all__ = ["DiSCoScheduler"]


class DiSCoScheduler:
    def __init__(
        self,
        cost_model: CostModel,
        server_ttft_samples,
        prompt_length_samples,
        budget: float,
        tail_ratio: float = DEFAULT_TAIL_RATIO,
        migration: MigrationConfig = MigrationConfig(),
        ttft_window: int = 2048,
        refresh_every: int = 64,
    ):
        self.cost_model = cost_model
        self.budget = budget
        self.tail_ratio = tail_ratio
        self._ttft_obs: deque[float] = deque(
            np.asarray(server_ttft_samples, dtype=float).tolist(), maxlen=ttft_window
        )
        self._length_obs: deque[int] = deque(
            np.asarray(prompt_length_samples).astype(int).tolist(), maxlen=ttft_window
        )
        self._refresh_every = refresh_every
        self._since_refresh = 0
        self.migration_controller = MigrationController(cost_model, migration)
        self._rebuild()

    # -- policy lifecycle ---------------------------------------------------
    def _rebuild(self) -> None:
        self.server_ttft = EmpiricalCDF.from_samples(list(self._ttft_obs))
        self.lengths = LengthDistribution.from_samples(list(self._length_obs))
        self.policy: DispatchPolicy = make_policy(
            self.cost_model, self.server_ttft, self.lengths, self.budget, self.tail_ratio
        )

    def observe_server_ttft(self, seconds: float) -> None:
        self._ttft_obs.append(float(seconds))
        self._since_refresh += 1
        if self._since_refresh >= self._refresh_every:
            self._since_refresh = 0
            self._rebuild()

    def observe_prompt_length(self, length: int) -> None:
        self._length_obs.append(int(length))

    # -- the two decisions --------------------------------------------------
    def plan_request(self, prompt_len: int, rng=None) -> DispatchDecision:
        return self.policy.decide(prompt_len, rng)

    def plan_migration(
        self,
        *,
        current: Endpoint,
        prompt_len: int,
        generated: int,
        expected_total_tokens: float,
        target_prefill_rate: float,
    ) -> Optional[MigrationPlan]:
        return self.migration_controller.plan(
            current=current,
            prompt_len=prompt_len,
            generated=generated,
            expected_total_tokens=expected_total_tokens,
            target_prefill_rate=target_prefill_rate,
        )

    @property
    def regime(self) -> Regime:
        return self.cost_model.regime()
