"""Unified cost model (§4.1).

Combines monetary server costs (USD/token, split prefill/decode — commercial
APIs price input and output tokens differently, App. E.2) with device energy
costs, converted to a common unit via a user-tunable exchange rate λ.

The dominant-cost *regime* (Algorithm 1) picks which dispatch policy applies:

  device-constrained  iff  min(c_d^p, c_d^d) > max(c_s^p, c_s^d)
  server-constrained  iff  max(c_s^p, c_s^d) > min(c_d^p, c_d^d)
"""
from __future__ import annotations

import dataclasses
import enum


class Regime(enum.Enum):
    DEVICE_CONSTRAINED = "device"
    SERVER_CONSTRAINED = "server"


class Endpoint(enum.Enum):
    DEVICE = "device"
    SERVER = "server"

    @property
    def other(self) -> "Endpoint":
        return Endpoint.SERVER if self is Endpoint.DEVICE else Endpoint.DEVICE


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-token costs, all expressed in the unified (monetary) unit.

    server_prefill / server_decode: USD per token (API pricing, App. E.2).
    device_prefill_energy / device_decode_energy: energy units per token
        (FLOPs-derived, App. E.1).
    exchange_rate: λ — USD per energy unit; user-tunable (battery level,
        charging status, spend preference).
    """

    server_prefill: float
    server_decode: float
    device_prefill_energy: float
    device_decode_energy: float
    exchange_rate: float = 1.0

    def __post_init__(self):
        for name in ("server_prefill", "server_decode",
                     "device_prefill_energy", "device_decode_energy",
                     "exchange_rate"):
            v = getattr(self, name)
            if not (v >= 0.0):
                raise ValueError(f"{name} must be nonnegative, got {v}")

    # -- unified per-token costs ------------------------------------------
    @property
    def device_prefill(self) -> float:
        return self.device_prefill_energy * self.exchange_rate

    @property
    def device_decode(self) -> float:
        return self.device_decode_energy * self.exchange_rate

    def prefill_cost(self, endpoint: Endpoint) -> float:
        return self.device_prefill if endpoint is Endpoint.DEVICE else self.server_prefill

    def decode_cost(self, endpoint: Endpoint) -> float:
        return self.device_decode if endpoint is Endpoint.DEVICE else self.server_decode

    # -- Algorithm 1 -------------------------------------------------------
    def regime(self) -> Regime:
        if min(self.device_prefill, self.device_decode) > max(
            self.server_prefill, self.server_decode
        ):
            return Regime.DEVICE_CONSTRAINED
        return Regime.SERVER_CONSTRAINED

    @property
    def constrained_endpoint(self) -> Endpoint:
        return (
            Endpoint.DEVICE
            if self.regime() is Regime.DEVICE_CONSTRAINED
            else Endpoint.SERVER
        )

    # -- migration economics (§4.3, Eq. 4) ---------------------------------
    def decode_cost_delta(self) -> float:
        """Δc_decode = |c_s^d − c_d^d| (per-token decode cost difference)."""
        return abs(self.server_decode - self.device_decode)

    def cheaper_decode_endpoint(self) -> Endpoint:
        return (
            Endpoint.DEVICE
            if self.device_decode <= self.server_decode
            else Endpoint.SERVER
        )

    def request_cost(
        self,
        *,
        server_prefill_tokens: float = 0.0,
        server_decode_tokens: float = 0.0,
        device_prefill_tokens: float = 0.0,
        device_decode_tokens: float = 0.0,
    ) -> float:
        """Total unified cost of one request given token counts per phase/endpoint."""
        return (
            self.server_prefill * server_prefill_tokens
            + self.server_decode * server_decode_tokens
            + self.device_prefill * device_prefill_tokens
            + self.device_decode * device_decode_tokens
        )
