"""Trace-driven QoE simulator for device-server cooperative serving.

This is the evaluation harness behind every paper figure: it plays a stream
of requests against two endpoint models (a trace-driven server and a
profile-driven device), applies a dispatch policy (§4.2) and optionally the
migration controller (§4.3), and records per-request QoE (TTFT, delivered
TBT series) and unified cost.

Two entry points:

* ``simulate_ttft`` — vectorized TTFT-only evaluation (used by the mean/tail
  TTFT benchmarks, Figs. 5-6 / Table 2, where decode does not matter).
* ``simulate_full`` — per-request event simulation including decode, the
  token delivery buffer and migration (Tables 3, Fig. 7).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .cost import CostModel, Endpoint
from .dispatch import DispatchDecision, DispatchPolicy
from .distributions import EmpiricalCDF
from .migration import MigrationConfig, MigrationController, TokenBuffer

__all__ = [
    "ServerModel",
    "DeviceModel",
    "Request",
    "RequestResult",
    "SimSummary",
    "simulate_ttft",
    "simulate_full",
    "summarize",
]


# ---------------------------------------------------------------------------
# Endpoint models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServerModel:
    """Trace-driven server endpoint: TTFT ~ empirical CDF (length-independent,
    §3 Table 1), decode TBT sampled from a trace-calibrated distribution."""

    ttft: EmpiricalCDF
    tbt_mean: float = 0.03          # packetized streaming → near-zero TBT (§3)
    tbt_shape: float = 2.0          # gamma shape; heavier tail = more jitter

    def sample_ttft(self, rng: np.random.Generator, size=None):
        return self.ttft.sample(rng, size)

    def sample_tbt(self, rng: np.random.Generator, size=None):
        scale = self.tbt_mean / self.tbt_shape
        return rng.gamma(self.tbt_shape, scale, size=size)


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Profile-driven device endpoint: TTFT = l / prefill_rate + overhead
    (linear in prompt length, §3), deterministic decode rate (Fig. 3).

    ``cold_start_s`` models App. B: loading the model before first use adds
    seconds to TTFT (paper Table 4: 1.29-13.43 s depending on model/GPU);
    ``cold_prob`` is the fraction of requests finding the model unloaded
    (evicted for memory/battery reasons).
    """

    prefill_rate: float             # tokens/s
    decode_rate: float              # tokens/s
    ttft_overhead: float = 0.08     # runtime dispatch + tokenizer, seconds
    cold_start_s: float = 0.0       # model load time when cold (App. B)
    cold_prob: float = 0.0
    name: str = "device"

    def ttft(self, length, rng: np.random.Generator | None = None) -> np.ndarray:
        base = np.asarray(length, dtype=np.float64) / self.prefill_rate + self.ttft_overhead
        if self.cold_start_s and self.cold_prob and rng is not None:
            cold = rng.random(np.shape(base) or None) < self.cold_prob
            base = base + np.where(cold, self.cold_start_s, 0.0)
        return base

    def tbt(self) -> float:
        return 1.0 / self.decode_rate


@dataclasses.dataclass(frozen=True)
class Request:
    arrival: float
    prompt_len: int
    gen_len: int


@dataclasses.dataclass
class RequestResult:
    ttft: float
    winner: Endpoint
    cost: float
    tbt_series: list[float] = dataclasses.field(default_factory=list)
    migrated: bool = False
    delayed_tokens: int = 0      # tokens whose *delivery* stalled (buffer ran dry)
    deferred_tokens: int = 0     # tokens whose *generation* moved to the target
                                 # during the hand-off (= buffer B, Eq. 5 — the
                                 # paper's Table 3 "delay_num" magnitude)
    decision: Optional[DispatchDecision] = None


# ---------------------------------------------------------------------------
# Vectorized TTFT-only simulation
# ---------------------------------------------------------------------------


def simulate_ttft(
    lengths: np.ndarray,
    policy: DispatchPolicy,
    server: ServerModel,
    device: DeviceModel,
    rng: np.random.Generator,
) -> dict[str, np.ndarray]:
    """TTFT of each request under ``policy``; returns arrays for analysis.

    The race semantics (§4.2): server starts at t=0 when used; device starts
    at t=w(l) when used; TTFT = min over used endpoints of their first-token
    times. The device is considered *started* (budget + energy spent) iff the
    server has not delivered a first token by the device start time.
    """
    lengths = np.asarray(lengths)
    n = lengths.size
    server_ttft = server.sample_ttft(rng, n)
    device_ttft = device.ttft(lengths)

    use_server = np.zeros(n, dtype=bool)
    use_device = np.zeros(n, dtype=bool)
    wait = np.zeros(n, dtype=np.float64)
    for i, l in enumerate(lengths):
        d = policy.decide(int(l), rng)
        use_server[i], use_device[i], wait[i] = d.use_server, d.use_device, d.device_wait

    t_server = np.where(use_server, server_ttft, np.inf)
    t_device = np.where(use_device, wait + device_ttft, np.inf)
    ttft = np.minimum(t_server, t_device)
    winner_is_device = t_device < t_server
    # device spends energy iff it actually started before the server won
    device_started = use_device & (t_server > wait)
    server_started = use_server
    return {
        "ttft": ttft,
        "winner_is_device": winner_is_device,
        "device_started": device_started,
        "server_started": server_started,
        "server_ttft": server_ttft,
        "device_ttft": device_ttft,
        "lengths": lengths,
    }


# ---------------------------------------------------------------------------
# Full event simulation (decode + buffer + migration)
# ---------------------------------------------------------------------------


def simulate_full(
    requests: Sequence[Request],
    policy: DispatchPolicy,
    cost_model: CostModel,
    server: ServerModel,
    device: DeviceModel,
    rng: np.random.Generator,
    migration: Optional[MigrationConfig] = None,
    expected_gen_len: Optional[float] = None,
) -> list[RequestResult]:
    controller = MigrationController(cost_model, migration) if migration else None
    results = []
    for req in requests:
        results.append(
            _simulate_one(
                req, policy, cost_model, server, device, rng, controller,
                expected_gen_len,
            )
        )
    return results


def _endpoint_tbt(ep: Endpoint, server, device, rng) -> float:
    return float(server.sample_tbt(rng)) if ep is Endpoint.SERVER else device.tbt()


def _simulate_one(
    req: Request,
    policy: DispatchPolicy,
    cost: CostModel,
    server: ServerModel,
    device: DeviceModel,
    rng: np.random.Generator,
    controller: Optional[MigrationController],
    expected_gen_len: Optional[float],
) -> RequestResult:
    decision = policy.decide(req.prompt_len, rng)
    t_server = float(server.sample_ttft(rng)) if decision.use_server else np.inf
    t_device = (
        decision.device_wait + float(device.ttft(req.prompt_len))
        if decision.use_device
        else np.inf
    )
    first = min(t_server, t_device)
    winner = Endpoint.DEVICE if t_device < t_server else Endpoint.SERVER

    # prefill costs: server billed if used; device billed iff it started
    total_cost = 0.0
    if decision.use_server:
        total_cost += cost.server_prefill * req.prompt_len
    if decision.use_device and t_server > decision.device_wait:
        total_cost += cost.device_prefill * req.prompt_len

    r_c = controller.config.consumption_rate if controller else 4.8
    buf = TokenBuffer(r_c, req.arrival + first)
    current = winner
    gen_time = req.arrival + first
    generated = 1
    total_cost += cost.decode_cost(current)  # first token decode-accounted
    migrated = False
    plan = None
    migration_start: Optional[float] = None
    target_ready: Optional[float] = None

    exp_total = expected_gen_len if expected_gen_len is not None else float(req.gen_len)

    while generated < req.gen_len:
        if controller and not migrated and plan is None:
            t_rate = (
                device.prefill_rate
                if cost.cheaper_decode_endpoint() is Endpoint.DEVICE
                else (req.prompt_len + generated) / max(float(server.ttft.mean()), 1e-9)
            )
            plan = controller.plan(
                current=current,
                prompt_len=req.prompt_len,
                generated=generated,
                expected_total_tokens=exp_total,
                target_prefill_rate=t_rate,
            )
        # start hand-off once the delivery buffer can mask it (Eq. 5 / Fig. 4)
        if (
            plan is not None
            and not migrated
            and migration_start is None
            and buf.occupancy(gen_time) >= plan.buffer_needed
        ):
            migration_start = gen_time
            if plan.target is Endpoint.DEVICE:
                t_m = (
                    (req.prompt_len + generated) / device.prefill_rate
                    + controller.config.network_rtt
                )
            else:
                t_m = float(server.sample_ttft(rng)) + controller.config.network_rtt
            # the buffer was sized from the t_m ESTIMATE; the actual hand-off
            # differs (network/queue variance) — this is what delays tokens
            t_m *= float(np.exp(rng.normal(0.0, controller.config.handoff_noise_sigma)))
            target_ready = migration_start + t_m
            # replay prefill on the target is paid now
            total_cost += cost.prefill_cost(plan.target) * (req.prompt_len + generated)

        if migration_start is not None and not migrated:
            if not controller.config.source_continues:
                # sequence freezes at hand-off start: the target replays the
                # fixed prefix; generation resumes only once it is ready.
                current = plan.target
                migrated = True
                gen_time = max(gen_time, target_ready)
            elif gen_time >= target_ready:
                # Fig. 4: source kept generating until this instant
                current = plan.target
                migrated = True
                gen_time = max(gen_time, target_ready)

        step = _endpoint_tbt(current, server, device, rng)
        if migration_start is not None and not migrated:
            # Fig. 4 Row A, throttled: during the hand-off the source only
            # needs to keep the delivery buffer fed — generation outpacing the
            # user's consumption rate r_c buys no QoE and wastes the source's
            # (expensive) decode budget, so it paces down to r_c.
            step = max(step, 1.0 / buf.r_c)
        gen_time += step
        buf.push(gen_time)
        generated += 1
        total_cost += cost.decode_cost(current)

    return RequestResult(
        ttft=first,
        winner=winner,
        cost=total_cost,
        tbt_series=buf.tbt_series(),
        migrated=migrated,
        delayed_tokens=buf.delayed_tokens() if migrated else 0,
        deferred_tokens=plan.buffer_needed if migrated and plan else 0,
        decision=decision,
    )


# ---------------------------------------------------------------------------
# Summaries
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimSummary:
    mean_ttft: float
    p99_ttft: float
    mean_cost: float
    p99_tbt: float
    mean_delayed: float
    migration_rate: float


def summarize(results: Sequence[RequestResult]) -> SimSummary:
    ttfts = np.array([r.ttft for r in results])
    costs = np.array([r.cost for r in results])
    tbts = np.concatenate([r.tbt_series for r in results if r.tbt_series]) if any(
        r.tbt_series for r in results
    ) else np.array([0.0])
    migrated = [r for r in results if r.migrated]
    return SimSummary(
        mean_ttft=float(ttfts.mean()),
        p99_ttft=float(np.percentile(ttfts, 99)),
        mean_cost=float(costs.mean()),
        p99_tbt=float(np.percentile(tbts, 99)),
        mean_delayed=float(np.mean([r.delayed_tokens for r in migrated])) if migrated else 0.0,
        migration_rate=len(migrated) / max(len(results), 1),
    )
