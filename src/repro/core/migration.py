"""Migration controller: cost-efficient token delivery (§4.3).

When both endpoints race the prefill, the *constrained* endpoint may win the
race yet be the more expensive decoder. The migration controller then hands
generation off to the cheaper endpoint, token-by-token:

* Efficient token transfer: only token IDs cross the link (shared vocab);
  no KV-cache/state transfer. The target re-prefills prompt + generated
  tokens locally. (For SSM targets this re-prefill is a linear scan — see
  DESIGN.md §Arch-applicability.)
* Trigger (Eq. 4): migrate iff projected savings
      C_migration = Δc_decode · l_remaining
  exceed the migration overhead (target re-prefill cost + link cost).
* Buffer protocol (Eq. 5, Fig. 4): delivery is paced at the user consumption
  rate r_c < r_g. Migration starts only once the undelivered-token buffer
  holds B = r_c · t_m tokens, where t_m is the estimated hand-off time, so
  the user never observes a stall; the source keeps generating during the
  hand-off until the target is ready.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .cost import CostModel, Endpoint

__all__ = ["MigrationConfig", "MigrationController", "MigrationPlan", "TokenBuffer"]


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    consumption_rate: float = 4.8       # r_c tokens/s (§2.2: 4-5 tok/s readers)
    network_rtt: float = 0.04           # s, token-ID hop device<->server
    per_token_link_cost: float = 0.0    # unified cost of shipping one token ID
    min_remaining_tokens: int = 4       # don't bother migrating at the very end
    handoff_noise_sigma: float = 0.3    # log-normal error of the t_m estimate
    # (the estimate sizes the buffer — Eq. 5; the *actual* hand-off time
    # differs in deployment, which is what delays tokens in Table 3)
    source_continues: bool = True
    # True  -> Fig. 4 protocol: Row A keeps generating (throttled to r_c)
    #          until Row B is ready; zero delivery gaps, slightly higher cost.
    # False -> the sequence freezes at hand-off start (the target replays a
    #          fixed prefix); cheaper, but an underestimated t_m drains the
    #          buffer and delays tokens — this is the regime Table 3 reports.

    def buffer_tokens(self, t_migration: float) -> int:
        """Eq. (5): B = r_c × t_m (rounded up)."""
        return int(math.ceil(self.consumption_rate * max(t_migration, 0.0)))


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    target: Endpoint
    buffer_needed: int      # B tokens that must sit undelivered before hand-off
    est_handoff_time: float  # t_m: target prefill (prompt + generated) + RTT
    projected_savings: float


class TokenBuffer:
    """Delivery-side pacing buffer (Fig. 4).

    Tokens are *generated* at r_g and *delivered* at r_c. ``occupancy(t)``
    is generated-but-undelivered tokens; migration may start when
    occupancy >= B so the user drains the buffer during the hand-off.
    """

    def __init__(self, consumption_rate: float, first_token_time: float):
        self.r_c = float(consumption_rate)
        self.t0 = float(first_token_time)
        self.generated_at: list[float] = [first_token_time]
        self.delivered_at: list[float] = [first_token_time]

    def push(self, gen_time: float) -> float:
        """Record one generated token; returns its delivery time.

        Delivery pace: token i leaves no earlier than one consumption gap
        after token i-1, and never before it is generated.
        """
        self.generated_at.append(gen_time)
        t = max(gen_time, self.delivered_at[-1] + 1.0 / self.r_c)
        self.delivered_at.append(t)
        return t

    def occupancy(self, now: float) -> int:
        """Generated-but-not-yet-delivered token count at time ``now``."""
        gen = sum(1 for t in self.generated_at if t <= now)
        dlv = sum(1 for t in self.delivered_at if t <= now)
        return gen - dlv

    @property
    def n_tokens(self) -> int:
        return len(self.generated_at)

    def tbt_series(self) -> list[float]:
        d = self.delivered_at
        return [d[i] - d[i - 1] for i in range(1, len(d))]

    def delayed_tokens(self, slack: float = 1e-9) -> int:
        """Tokens whose delivery stalled on generation (TBT > 1/r_c)."""
        gap = 1.0 / self.r_c + slack
        return sum(1 for dt in self.tbt_series() if dt > gap)


class MigrationController:
    """Decides *whether*, *where to*, and *when* to migrate (§4.3)."""

    def __init__(self, cost_model: CostModel, config: MigrationConfig = MigrationConfig()):
        self.cost = cost_model
        self.config = config

    def plan(
        self,
        *,
        current: Endpoint,
        prompt_len: int,
        generated: int,
        expected_total_tokens: float,
        target_prefill_rate: float,
    ) -> Optional[MigrationPlan]:
        """Return a MigrationPlan if migrating now is worthwhile, else None.

        target_prefill_rate: tokens/s the target endpoint prefills at — used
        to estimate t_m (it must re-prefill prompt + generated token IDs).
        """
        target = self.cost.cheaper_decode_endpoint()
        if target is current:
            return None
        l_remaining = max(expected_total_tokens - generated, 0.0)
        if l_remaining < self.config.min_remaining_tokens:
            return None

        # Eq. (4): projected savings from decoding the remainder on the target.
        savings = self.cost.decode_cost_delta() * l_remaining

        # Overhead: target re-prefill of (prompt + generated) tokens, plus link.
        replay = prompt_len + generated
        overhead = (
            self.cost.prefill_cost(target) * replay
            + self.config.per_token_link_cost * replay
        )
        if savings <= overhead:
            return None

        t_m = replay / max(target_prefill_rate, 1e-9) + self.config.network_rtt
        return MigrationPlan(
            target=target,
            buffer_needed=self.config.buffer_tokens(t_m),
            est_handoff_time=t_m,
            projected_savings=savings - overhead,
        )
