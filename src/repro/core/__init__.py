"""DiSCo core: the paper's contribution (cost-aware dispatch + token-level
migration for device-server cooperative LLM text streaming)."""
from .cost import CostModel, Endpoint, Regime
from .dispatch import (
    DEFAULT_TAIL_RATIO,
    DevicePolicy,
    DispatchDecision,
    DispatchPolicy,
    ServerPolicy,
    SingleEndpointPolicy,
    StochasticPolicy,
    make_policy,
)
from .distributions import EmpiricalCDF, LengthDistribution, lognormal_fit
from .energy import (
    BLOOM_1B1,
    BLOOM_560M,
    QWEN_05B,
    DeviceModelSpec,
    FlopsBreakdown,
    energy_cost_per_token,
    flops_per_token,
)
from .migration import MigrationConfig, MigrationController, MigrationPlan, TokenBuffer
from .scheduler import DiSCoScheduler
from .simulator import (
    DeviceModel,
    Request,
    RequestResult,
    ServerModel,
    SimSummary,
    simulate_full,
    simulate_ttft,
    summarize,
)

__all__ = [
    "CostModel", "Endpoint", "Regime",
    "DEFAULT_TAIL_RATIO", "DevicePolicy", "DispatchDecision", "DispatchPolicy",
    "ServerPolicy", "SingleEndpointPolicy", "StochasticPolicy", "make_policy",
    "EmpiricalCDF", "LengthDistribution", "lognormal_fit",
    "BLOOM_1B1", "BLOOM_560M", "QWEN_05B", "DeviceModelSpec", "FlopsBreakdown",
    "energy_cost_per_token", "flops_per_token",
    "MigrationConfig", "MigrationController", "MigrationPlan", "TokenBuffer",
    "DiSCoScheduler",
    "DeviceModel", "Request", "RequestResult", "ServerModel", "SimSummary",
    "simulate_full", "simulate_ttft", "summarize",
]
