"""Lightweight TTFT predictors (Appendix C, Table 5).

The paper evaluates Moving Average, Exponential Smoothing, Random Forest and
XGBoost on server-TTFT traces and concludes *none* is accurate enough
(MAPE 20-54%) — which motivates DiSCo's distribution-based scheduling instead
of point prediction. We reproduce the two closed-form methods exactly and add
a numpy gradient-boosted-stumps stand-in for the tree baselines (sklearn /
xgboost are not available offline); the conclusion (high MAPE) is what the
benchmark validates.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "moving_average_forecast",
    "exponential_smoothing_forecast",
    "boosted_stumps_forecast",
    "mape",
    "mae",
]


def moving_average_forecast(series: np.ndarray, window: int = 8) -> np.ndarray:
    """One-step-ahead MA forecast; first ``window`` steps use expanding mean."""
    series = np.asarray(series, dtype=np.float64)
    preds = np.empty_like(series)
    preds[0] = series[0]
    for i in range(1, series.size):
        lo = max(0, i - window)
        preds[i] = series[lo:i].mean()
    return preds


def exponential_smoothing_forecast(series: np.ndarray, alpha: float = 0.3) -> np.ndarray:
    """Simple exponential smoothing, one-step-ahead."""
    series = np.asarray(series, dtype=np.float64)
    preds = np.empty_like(series)
    level = series[0]
    preds[0] = level
    for i in range(1, series.size):
        preds[i] = level
        level = alpha * series[i] + (1 - alpha) * level
    return preds


def boosted_stumps_forecast(
    series: np.ndarray, n_lags: int = 4, n_rounds: int = 32, lr: float = 0.3
) -> np.ndarray:
    """Tree-baseline stand-in: gradient-boosted depth-1 regression stumps on
    lag features, trained on the first half, predicting one-step-ahead on the
    rest (simplest honest analogue of the paper's RF/XGBoost rows)."""
    series = np.asarray(series, dtype=np.float64)
    n = series.size
    if n <= n_lags + 8:
        return np.full_like(series, series.mean())
    X = np.stack([series[i : n - n_lags + i] for i in range(n_lags)], axis=1)
    y = series[n_lags:]
    split = max(n_lags + 4, (n - n_lags) // 2)
    Xtr, ytr = X[:split], y[:split]

    base = float(ytr.mean())
    stumps: list[tuple[int, float, float, float]] = []
    resid = ytr - base
    for _ in range(n_rounds):
        best = None
        for f in range(n_lags):
            order = np.argsort(Xtr[:, f])
            xs, rs = Xtr[order, f], resid[order]
            csum = np.cumsum(rs)
            total = csum[-1]
            cnt = np.arange(1, rs.size + 1)
            left_mean = csum / cnt
            right_cnt = rs.size - cnt
            with np.errstate(divide="ignore", invalid="ignore"):
                right_mean = (total - csum) / np.maximum(right_cnt, 1)
            gain = cnt * left_mean**2 + right_cnt * right_mean**2
            k = int(np.argmax(gain[:-1])) if rs.size > 1 else 0
            if best is None or gain[k] > best[0]:
                best = (gain[k], f, xs[k], left_mean[k], right_mean[k])
        _, f, thr, lm, rm = best
        pred = np.where(Xtr[:, f] <= thr, lm, rm)
        resid = resid - lr * pred
        stumps.append((f, thr, lr * lm, lr * rm))

    def predict(Xq: np.ndarray) -> np.ndarray:
        out = np.full(Xq.shape[0], base)
        for f, thr, lv, rv in stumps:
            out += np.where(Xq[:, f] <= thr, lv, rv)
        return out

    preds = np.empty_like(series)
    preds[: n_lags + 1] = series[: n_lags + 1].mean()
    preds[n_lags:] = predict(X)
    # only the held-out half is evaluated by the bench, but return full series
    return preds


def mape(y: np.ndarray, pred: np.ndarray) -> float:
    y, pred = np.asarray(y), np.asarray(pred)
    mask = y > 1e-9
    return float(np.mean(np.abs((pred[mask] - y[mask]) / y[mask])) * 100.0)


def mae(y: np.ndarray, pred: np.ndarray) -> float:
    return float(np.mean(np.abs(np.asarray(pred) - np.asarray(y))))
