"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On real hardware this runs the pjit-sharded train step on the production
mesh; on this CPU container it runs the same code path over the available
devices (mesh (1,1)) with smoke-scale configs. The dry-run
(``repro.launch.dryrun``) is the multi-pod proof; this is the runnable loop
(checkpointing included).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data import lm_batches, masked_audio_batches
from repro.models import init_params, param_shapes
from repro.training import (
    latest_step,
    load_checkpoint,
    make_optimizer,
    make_train_step,
    save_checkpoint,
)

from .sharding import named, opt_state_pspecs, param_pspecs


def make_local_mesh() -> jax.sharding.Mesh:
    n = len(jax.devices())
    model = 1
    data = n
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="codeqwen1.5-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full config (needs a real TPU pod)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_local_mesh()
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M mesh={dict(mesh.shape)}")

    opt = make_optimizer(cfg.name, lr=args.lr)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    start = 0
    if args.ckpt_dir and (s := latest_step(args.ckpt_dir)) is not None:
        params, opt_state, _ = load_checkpoint(args.ckpt_dir, s, params, opt_state)
        start = s
        step = jnp.asarray(s, jnp.int32)
        print(f"resumed from step {s}")

    if cfg.family == "audio":
        batches = masked_audio_batches(cfg.d_model, cfg.vocab, args.batch, args.seq)
    else:
        batches = lm_batches(cfg.vocab, args.batch, args.seq)

    pspec = param_pspecs(cfg, mesh, param_shapes(cfg))
    p_sh = named(mesh, pspec)
    o_sh = named(mesh, opt_state_pspecs(
        jax.eval_shape(lambda: opt_state), pspec, param_shapes(cfg)
    ))
    step_fn = jax.jit(
        make_train_step(cfg, opt, num_microbatches=1),
        in_shardings=(p_sh, o_sh, None, None),
        donate_argnums=(0, 1),
    )

    with mesh:
        params = jax.device_put(params, p_sh)
        for i in range(start, start + args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
            params, opt_state, step, metrics = step_fn(params, opt_state, step, batch)
            if i % args.log_every == 0 or i == start + args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                print(f"step {i:5d} loss={m['loss']:.4f} gnorm={m['grad_norm']:.2f}")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, start + args.steps, params, opt_state,
                        meta={"arch": cfg.name})
        print(f"checkpointed at {start + args.steps}")


if __name__ == "__main__":
    main()
