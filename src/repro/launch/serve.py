"""DiSCo serving launcher: ``python -m repro.launch.serve [--requests N]``.

Spins up a real device engine (tiny model) and a real server engine (larger
model behind a simulated network with queueing spikes), wires them into the
DiSCo scheduler, serves a request stream, and reports QoE/cost versus the
all-server and all-device baselines.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import paper_models
from repro.core import (
    CostModel,
    DiSCoScheduler,
    Endpoint,
    MigrationConfig,
    SingleEndpointPolicy,
)
from repro.models import init_params
from repro.serving import (
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    ServerEndpoint,
)


def build_stack(constraint: str = "server", budget: float = 0.5, seed: int = 0):
    dev_cfg, srv_cfg = paper_models.TINY_DEVICE, paper_models.TINY_SERVER
    dev_engine = InferenceEngine(dev_cfg, init_params(dev_cfg, jax.random.PRNGKey(0)), max_len=128)
    srv_engine = InferenceEngine(srv_cfg, init_params(srv_cfg, jax.random.PRNGKey(1)), max_len=128)
    dev_engine.warmup()
    srv_engine.warmup()

    if constraint == "device":
        cm = CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6)
    else:
        cm = CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12)

    rng = np.random.default_rng(seed)
    sched = DiSCoScheduler(
        cm,
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 500),
        prompt_length_samples=np.clip(rng.lognormal(2.5, 0.8, 500), 1, 96).astype(int),
        budget=budget,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.02),
    )
    disco = DiSCoServer(
        sched,
        DeviceEndpoint(dev_engine),
        ServerEndpoint(srv_engine, NetworkModel(rtt_mean=0.05, queue_spike_prob=0.15)),
        rng=np.random.default_rng(seed + 1),
    )
    return disco, dev_engine, srv_engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--budget", type=float, default=0.5)
    ap.add_argument("--constraint", choices=["server", "device"], default="server")
    args = ap.parse_args()

    disco, dev_engine, srv_engine = build_stack(args.constraint, args.budget)
    rng = np.random.default_rng(7)
    prompts = [
        rng.integers(0, 1024, size=int(n)).astype(np.int32)
        for n in np.clip(rng.lognormal(2.5, 0.8, args.requests), 2, 64)
    ]

    results = [disco.serve(p, args.max_new) for p in prompts]
    ttfts = np.array([r.ttft for r in results])
    costs = np.array([r.cost for r in results])
    migrated = sum(r.migrated for r in results)
    print(f"\nDiSCo ({args.constraint}-constrained, b={args.budget}):")
    print(f"  requests={len(results)}  migrated={migrated}")
    print(f"  TTFT   mean={ttfts.mean()*1e3:.1f}ms  p99={np.percentile(ttfts,99)*1e3:.1f}ms")
    print(f"  cost   mean={costs.mean():.3e}")
    winners = [r.winner.value for r in results]
    print(f"  winners: device={winners.count('device')} server={winners.count('server')}")


if __name__ == "__main__":
    main()
