"""DiSCo serving launcher: ``python -m repro.launch.serve [--requests N]``.

Spins up a real device engine (tiny model) and a real server stack (larger
model inside a contended continuous-batching scheduler behind a simulated
network), wires them into the event-driven DiSCo runtime, replays an arrival
trace of concurrent ``Request`` objects (each carrying its own sampler,
seed, and SLO contract), and reports QoE/cost/wasted compute.

Migration note: the old tuple API — ``serve_many([(arrival, prompt,
max_new)])`` — was replaced by the first-class request contract:
``serve_many([Request(prompt, max_new, arrival=..., sampler=..., slo=...)])``
(see ``repro.serving.request``).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import paper_models
from repro.core import (
    CostModel,
    DiSCoScheduler,
    MigrationConfig,
)
from repro.models import init_params
from repro.serving import (
    SLO,
    BatchedServer,
    DeviceEndpoint,
    DiSCoServer,
    InferenceEngine,
    NetworkModel,
    Request,
    ServerEndpoint,
)
from repro.sim.traces import poisson_arrivals


def build_stack(constraint: str = "server", budget: float = 0.5, seed: int = 0,
                max_slots: int = 2, cancel_losers: bool = True):
    """Build the full DiSCo stack: per-user device engine + shared contended
    BatchedServer. Returns ``(disco, device_engine, batched_server)``."""
    dev_cfg, srv_cfg = paper_models.TINY_DEVICE, paper_models.TINY_SERVER
    dev_engine = InferenceEngine(dev_cfg, init_params(dev_cfg, jax.random.PRNGKey(0)), max_len=128)
    # 128 covers migration replays: prompt (<=64) + generated prefix buckets
    dev_engine.warmup(prompt_lens=(32, 64, 128))
    server = BatchedServer(
        srv_cfg, init_params(srv_cfg, jax.random.PRNGKey(1)),
        max_slots=max_slots, max_len=128,
    )
    server.warmup(prompt_lens=(32, 64, 128))

    if constraint == "device":
        cm = CostModel(1e-7, 6e-7, 900.0, 800.0, exchange_rate=5e-6)
    else:
        cm = CostModel(1e-4, 6e-4, 900.0, 800.0, exchange_rate=1e-12)

    rng = np.random.default_rng(seed)
    sched = DiSCoScheduler(
        cm,
        server_ttft_samples=rng.lognormal(np.log(0.3), 0.5, 500),
        prompt_length_samples=np.clip(rng.lognormal(2.5, 0.8, 500), 1, 96).astype(int),
        budget=budget,
        migration=MigrationConfig(consumption_rate=30.0, network_rtt=0.02),
    )
    disco = DiSCoServer(
        sched,
        DeviceEndpoint(dev_engine),
        ServerEndpoint(server, NetworkModel(rtt_mean=0.05)),
        rng=np.random.default_rng(seed + 1),
        cancel_losers=cancel_losers,
    )
    return disco, dev_engine, server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--budget", type=float, default=0.5)
    ap.add_argument("--constraint", choices=["server", "device"], default="server")
    ap.add_argument("--mean-interval", type=float, default=0.05,
                    help="mean Poisson inter-arrival in virtual seconds "
                         "(smaller = more server contention)")
    ap.add_argument("--ttft-deadline", type=float, default=0.5,
                    help="per-request TTFT SLO deadline in virtual seconds "
                         "(feeds deadline-aware admission + QoE scoring)")
    args = ap.parse_args()

    disco, dev_engine, server = build_stack(args.constraint, args.budget)
    rng = np.random.default_rng(7)
    arrivals = poisson_arrivals(rng, args.requests, args.mean_interval)
    slo = SLO(ttft_deadline=args.ttft_deadline)
    requests = [
        Request(rng.integers(0, 1024, size=int(n)).astype(np.int32),
                args.max_new, arrival=float(a), slo=slo)
        for a, n in zip(arrivals, np.clip(rng.lognormal(2.5, 0.8, args.requests), 2, 64))
    ]

    results = disco.serve_many(requests)
    ttfts = np.array([r.ttft for r in results])
    costs = np.array([r.cost for r in results])
    wasted = sum(r.wasted_tokens for r in results)
    generated = sum(r.generated_tokens for r in results)
    migrated = sum(r.migrated for r in results)
    qoe = np.array([r.qoe.qoe_score for r in results])
    attained = sum(r.qoe.slo_attained for r in results)
    print(f"\nDiSCo ({args.constraint}-constrained, b={args.budget}, "
          f"{args.requests} concurrent requests):")
    print(f"  migrated={migrated}  wasted tokens={wasted}/{generated}")
    print(f"  TTFT   mean={ttfts.mean()*1e3:.1f}ms  p99={np.percentile(ttfts,99)*1e3:.1f}ms")
    print(f"  QoE    mean={qoe.mean():.3f}  slo_attained={attained}/{len(results)}"
          f"  (deadline={args.ttft_deadline*1e3:.0f}ms)")
    print(f"  cost   mean={costs.mean():.3e}")
    winners = [r.winner.value for r in results]
    print(f"  winners: device={winners.count('device')} server={winners.count('server')}")


if __name__ == "__main__":
    main()
