import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without real
hardware.

For every (architecture × input shape × mesh) combination this lowers and
compiles the appropriate step function — train_step (train_4k), prefill_step
(prefill_32k) or serve/decode_step (decode_32k, long_500k) — against
ShapeDtypeStruct inputs (no allocation), then records:

  * compiled.memory_analysis()  (per-device bytes: proves it fits 16 GiB)
  * compiled.cost_analysis()    (per-device HLO FLOPs / bytes accessed)
  * collective bytes parsed from the optimized HLO text, by collective type

into experiments/dryrun/<arch>__<shape>__<mesh>.json for §Roofline.

The XLA_FLAGS line above MUST run before any jax import: jax locks the
device count at first init. Do not set it globally — smoke tests and benches
see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, input_specs, shape_supported
from repro.models import decode_step, init_params, param_shapes, prefill
from repro.models.config import ModelConfig
from repro.training import make_optimizer, make_train_step

from .analytic import analytic_costs
from .mesh import HW, make_production_mesh, mesh_batch_axes
from .sharding import (
    batch_pspecs,
    cache_pspecs,
    named,
    opt_state_pspecs,
    param_pspecs,
)

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _line_output_bytes(lhs: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _loop_depth(line: str) -> int:
    """Scan-nesting depth of an HLO op, from its op_name metadata path.

    XLA preserves the jax op_name trace: ops inside a lax.scan/while carry
    "/while/body/" path segments — one per nesting level. XLA's
    cost_analysis counts while bodies ONCE (verified empirically), so
    collective bytes must be scaled by the enclosing loops' trip counts.
    """
    m = re.search(r'op_name="([^"]*)"', line)
    if not m:
        return 0
    return m.group(1).count("/while/")


def depth_multipliers(cfg: ModelConfig, kind: str, seq: int) -> list[float]:
    """Trip-count multiplier per loop depth (cumulative), from the known
    step-function structure:

      train:   [microbatch scan]? -> layer scan -> (SSD chunk scan)
      prefill: layer scan -> blockwise-attn q-map / SSD chunks -> kv scan
      decode:  layer scan
    """
    L = cfg.n_layers
    if kind == "train":
        levels = ([cfg.num_microbatches] if cfg.num_microbatches > 1 else []) + [L]
        if cfg.has_ssm:
            levels.append(max(seq // cfg.ssm_chunk, 1))
    elif kind == "prefill":
        levels = [L]
        inner = []
        if cfg.has_attention and seq > 4096:
            inner = [seq // 512, seq // 1024]      # q-block map, kv scan
        if cfg.has_ssm:
            inner = [max(max(seq // cfg.ssm_chunk, 1), inner[0] if inner else 1)]
        levels.extend(inner)
    else:
        levels = [L]
    cum, out = 1.0, []
    for t in levels:
        cum *= max(t, 1)
        out.append(cum)
    return out


def collective_stats(hlo_text: str, multipliers: list[float]) -> dict:
    """Sum output bytes of every collective op in the optimized HLO (the
    partitioned per-device module => per-device traffic), scaling each op by
    the trip count of its enclosing scan loops (see depth_multipliers)."""
    stats = {c: {"count": 0, "bytes": 0, "bytes_raw": 0} for c in _COLLECTIVES}
    by_depth: dict[int, int] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        for c in _COLLECTIVES:
            # match op name at the call position, skip "-done" halves of
            # async pairs (the "-start" carries the shape)
            if re.match(rf"(\w+\[|\()?.*\b{c}(-start)?\(", rhs) and f"{c}-done" not in rhs:
                depth = _loop_depth(line)
                mult = (
                    multipliers[min(depth, len(multipliers)) - 1]
                    if depth > 0 and multipliers
                    else 1.0
                )
                raw = _line_output_bytes(rhs.split(c)[0] + " " + lhs)
                stats[c]["count"] += 1
                stats[c]["bytes_raw"] += raw
                stats[c]["bytes"] += int(raw * mult)
                by_depth[depth] = by_depth.get(depth, 0) + int(raw * mult)
                break
    stats["total_bytes"] = sum(v["bytes"] for v in stats.values() if isinstance(v, dict))
    stats["total_bytes_raw"] = sum(
        v["bytes_raw"] for v in stats.values() if isinstance(v, dict)
    )
    stats["total_count"] = sum(v["count"] for v in stats.values() if isinstance(v, dict))
    stats["bytes_by_depth"] = by_depth
    return stats


def _model_flops(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch  # decode: one token per sequence


def build_step(cfg: ModelConfig, kind: str, seq_len: int):
    if kind == "train":
        opt = make_optimizer(cfg.name)
        step_fn = make_train_step(cfg, opt)
        return step_fn, opt
    if kind == "prefill":
        def prefill_fn(params, inputs):
            return prefill(params, cfg, inputs, max_len=seq_len)
        return prefill_fn, None
    def decode_fn(params, cache, token):
        return decode_step(params, cfg, cache, token)
    return decode_fn, None


# §Perf hillclimb variants: config and/or sharding overrides measured against
# the baselines. See EXPERIMENTS.md §Perf for the hypothesis->result log.
import dataclasses as _dc

VARIANTS = {
    # inference should not remat (training-only concern); removes the
    # checkpoint-induced copies/resharding in prefill.
    "noremat": dict(cfg=lambda c: _dc.replace(c, remat=False)),
    # small models don't need tensor parallelism: replicate params, shard
    # batch only => zero per-layer collectives.
    "dp-only": dict(dp_only=True),
    "dp-noremat": dict(cfg=lambda c: _dc.replace(c, remat=False), dp_only=True),
    # MLA weight absorption: decode attends in compressed c_kv space.
    "mla-absorb": dict(cfg=lambda c: _dc.replace(c, mla_absorb=True)),
    # distributed flash-decode over seq-sharded KV (shard_map combine).
    "shmap-decode": dict(shmap_decode=True),
    # prefill cache emitted batch-sharded only (replicated over "model"):
    # prevents the cache's seq-sharding from propagating backwards into the
    # blockwise-attention kv scan (per-block all-gathers). Valid when the
    # batch-sharded cache fits HBM.
    "cache-batch-only": dict(cache_batch_only=True),
    "dp-cache-noremat": dict(
        cfg=lambda c: _dc.replace(c, remat=False), dp_only=True, cache_batch_only=True,
    ),
    # 256-way tensor parallelism over BOTH mesh axes for the big matrices:
    # the 340B-class decode param shard must drop below HBM (42.5 GiB at
    # TP=16 -> ~3 GiB at TP=256); 1-token activations make the extra
    # row-parallel all-reduces negligible.
    "tp-wide": dict(tp_wide=True),
    "tp-wide-shmap": dict(tp_wide=True, shmap_decode=True),
    # MLA prefill residual: the low-rank factors are tiny (2.6 MB) — replicate
    # them so the per-token expansion never contracts a sharded dim.
    "mla-repl-factors": dict(mla_repl=True, cache_batch_only=True),
    # MLA iteration 2: seq-sharded compressed cache + shard_map flash combine
    "mla-absorb-shmap": dict(
        cfg=lambda c: _dc.replace(c, mla_absorb=True),
        shmap_decode=True, cache_seq_shard=True,
    ),
    # iteration 3: row-parallel kv projections (kv=8 unshardable over model),
    # one-hot embedding (no table gather), FFN over both axes
    "tp-wide2-shmap": dict(
        cfg=lambda c: _dc.replace(c, embed_onehot=True),
        tp_wide2=True, shmap_decode=True,
    ),
    # combined winners
    "mla-absorb-noremat": dict(cfg=lambda c: _dc.replace(c, mla_absorb=True, remat=False)),
    "shmap-noremat": dict(cfg=lambda c: _dc.replace(c, remat=False), shmap_decode=True),
}

_TP_WIDE_RULES = {
    "embed": [{0: ("data", "model")}, {0: "model"}],
    "lm_head": [{1: ("data", "model")}, {1: "model"}],
    "wq": [{1: "model", 2: "data"}, {1: "model"}],
    "wk": [{2: "data"}, {}],
    "wv": [{2: "data"}, {}],
    "wo": [{0: "model", 1: "data"}, {0: "model"}],
    "w_gate": [{1: ("data", "model")}, {1: "model"}],
    "w_up": [{1: ("data", "model")}, {1: "model"}],
    "w_down": [{0: ("data", "model")}, {0: "model"}],
}

_TP_WIDE2_RULES = {
    "embed": [{0: ("data", "model")}, {0: "model"}],
    "lm_head": [{1: ("data", "model")}, {1: "model"}],
    "wq": [{1: "model"}],             # 96 heads / 16
    "wk": [{0: "model"}],             # row-parallel: kv heads unshardable
    "wv": [{0: "model"}],
    "wo": [{0: "model"}],
    "w_gate": [{1: ("data", "model")}, {1: "model"}],
    "w_up": [{1: ("data", "model")}, {1: "model"}],
    "w_down": [{0: ("data", "model")}, {0: "model"}],
}


def _tp_wide_pspecs(cfg, mesh, pshapes, rules=None):
    from .sharding import _spec_with_fallbacks
    from jax.sharding import PartitionSpec as _P
    rules = rules or _TP_WIDE_RULES
    base = param_pspecs(cfg, mesh, pshapes)
    for k, shape in pshapes["layers"].items():
        if k in rules:
            spec = _spec_with_fallbacks(mesh, shape[1:], *rules[k])
            base["layers"][k] = _P(None, *spec)
    for k in ("embed", "lm_head"):
        if k in pshapes:
            base[k] = _spec_with_fallbacks(mesh, pshapes[k], *rules[k])
    return base


def _batch_only_cache_spec(cache_shapes, mesh):
    from jax.sharding import PartitionSpec as _P
    from .mesh import mesh_batch_axes as _mba
    baxes = _mba(mesh)
    import math as _m
    bsz = _m.prod(mesh.shape[a] for a in baxes)
    out = {}
    for k, leaf in cache_shapes.items():
        if k == "lengths":
            out[k] = _P(None)
            continue
        b_ax = baxes if leaf.shape[1] % bsz == 0 and leaf.shape[1] >= bsz else None
        out[k] = _P(None, b_ax, *([None] * (len(leaf.shape) - 2)))
    return out


def dryrun_one(arch: str, shape_name: str, multi_pod: bool, variant: str | None = None) -> dict:
    cfg = get_config(arch)
    vspec = VARIANTS.get(variant, {}) if variant else {}
    if "cfg" in vspec:
        cfg = vspec["cfg"](cfg)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = shape_supported(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    base = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant or "baseline",
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not ok:
        return {**base, "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    specs = input_specs(cfg, shape)
    pshapes = param_shapes(cfg)
    params_s = jax.eval_shape(functools.partial(init_params, cfg), jax.random.PRNGKey(0))
    if vspec.get("dp_only"):
        from jax.sharding import PartitionSpec as _P
        pspec = jax.tree.map(
            lambda s: _P(*([None] * len(s))) if isinstance(s, tuple) else s,
            pshapes_tree(pshapes),
            is_leaf=lambda x: isinstance(x, tuple),
        )
    elif vspec.get("tp_wide"):
        pspec = _tp_wide_pspecs(cfg, mesh, pshapes)
    elif vspec.get("tp_wide2"):
        pspec = _tp_wide_pspecs(cfg, mesh, pshapes, rules=_TP_WIDE2_RULES)
    elif vspec.get("mla_repl"):
        from jax.sharding import PartitionSpec as _P
        pspec = param_pspecs(cfg, mesh, pshapes)
        for k in ("wkv_b", "wq_b", "wkv_a", "wq_a"):
            if k in pspec["layers"]:
                n = len(pshapes["layers"][k])
                pspec["layers"][k] = _P(*([None] * n))
    else:
        pspec = param_pspecs(cfg, mesh, pshapes)
    p_sh = named(mesh, pspec)

    import contextlib
    from repro.models.distributed import decode_context
    dist_ctx = (
        decode_context(mesh, seq_axis="model", batch_axes=mesh_batch_axes(mesh))
        if vspec.get("shmap_decode")
        else contextlib.nullcontext()
    )

    t0 = time.time()
    with mesh, dist_ctx:
        if shape.kind == "train":
            step_fn, opt = build_step(cfg, "train", shape.seq_len)
            opt_s = jax.eval_shape(opt.init, params_s)
            ospec = opt_state_pspecs(opt_s, pspec, pshapes_tree(pshapes))
            o_sh = named(mesh, ospec)
            b_spec = batch_pspecs(cfg, mesh, specs)
            b_sh = named(mesh, b_spec)
            step0 = jax.ShapeDtypeStruct((), jnp.int32)
            from jax.sharding import PartitionSpec as P
            scalar = named(mesh, P())
            metrics_spec = jax.tree.map(
                lambda _: scalar,
                jax.eval_shape(step_fn, params_s, opt_s, step0, specs)[3],
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, o_sh, scalar, b_sh),
                out_shardings=(p_sh, o_sh, scalar, metrics_spec),
                donate_argnums=(0, 1),  # params/opt updated in place
            )
            lowered = jitted.lower(params_s, opt_s, step0, specs)
        elif shape.kind == "prefill":
            step_fn, _ = build_step(cfg, "prefill", shape.seq_len)
            b_spec = batch_pspecs(cfg, mesh, specs)
            b_sh = named(mesh, b_spec)
            out_shape = jax.eval_shape(step_fn, params_s, specs["inputs"])
            if vspec.get("cache_batch_only"):
                cache_spec = _batch_only_cache_spec(out_shape[1], mesh)
            else:
                cache_spec = cache_pspecs(cfg, mesh, out_shape[1])
            from jax.sharding import PartitionSpec as P
            baxes = mesh_batch_axes(mesh)
            logits_spec = P(
                baxes if shape.global_batch % _ax(mesh, baxes) == 0 else None, None
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, b_sh["inputs"]),
                out_shardings=(
                    named(mesh, logits_spec),
                    named(mesh, cache_spec),
                ),
            )
            lowered = jitted.lower(params_s, specs["inputs"])
        else:  # decode
            step_fn, _ = build_step(cfg, "decode", shape.seq_len)
            cache_spec = cache_pspecs(cfg, mesh, specs["cache"])
            if vspec.get("cache_seq_shard"):
                from jax.sharding import PartitionSpec as _P
                for _k in ("ckv", "krope"):
                    if _k in cache_spec:
                        old = list(cache_spec[_k])
                        cache_spec[_k] = _P(old[0], old[1], "model", None)
            c_sh = named(mesh, cache_spec)
            from jax.sharding import PartitionSpec as P
            baxes = mesh_batch_axes(mesh)
            tok_ax = baxes if shape.global_batch % _ax(mesh, baxes) == 0 else None
            t_sh = named(mesh, P(tok_ax))
            logits_spec = named(mesh, P(tok_ax, None))
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, c_sh, t_sh),
                out_shardings=(logits_spec, c_sh),
                donate_argnums=(1,),   # in-place cache update (serving)
            )
            lowered = jitted.lower(params_s, specs["cache"], specs["token"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        }
        print(ma)
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})

    mults = depth_multipliers(cfg, shape.kind, shape.seq_len)
    coll = collective_stats(compiled.as_text(), mults)

    # raw HLO numbers (while bodies counted once — see analytic.py docstring)
    flops_dev_raw = float(cost.get("flops", 0.0))
    bytes_dev_raw = float(cost.get("bytes accessed", 0.0))
    model_shard = 1 if vspec.get("dp_only") else mesh.shape.get("model", 1)
    ac = analytic_costs(
        cfg, shape.kind, shape.global_batch, shape.seq_len, n_dev,
        model_shard=model_shard,
    )
    model_fl = _model_flops(cfg, shape.kind, shape.global_batch, shape.seq_len)
    compute_s = ac.flops_per_device / HW.PEAK_FLOPS_BF16
    memory_s = ac.bytes_per_device / HW.HBM_BW
    collective_s = coll["total_bytes"] / HW.ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    return {
        **base,
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": ac.flops_per_device,
        "bytes_per_device": ac.bytes_per_device,
        "hlo_flops_per_device_raw": flops_dev_raw,
        "hlo_bytes_per_device_raw": bytes_dev_raw,
        "loop_multipliers": mults,
        "collective_bytes_per_device": coll["total_bytes"],
        "collectives": coll,
        "memory": mem,
        "model_flops_total": model_fl,
        "useful_flops_ratio": model_fl / ac.flops_total if ac.flops_total else None,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
        },
    }


def _ax(mesh, axes) -> int:
    import math as _m
    if axes is None:
        return 1
    if isinstance(axes, tuple):
        return _m.prod(mesh.shape[a] for a in axes)
    return mesh.shape[axes]


def pshapes_tree(pshapes: dict):
    """param_shapes dict (tuples) -> tree of shape-tuples matching params."""
    out = {}
    for k, v in pshapes.items():
        out[k] = {kk: vv for kk, vv in v.items()} if k == "layers" else v
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="every supported (arch×shape)")
    ap.add_argument("--variant", choices=sorted(VARIANTS), default=None)
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    combos = []
    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                combos.append((a, s, m))

    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for arch, shape, m in combos:
        tag = f"{arch}__{shape}__{m}" + (f"__{args.variant}" if args.variant else "")
        out_path = os.path.join(args.out_dir, tag + ".json")
        print(f"=== dryrun {tag}", flush=True)
        try:
            rec = dryrun_one(arch, shape, multi_pod=(m == "multi"), variant=args.variant)
        except Exception as e:
            traceback.print_exc()
            rec = {
                "arch": arch, "shape": shape, "mesh": m,
                "status": "error", "error": f"{type(e).__name__}: {e}",
            }
            failures += 1
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
        print(json.dumps({k: rec.get(k) for k in (
            "status", "compile_s", "flops_per_device",
            "collective_bytes_per_device", "reason", "error")}), flush=True)
    print(f"done: {len(combos) - failures}/{len(combos)} ok")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
