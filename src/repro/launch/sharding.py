"""Sharding rules: PartitionSpecs for params, optimizer states, caches and
batches on the production mesh.

Tensor-parallel layout (Megatron-style) on the "model" axis with per-tensor
divisibility fallbacks:

  embed (V,d)            -> vocab on model (fallback: d on model)
  lm_head (d,V)          -> V on model (fallback: d on model)
  attention wq/wk/wv     -> heads on model (fallback: replicate)
  attention wo           -> heads on model
  MLA low-rank factors   -> rank on model
  dense FFN w_up/w_gate  -> d_ff on model; w_down: d_ff on model (row-parallel)
  MoE expert weights     -> experts on model (expert parallelism; 128/64 both
                            divide 16); router replicated
  SSM in/out projections -> row/col parallel over model
  norms / scalar vectors -> replicated

Caches (decode): batch over ("pod","data") when divisible; KV heads on
"model" when divisible, else the sequence axis takes every still-unused mesh
axis (this is what lets nemotron's kv=8 < 16 cache and the long_500k
batch=1 cache fit). Batches: leading dim over ("pod","data").

These are BASELINE rules — §Perf hillclimbing changes them per-experiment.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

from .mesh import mesh_batch_axes

__all__ = [
    "param_pspecs",
    "opt_state_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "named",
]


def _axsize(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return math.prod(mesh.shape[a] for a in name)
    return mesh.shape[name]


def _fit(mesh: Mesh, dim_size: int, axis) -> bool:
    return dim_size % _axsize(mesh, axis) == 0


def _spec_with_fallbacks(mesh: Mesh, shape: tuple, *rules) -> P:
    """Each rule is a dict {dim_index: axis}; the first rule whose every
    assignment divides evenly wins; otherwise fully replicated."""
    for rule in rules:
        ok = all(_fit(mesh, shape[d], ax) for d, ax in rule.items())
        if ok:
            entries = [rule.get(d) for d in range(len(shape))]
            return P(*entries)
    return P(*([None] * len(shape)))


# per-param rules: list of {dim: axis} fallbacks, dims indexed WITHOUT the
# leading layer-stacking dim (added automatically for layer params)
_RULES: dict[str, list[dict[int, str]]] = {
    "embed": [{0: "model"}, {1: "model"}],
    "lm_head": [{1: "model"}, {0: "model"}],
    "in_proj": [{1: "model"}],
    # GQA attention
    "wq": [{1: "model"}, {0: "model"}],
    "wk": [{1: "model"}, {0: "model"}],
    "wv": [{1: "model"}, {0: "model"}],
    "wo": [{0: "model"}, {2: "model"}],
    # MLA
    "wq_a": [{1: "model"}],
    "wq_b": [{0: "model"}],
    "wkv_a": [{}],
    "wkv_b": [{0: "model"}],
    # FFN
    "w_gate": [{1: "model"}],
    "w_up": [{1: "model"}],
    "w_down": [{0: "model"}],
    # MoE
    "router": [{}],
    "moe_gate": [{0: "model"}, {2: "model"}],
    "moe_up": [{0: "model"}, {2: "model"}],
    "moe_down": [{0: "model"}, {1: "model"}],
    # SSM
    "ssm_in": [{0: "model"}],       # row-parallel (contracting dim sharded)
    "ssm_out": [{1: "model"}],      # col-parallel output
    "conv_w": [{}],
    "conv_b": [{}],
}

_LAYER_STACKED_EXEMPT = {"embed", "lm_head", "in_proj", "final_norm"}


def param_pspecs(cfg: ModelConfig, mesh: Mesh, shapes: dict[str, Any]) -> dict:
    """PartitionSpec tree matching ``models.param_shapes(cfg)`` layout.

    ``shapes``: the param_shapes(cfg) dict (tuples), so divisibility checks
    run against real dimensions.
    """
    out: dict[str, Any] = {}
    for name, shape in shapes.items():
        if name == "layers":
            out["layers"] = {}
            for k, s in shape.items():
                inner = s[1:]  # strip layer dim
                rules = _RULES.get(k)
                if rules is None:
                    spec = P(*([None] * len(inner)))
                else:
                    spec = _spec_with_fallbacks(mesh, inner, *rules)
                out["layers"][k] = P(None, *spec)
        else:
            rules = _RULES.get(name)
            if rules is None:
                spec = P(*([None] * len(shape)))
            else:
                spec = _spec_with_fallbacks(mesh, shape, *rules)
            out[name] = spec
    return out


def opt_state_pspecs(opt_state_shapes: Any, pspecs: dict, params_shapes: Any) -> Any:
    """Optimizer-state specs derived from param specs.

    AdamW m/v mirror the param layout. Adafactor vr drops the last dim's
    entry, vc drops the second-to-last. Works by structural matching.
    """

    def match(state_leaf_shape, pshape, pspec: P) -> P:
        if tuple(state_leaf_shape) == tuple(pshape):
            return pspec
        entries = list(pspec) + [None] * (len(pshape) - len(pspec))
        if tuple(state_leaf_shape) == tuple(pshape[:-1]):      # vr
            return P(*entries[:-1])
        if tuple(state_leaf_shape) == tuple(pshape[:-2] + pshape[-1:]):  # vc
            return P(*(entries[:-2] + entries[-1:]))
        return P(*([None] * len(state_leaf_shape)))

    def walk(state_node, pspec_node, pshape_node):
        if isinstance(state_node, dict):
            keys = set(state_node)
            if keys <= {"m", "v"}:  # adamw: same tree as params
                return {k: walk(v, pspec_node, pshape_node) for k, v in state_node.items()}
            if keys <= {"v", "vr", "vc"} and not isinstance(
                next(iter(state_node.values())), dict
            ):
                return {
                    k: match(v.shape, pshape_node, pspec_node)
                    for k, v in state_node.items()
                }
            return {
                k: walk(v, pspec_node[k], pshape_node[k]) for k, v in state_node.items()
            }
        return match(state_node.shape, pshape_node, pspec_node)

    return walk(opt_state_shapes, pspecs, params_shapes)


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_shapes: dict) -> dict:
    """Leading (batch) dim over ("pod","data") when divisible."""
    baxes = mesh_batch_axes(mesh)
    out = {}
    for k, v in batch_shapes.items():
        shape = v.shape
        lead = baxes if shape and _fit(mesh, shape[0], baxes) else None
        out[k] = P(lead, *([None] * (len(shape) - 1))) if shape else P()
    return out


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shapes: dict) -> dict:
    """Decode-cache specs: see module docstring."""
    baxes = mesh_batch_axes(mesh)
    out: dict[str, P] = {}
    for name, leaf in cache_shapes.items():
        shape = leaf.shape
        if name == "lengths":
            out[name] = P(None)
            continue
        # (L, B, ...) layout
        used: list = []
        b_ax = None
        if _fit(mesh, shape[1], baxes) and shape[1] >= _axsize(mesh, baxes):
            b_ax = baxes
            used += list(baxes)
        entries: list = [None, b_ax]
        if name in ("k", "v"):
            L, B, K, S, hd = shape  # head-major cache layout
            if _fit(mesh, K, "model"):
                entries += ["model", None, None]
                used.append("model")
            else:
                free = tuple(a for a in mesh.axis_names if a not in used)
                seq_ax = _seq_axes(mesh, S, free)
                entries += [None, seq_ax, None]
        elif name == "ckv":
            L, B, S, r = shape
            if _fit(mesh, r, "model"):
                entries += [None, "model"]
            else:
                free = tuple(a for a in mesh.axis_names if a not in used)
                entries += [_seq_axes(mesh, S, free), None]
        elif name == "krope":
            L, B, S, r = shape
            free = tuple(a for a in mesh.axis_names if a not in used)
            entries += [_seq_axes(mesh, S, free), None]
        elif name == "ssm_state":
            L, B, H, Pp, N = shape
            if _fit(mesh, H, "model"):
                entries += ["model", None, None]
            elif _fit(mesh, Pp, "model"):
                entries += [None, "model", None]
            else:
                entries += [None, None, None]
        elif name == "conv_state":
            L, B, W, C = shape
            entries += [None, "model" if _fit(mesh, C, "model") else None]
        else:
            entries += [None] * (len(shape) - 2)
        out[name] = P(*entries)
    return out


def _seq_axes(mesh: Mesh, seq: int, free: tuple):
    """Assign the largest prefix of free axes whose product divides seq."""
    chosen = []
    for a in free:
        cand = chosen + [a]
        if seq % math.prod(mesh.shape[x] for x in cand) == 0:
            chosen = cand
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def named(mesh: Mesh, spec_tree: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
