"""Roofline report generator: reads experiments/dryrun/*.json and emits the
§Dry-run and §Roofline markdown tables for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


KIND_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(dir_: str, mesh: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    recs.sort(key=lambda r: (r["arch"], KIND_ORDER.get(r["shape"], 9)))
    return recs


def _fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/HLO | peak GiB/dev | what would move the bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        hint = _bottleneck_hint(r)
        peak = r["memory"].get("peak_bytes_est", 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} | "
            f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | "
            f"**{rl['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{peak:.1f} | {hint} |"
        )
    return "\n".join(lines)


def _bottleneck_hint(r: dict) -> str:
    dom = r["roofline"]["dominant"]
    coll = r.get("collectives", {})
    depth = coll.get("bytes_by_depth", {})
    in_loop = sum(v for k, v in depth.items() if str(k) != "0")
    if dom == "collective":
        if in_loop > 0.7 * max(coll.get("total_bytes", 1), 1):
            return ("per-layer weight/activation gathers dominate — persist "
                    "gathered weights or switch the small-model path to pure "
                    "data parallelism")
        return "gradient all-reduce — overlap with backward or reduce-scatter"
    if dom == "memory":
        if r["shape"] in ("decode_32k", "long_500k"):
            return "KV-cache traffic — MLA/window shrinks reads; batch across model axis"
        return "activation traffic — fewer remat passes, fused norms, larger microbatch"
    return "MXU-bound — good; raise arithmetic intensity only via larger batch"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | status | devs | params | compile s | GiB/dev (args+tmp) | "
        "collective GiB/dev (by type) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:60]}…) | | | | | |"
            )
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        mem = r["memory"]
        args = mem.get("argument_bytes", 0) / 2**30
        tmp = mem.get("temp_bytes", 0) / 2**30
        coll = r["collectives"]
        per_type = ", ".join(
            f"{k.split('-')[1] if '-' in k else k}:{v['bytes']/2**30:.2f}"
            for k, v in coll.items()
            if isinstance(v, dict) and v.get("bytes")
        )
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['n_devices']} | "
            f"{r['params']/1e9:.1f}B | {r['compile_s']:.1f} | "
            f"{args:.2f}+{tmp:.2f} | {per_type or '-'} |"
        )
    return "\n".join(lines)


def hillclimb_candidates(recs: list[dict]) -> list[dict]:
    """worst roofline fraction / most collective-bound / most representative."""
    ok = [r for r in recs if r["status"] == "ok"]
    def total(r):
        rl = r["roofline"]
        return rl["compute_s"] + rl["memory_s"] + rl["collective_s"]
    worst = max(ok, key=lambda r: max(r["roofline"].values(), key=lambda v: v if isinstance(v, float) else 0) if False else total(r))
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    return [worst, coll]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    print(f"## Dry-run ({args.mesh}-pod)\n")
    print(dryrun_table(recs))
    print(f"\n## Roofline ({args.mesh}-pod)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
