"""Production mesh construction.

Single pod: 16×16 = 256 TPU v5e chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis extends data parallelism across the DCN/ICI boundary.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. The dry-run entrypoint force-creates 512 host devices via
XLA_FLAGS *before* any jax import (see dryrun.py).
"""
from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "mesh_batch_axes", "HW"]


class HW:
    """TPU v5e hardware constants for the roofline model."""

    PEAK_FLOPS_BF16 = 197e12       # per chip, FLOP/s
    HBM_BW = 819e9                 # per chip, B/s
    ICI_BW = 50e9                  # per link, B/s
    HBM_BYTES = 16 * 2**30         # 16 GiB per chip


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the {'multi' if multi_pod else 'single'}-pod "
            f"mesh, found {len(devices)}. Set "
            'XLA_FLAGS="--xla_force_host_platform_device_count=512" BEFORE '
            "importing jax (dryrun.py does this)."
        )
    # axis_types / AxisType only exist on newer jax; Auto is the default there
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = {"axis_types": (axis_type.Auto,) * len(axes)} if axis_type else {}
    return jax.make_mesh(shape, axes, devices=devices[:n], **kwargs)


def mesh_batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The axes that shard the batch dimension: ("pod","data") or ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
