"""Analytic FLOPs / HBM-byte models for the roofline report.

WHY ANALYTIC: XLA's ``compiled.cost_analysis()`` counts a while-loop body
ONCE regardless of trip count (verified empirically — a 10-step scan of
512³ matmuls reports exactly one matmul's FLOPs). Our step functions are
scan-over-layers (× scan-over-microbatches × blockwise-attention scans), so
raw HLO numbers undercount by 1-3 orders of magnitude depending on
architecture — and *differently* per architecture, which would corrupt any
cross-arch comparison. The dry-run records the raw HLO numbers for
reference; the roofline terms use these analytic models, which are exact
for the matmul-dominated parts (we control every architecture's math).

Conventions: 1 MAC = 2 FLOPs. Causal attention counts the triangular half.
Bytes are per-device HBM traffic per step: parameter reads (sharded resident
size × passes), KV/state cache traffic, and activation write+read traffic.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

__all__ = ["step_flops", "step_bytes", "AnalyticCosts", "analytic_costs"]


@dataclasses.dataclass(frozen=True)
class AnalyticCosts:
    flops_total: float          # whole-step, all devices
    bytes_per_device: float     # HBM traffic per device
    flops_per_device: float


def _attn_flops_layer(cfg: ModelConfig, batch: int, seq: int, window_layers_frac: float = None) -> float:
    """Attention score+value FLOPs for one layer, full sequence."""
    if not cfg.has_attention:
        return 0.0
    h = cfg.n_heads
    hd = cfg.resolved_head_dim
    vd = cfg.resolved_v_head_dim
    def ctx(kv_span: float) -> float:
        # qk^T and p·v, 2 FLOPs per MAC each
        return 2.0 * batch * seq * kv_span * h * (hd + vd)
    if cfg.attention == "full":
        return ctx(seq / 2 if cfg.causal else seq)
    if cfg.attention == "window":
        return ctx(min(cfg.window, seq))
    # pattern: 1/global_interval layers are global
    g = 1.0 / cfg.global_interval
    return g * ctx(seq / 2) + (1 - g) * ctx(min(cfg.window, seq))


def _proj_flops_layer(cfg: ModelConfig, tokens: float) -> float:
    """QKV/O, FFN/MoE, SSM projection FLOPs for one layer (2 FLOPs/MAC)."""
    d = cfg.d_model
    fl = 0.0
    if cfg.has_attention:
        if cfg.use_mla:
            hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            q_in = cfg.q_lora_rank or d
            if cfg.q_lora_rank:
                fl += 2 * tokens * d * cfg.q_lora_rank
            fl += 2 * tokens * q_in * cfg.n_heads * hd
            fl += 2 * tokens * d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            fl += 2 * tokens * cfg.kv_lora_rank * cfg.n_heads * (
                cfg.qk_nope_head_dim + cfg.v_head_dim
            )
            fl += 2 * tokens * cfg.n_heads * cfg.v_head_dim * d
        else:
            hd = cfg.resolved_head_dim
            fl += 2 * tokens * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
            fl += 2 * tokens * cfg.n_heads * hd * d
    if cfg.has_ssm:
        di = cfg.d_inner
        gn = cfg.ssm_groups * cfg.ssm_state
        fl += 2 * tokens * d * (2 * di + 2 * gn + cfg.ssm_heads)   # in_proj
        fl += 2 * tokens * di * d                                   # out_proj
        fl += 2 * tokens * (di + 2 * gn) * cfg.conv_width           # conv
    if cfg.has_ffn:
        mult = 3 if cfg.act == "swiglu" else 2
        if cfg.is_moe:
            routed = tokens * cfg.experts_per_token * cfg.capacity_factor
            fl += 2 * routed * mult * d * cfg.d_ff
            fl += 2 * tokens * d * cfg.n_experts                    # router
            if cfg.moe_dense_residual:
                fl += 2 * tokens * mult * d * cfg.d_ff
        else:
            fl += 2 * tokens * mult * d * cfg.d_ff
    return fl


def _ssd_flops_layer(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Chunked SSD: intra-chunk dual form + state pass (2 FLOPs/MAC)."""
    if not cfg.has_ssm:
        return 0.0
    q = cfg.ssm_chunk
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    nc = max(seq // q, 1)
    per_chunk = 2 * q * q * n + 2 * q * q * p + 2 * 2 * q * n * p  # scores, y_intra, states+y_inter
    return batch * nc * h * per_chunk


def step_flops(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    """Whole-step FLOPs across all devices."""
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    if kind == "decode":
        tokens = float(batch)
        per_layer = _proj_flops_layer(cfg, tokens)
        if cfg.has_attention:
            h, hd, vd = cfg.n_heads, cfg.resolved_head_dim, cfg.resolved_v_head_dim
            if cfg.attention == "full":
                span = seq
            elif cfg.attention == "window":
                span = min(cfg.window, seq)
            else:
                g = 1.0 / cfg.global_interval
                span = g * seq + (1 - g) * min(cfg.window, seq)
            per_layer += 2.0 * batch * span * h * (hd + vd)
            if cfg.use_mla:  # expansion of compressed cache per step
                per_layer += 2.0 * batch * seq * cfg.kv_lora_rank * cfg.n_heads * (
                    cfg.qk_nope_head_dim + cfg.v_head_dim
                )
        if cfg.has_ssm:
            per_layer += 2.0 * batch * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 2
        head = 2.0 * tokens * d * V
        return L * per_layer + head

    tokens = float(batch) * seq
    per_layer = (
        _proj_flops_layer(cfg, tokens)
        + _attn_flops_layer(cfg, batch, seq)
        + _ssd_flops_layer(cfg, batch, seq)
    )
    head = 2.0 * tokens * d * V
    fwd = L * per_layer + head
    if kind == "prefill":
        return fwd
    # train: fwd + 2x bwd (+1x remat recompute of the forward)
    mult = 4.0 if cfg.remat else 3.0
    return mult * fwd


def _param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * 2.0  # bf16


def _cache_bytes(cfg: ModelConfig, batch: int, seq: int) -> float:
    L = cfg.n_layers
    b = 0.0
    if cfg.has_attention:
        if cfg.use_mla:
            b += L * batch * seq * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
        else:
            span = seq  # cache is allocated full-length (uniform scan layers)
            b += L * batch * span * 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
    if cfg.has_ssm:
        b += L * batch * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
        b += L * batch * (cfg.conv_width - 1) * (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state) * 2
    return b


def step_bytes(cfg: ModelConfig, kind: str, batch: int, seq: int, n_devices: int,
               model_shard: int) -> float:
    """Per-device HBM traffic estimate.

    params: resident shard read once per pass (train: fwd+bwd+remat ≈ 3
    passes × num_microbatches; prefill/decode: 1).
    activations: ~6 residual-stream reads/writes per layer per token
    (pre-norm x2, mixer io, ffn io) in bf16 — a deliberately coarse but
    uniform estimate.
    cache: decode reads the full (sharded) cache once and writes one slot;
    prefill writes it once.
    """
    passes = (3.0 * cfg.num_microbatches) if kind == "train" else 1.0
    p_bytes = _param_bytes(cfg) / model_shard * passes
    batch_shard = n_devices // model_shard
    if kind == "decode":
        tokens_dev = max(batch / batch_shard, batch / n_devices, 1)
        act = tokens_dev * cfg.n_layers * cfg.d_model * 6 * 2
        cache = _cache_bytes(cfg, batch, seq) / n_devices  # sharded read
        return p_bytes + act + cache
    tokens_dev = batch * seq / batch_shard
    act = tokens_dev * cfg.n_layers * cfg.d_model * 6 * 2
    if kind == "train":
        act *= 3.0  # fwd + bwd + remat recompute traffic
    cache = _cache_bytes(cfg, batch, seq) / n_devices if kind == "prefill" else 0.0
    head = tokens_dev * cfg.vocab / model_shard * 4.0  # fp32 logits
    return p_bytes + act + cache + head


def analytic_costs(cfg: ModelConfig, kind: str, batch: int, seq: int,
                   n_devices: int, model_shard: int = 16) -> AnalyticCosts:
    fl = step_flops(cfg, kind, batch, seq)
    by = step_bytes(cfg, kind, batch, seq, n_devices, model_shard)
    return AnalyticCosts(
        flops_total=fl,
        bytes_per_device=by,
        flops_per_device=fl / n_devices,
    )
