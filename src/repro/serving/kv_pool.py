"""Paged KV-cache memory manager: a vLLM-style block pool for the serving
stack.

Physical KV storage is a fixed pool of ``num_blocks`` token blocks of
``block_size`` tokens each (the device arrays live in
``repro.models.paged``); this module is the *host-side* memory manager that
decides which request owns which blocks:

* ``BlockPool``   — the free-list. Block 0 is reserved as the NULL/trash
  block: page-table padding points at it (so gathers stay in-range and the
  masked tail reads garbage instead of faulting) and frozen rows route their
  scatter writes into it.
* ``KVPoolManager`` — per-request page tables over the pool plus a fixed set
  of batch *rows* (the jit-static batch dimension). Lifecycle:
  alloc-on-prefill (``admit``), extend-on-decode (``extend`` allocates a new
  block when a row's length crosses a block boundary), free-on-finish-or-
  cancel (``release``), and copy-on-migration (``clone`` duplicates a page
  table into freshly allocated blocks for the consistent-prefix hand-off —
  the caller copies the block *contents* device-side).

Capacity accounting is the admission signal for continuous batching: a
request is admitted when its prefill's block demand fits the free pool and
queued otherwise, so server queueing under load emerges from real memory
pressure instead of an arbitrary slot count. ``blocks_in_use_peak`` and the
per-rid wait accounting feed the e2e serving benchmark.
"""
from __future__ import annotations

import dataclasses

# single source of truth for the reserved block id: the paged model step
# functions route frozen-row writes there and the kernel DMA-reads it for
# padded table slots, so allocator and compute must agree on it
from repro.models.paged import NULL_BLOCK

__all__ = ["BlockPool", "KVPoolManager", "PageTable", "blocks_for_tokens", "NULL_BLOCK"]


def blocks_for_tokens(tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``tokens`` cache entries."""
    return max(0, -(-int(tokens) // block_size))


class BlockPool:
    """LIFO free-list over ``num_blocks`` physical blocks (block 0 reserved).

    LIFO reuse keeps recently-freed (cache-warm) blocks hot, and makes
    free-on-cancel reuse observable in tests: the next allocation returns
    exactly the blocks a cancellation just released.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the reserved trash block)")
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> block 1 first
        self.peak_in_use = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Allocate ``n`` blocks, or None (all-or-nothing) when short."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        return got

    def free(self, blocks: list[int]) -> None:
        if len(set(blocks)) != len(blocks):
            raise ValueError("duplicate block in free batch")
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the reserved trash block")
            if b in self._free or not (0 < b < self.num_blocks):
                raise ValueError(f"double/invalid free of block {b}")
        # reversed: re-allocating returns blocks in the order they were held
        self._free.extend(reversed(blocks))


@dataclasses.dataclass
class PageTable:
    """One request's view of the pool: its row and its ordered block list."""

    rid: int
    row: int
    blocks: list[int]
    num_tokens: int          # cache entries currently covered by a write

    @property
    def capacity(self) -> int:
        return len(self.blocks)   # in blocks; tokens = capacity * block_size

    def padded(self, max_blocks: int) -> list[int]:
        """Block ids padded with NULL_BLOCK to the fixed table width."""
        return self.blocks + [NULL_BLOCK] * (max_blocks - len(self.blocks))


class KVPoolManager:
    """Page tables + row assignment over one :class:`BlockPool`.

    ``rows`` is the jit-static batch dimension of the paged decode dispatch;
    ``max_blocks_per_row`` bounds one request's table (= ceil(max_len /
    block_size) at the engine layer). Admission needs BOTH a free row and the
    prefill's block demand — under memory pressure the pool, not the row
    count, is the binding constraint.
    """

    def __init__(self, num_blocks: int, block_size: int, rows: int,
                 max_blocks_per_row: int):
        self.pool = BlockPool(num_blocks)
        self.block_size = int(block_size)
        self.rows = int(rows)
        self.max_blocks_per_row = int(max_blocks_per_row)
        self.tables: dict[int, PageTable] = {}
        self._free_rows = list(range(rows - 1, -1, -1))
        # accounting for the serving benchmark. Two distinct pressure
        # signals: ``memory_waits`` = rids whose ADMISSION was blocked by
        # blocks (they sat in the queue); ``extend_stalls`` = already-running
        # rids whose extend/clone was denied (resolved by preemption or by
        # truncating the stream — they never re-queued).
        self.memory_waits: set[int] = set()
        self.extend_stalls: set[int] = set()
        self.preemptions = 0

    # -- capacity queries ---------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.pool.num_in_use

    @property
    def blocks_in_use_peak(self) -> int:
        return self.pool.peak_in_use

    @property
    def has_free_row(self) -> bool:
        return bool(self._free_rows)

    def prefill_demand(self, bucket_tokens: int, true_tokens: int | None = None) -> int:
        """Blocks a prefill needs: cover the (bucket-padded) scatter plus the
        first decode token's slot when the true length exactly fills its
        blocks. Bucket padding is *real* allocated memory here — paged
        serving makes that cost visible instead of hiding it in a dense
        max_len reservation."""
        true_tokens = bucket_tokens if true_tokens is None else true_tokens
        demand = max(
            blocks_for_tokens(bucket_tokens, self.block_size),
            blocks_for_tokens(true_tokens + 1, self.block_size),
        )
        return min(demand, self.max_blocks_per_row)

    def can_admit(self, demand_blocks: int, rid: int | None = None) -> bool:
        """True when ``demand_blocks`` could be allocated NOW along with a
        row. When blocked by memory (a row is free but blocks are not), the
        rid is recorded in ``memory_waits`` — the benchmark's
        queued-on-memory signal."""
        if not self._free_rows:
            return False
        if demand_blocks > self.pool.num_free:
            if rid is not None:
                self.memory_waits.add(rid)
            return False
        return True

    # -- lifecycle ----------------------------------------------------------

    def admit(self, rid: int, demand_blocks: int, num_tokens: int = 0) -> PageTable | None:
        """Alloc-on-prefill: allocate ``demand_blocks`` and a row. Returns
        None (nothing allocated) when either is unavailable."""
        if rid in self.tables:
            raise ValueError(f"rid {rid} already admitted")
        if not self.can_admit(demand_blocks, rid):
            return None
        blocks = self.pool.alloc(demand_blocks)
        assert blocks is not None
        table = PageTable(rid, self._free_rows.pop(), blocks, num_tokens)
        self.tables[rid] = table
        return table

    def extend(self, rid: int, target_tokens: int) -> bool:
        """Extend-on-decode: grow ``rid``'s table to cover ``target_tokens``
        cache entries. Allocates only when the target crosses a block
        boundary; False (table unchanged) when the pool is exhausted."""
        table = self.tables[rid]
        need = blocks_for_tokens(target_tokens, self.block_size)
        need = min(need, self.max_blocks_per_row)
        extra = need - table.capacity
        if extra <= 0:
            return True
        got = self.pool.alloc(extra)
        if got is None:
            self.extend_stalls.add(rid)
            return False
        table.blocks.extend(got)
        return True

    def release(self, rid: int) -> None:
        """Free-on-finish-or-cancel: blocks and row return to the pool
        immediately (no drain — the cache contents just become garbage)."""
        table = self.tables.pop(rid, None)
        if table is None:
            return
        self.pool.free(table.blocks)
        self._free_rows.append(table.row)

    def clone(self, src_rid: int, dst_rid: int) -> tuple[PageTable, list[tuple[int, int]]] | None:
        """Copy-on-migration: allocate a fresh table for ``dst_rid`` mirroring
        ``src_rid``'s, and return (dst_table, [(src_block, dst_block), ...])
        copy pairs — the caller performs the device-side block copies. The
        source table is untouched (the consistent-prefix hand-off keeps the
        source generating until the target's first token arrives). Returns
        None when blocks or a row are unavailable."""
        src = self.tables[src_rid]
        if dst_rid in self.tables:
            raise ValueError(f"rid {dst_rid} already admitted")
        if not self._free_rows:
            return None
        blocks = self.pool.alloc(len(src.blocks))
        if blocks is None:
            self.extend_stalls.add(dst_rid)
            return None
        dst = PageTable(dst_rid, self._free_rows.pop(), blocks, src.num_tokens)
        self.tables[dst_rid] = dst
        return dst, list(zip(src.blocks, blocks))
