"""Paged KV-cache memory manager: a vLLM-style block pool for the serving
stack.

Physical KV storage is a fixed pool of ``num_blocks`` token blocks of
``block_size`` tokens each (the device arrays live in
``repro.models.paged``); this module is the *host-side* memory manager that
decides which request owns which blocks:

* ``BlockPool``   — the refcounted free-list. Block 0 is reserved as the
  NULL/trash block: page-table padding points at it (so gathers stay
  in-range and the masked tail reads garbage instead of faulting) and frozen
  rows route their scatter writes into it. Every live block carries a
  refcount so several page tables (and the prefix cache) can alias one
  immutable block; a block returns to the free list only when its last
  reference drops.
* ``PrefixIndex`` — a radix/trie prefix cache over *sealed* (full) blocks,
  keyed on the block's token ids. Released requests register their full
  blocks; admission consults the trie and maps matched blocks straight into
  the new request's page table (refcount bump, zero device work), so shared
  system prompts and resent multi-turn histories skip their prefill
  entirely. Unpinned entries are evicted LRU-first under pool pressure.
* ``KVPoolManager`` — per-request page tables over the pool plus a fixed set
  of batch *rows* (the jit-static batch dimension). Lifecycle:
  alloc-on-prefill (``admit``, optionally aliasing a matched cached prefix),
  extend-on-decode (``extend`` allocates a new block when a row's length
  crosses a block boundary), free-on-finish-or-cancel (``release``
  decrements refcounts and can register the row's sealed blocks in the
  prefix index), and alias-on-migration (``clone`` shares the source's
  sealed blocks copy-on-write: only a partial tail block is device-copied).

Capacity accounting is the admission signal for continuous batching: a
request is admitted when its prefill's block demand fits the free pool plus
what the prefix cache could evict, and queued otherwise, so server queueing
under load emerges from real memory pressure instead of an arbitrary slot
count. ``blocks_in_use_peak``, the per-rid wait accounting, and the prefix
hit/eviction counters feed the e2e serving benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

# single source of truth for the reserved block id: the paged model step
# functions route frozen-row writes there and the kernel DMA-reads it for
# padded table slots, so allocator and compute must agree on it
from repro.models.paged import NULL_BLOCK
from repro.serving.telemetry import NULL_TRACER, MetricsRegistry, metric_attr

__all__ = [
    "BlockPool",
    "KVPoolManager",
    "PageTable",
    "PrefixIndex",
    "blocks_for_tokens",
    "NULL_BLOCK",
]


def blocks_for_tokens(tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``tokens`` cache entries."""
    return max(0, -(-int(tokens) // block_size))


class BlockPool:
    """Refcounted LIFO free-list over ``num_blocks`` physical blocks (block 0
    reserved).

    LIFO reuse keeps recently-freed (cache-warm) blocks hot, and makes
    free-on-cancel reuse observable in tests: the next allocation returns
    exactly the blocks a cancellation just released.

    Allocation hands a block out with refcount 1; ``incref`` lets another
    owner (a cloned page table, a prefix-cache entry) alias it, and the block
    only rejoins the free list when the count returns to 0. ``free`` is a
    batch decref — with a single owner it behaves exactly like the
    pre-refcount free.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the reserved trash block)")
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> block 1 first
        self._ref = [0] * num_blocks                      # block 0 never counted
        self.peak_in_use = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def ref(self, block: int) -> int:
        """Current refcount of ``block`` (0 = on the free list)."""
        return self._ref[block]

    def alloc(self, n: int) -> list[int] | None:
        """Allocate ``n`` blocks at refcount 1, or None (all-or-nothing)."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._ref[b] = 1
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        return got

    def _check_live(self, b: int) -> None:
        if b == NULL_BLOCK:
            raise ValueError("cannot ref/free the reserved trash block")
        if not (0 < b < self.num_blocks):
            raise ValueError(f"invalid block id {b}")
        if self._ref[b] <= 0:
            raise ValueError(f"double/invalid free of block {b}")

    def incref(self, block: int) -> int:
        """Add an owner to a live block (aliasing). Returns the new count."""
        self._check_live(block)
        self._ref[block] += 1
        return self._ref[block]

    def decref(self, block: int) -> int:
        """Drop one owner; the block rejoins the free list at count 0.
        Returns the new count."""
        self._check_live(block)
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)
        return self._ref[block]

    def free(self, blocks: list[int]) -> None:
        """Batch decref of one owner's blocks. Blocks whose last reference
        dropped rejoin the free list in reversed batch order, so re-allocating
        returns them in the order they were held (the LIFO observable)."""
        if len(set(blocks)) != len(blocks):
            raise ValueError("duplicate block in free batch")
        for b in blocks:
            self._check_live(b)
        released = []
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                released.append(b)
        self._free.extend(reversed(released))


@dataclasses.dataclass
class PageTable:
    """One request's view of the pool: its row and its ordered block list.

    ``num_prefix`` leading blocks were aliased from the prefix cache at
    admission (refcount-bumped, never written by this request): the prefill
    scatter starts after them.
    """

    rid: int
    row: int
    blocks: list[int]
    num_tokens: int          # cache entries currently covered by a write
    num_prefix: int = 0      # leading blocks aliased from the prefix cache

    @property
    def capacity(self) -> int:
        return len(self.blocks)   # in blocks; tokens = capacity * block_size

    def padded(self, max_blocks: int) -> list[int]:
        """Block ids padded with NULL_BLOCK to the fixed table width."""
        return self.blocks + [NULL_BLOCK] * (max_blocks - len(self.blocks))


class _PrefixNode:
    """One cached block: a trie edge keyed by its block's token ids."""

    __slots__ = ("key", "block", "parent", "children", "stamp")

    def __init__(self, key, block, parent):
        self.key = key            # tuple of block_size token ids (None = root)
        self.block = block        # physical block id (None = root)
        self.parent = parent
        self.children: dict[tuple, "_PrefixNode"] = {}
        self.stamp = 0            # LRU clock value of the last touch


class PrefixIndex:
    """Radix/trie prefix cache over sealed blocks.

    Each non-root node owns exactly one pool reference on one physical block
    whose ``block_size`` token ids are the node's edge key; a root-to-node
    path spells a cached token prefix. Because every page table that aliases
    a node's block also aliases all its ancestors' blocks (prefixes are
    contiguous), a node with pool refcount 1 — the cache's own reference —
    is always reclaimable bottom-up: ``evict_one`` drops the least recently
    touched such leaf, so ``evictable()`` (the count of refcount-1 nodes) is
    exactly the headroom eviction can create.
    """

    def __init__(self, pool: BlockPool, block_size: int):
        self.pool = pool
        self.block_size = int(block_size)
        self.root = _PrefixNode(None, None, None)
        self._by_block: dict[int, _PrefixNode] = {}
        self._clock = 0
        self.evictions = 0

    @property
    def num_cached(self) -> int:
        """Blocks currently held by the cache."""
        return len(self._by_block)

    def _touch(self, node: _PrefixNode) -> None:
        self._clock += 1
        node.stamp = self._clock

    def _key(self, tokens: Sequence[int], i: int) -> tuple:
        bs = self.block_size
        return tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])

    def match(self, tokens: Sequence[int], max_blocks: int) -> list[int]:
        """Longest cached full-block prefix of ``tokens`` (≤ ``max_blocks``
        blocks). Pure query: no refcounts taken, no LRU touch — callers pin
        via ``touch`` + ``BlockPool.incref`` at admission time."""
        node = self.root
        out: list[int] = []
        for i in range(max_blocks):
            child = node.children.get(self._key(tokens, i))
            if child is None:
                break
            out.append(child.block)
            node = child
        return out

    def touch(self, blocks: Iterable[int]) -> None:
        """Refresh the LRU stamp of cached ``blocks`` (a matched prefix)."""
        for b in blocks:
            node = self._by_block.get(b)
            if node is not None:
                self._touch(node)

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Register ``blocks`` as the cache entries for the full-block prefix
        of ``tokens`` (``len(blocks)`` sealed blocks). Existing nodes are
        kept (their block already holds identical content — the duplicate is
        simply not cached twice); new nodes take one pool reference each.
        Returns the number of newly cached blocks."""
        node = self.root
        added = 0
        for i, b in enumerate(blocks):
            key = self._key(tokens, i)
            child = node.children.get(key)
            if child is None:
                child = _PrefixNode(key, b, node)
                node.children[key] = child
                self._by_block[b] = child
                self.pool.incref(b)
                added += 1
            self._touch(child)
            node = child
        return added

    def evictable(self, exclude: frozenset | set = frozenset()) -> int:
        """Blocks eviction could free right now: cached nodes whose only
        reference is the cache's own (minus ``exclude`` — blocks about to be
        pinned by the admission asking the question)."""
        return sum(
            1
            for b, n in self._by_block.items()
            if self.pool.ref(b) == 1 and b not in exclude
        )

    def evict_one(self, exclude: frozenset | set = frozenset()) -> bool:
        """Drop the least-recently-touched reclaimable leaf, returning its
        block to the pool. False when nothing is evictable."""
        best: Optional[_PrefixNode] = None
        for b, node in self._by_block.items():
            if node.children or self.pool.ref(b) != 1 or b in exclude:
                continue
            if best is None or node.stamp < best.stamp:
                best = node
        if best is None:
            return False
        del best.parent.children[best.key]
        del self._by_block[best.block]
        self.pool.free([best.block])
        self.evictions += 1
        return True

    def flush(self) -> None:
        """Drop every cache reference (pinned blocks stay with their other
        owners). Used by tests asserting the pool drains to its initial
        free count."""
        if self._by_block:
            self.pool.free(list(self._by_block.keys()))
        self._by_block.clear()
        self.root = _PrefixNode(None, None, None)


class KVPoolManager:
    """Page tables + row assignment over one :class:`BlockPool`.

    ``rows`` is the jit-static batch dimension of the paged decode dispatch;
    ``max_blocks_per_row`` bounds one request's table (= ceil(max_len /
    block_size) at the engine layer). Admission needs BOTH a free row and the
    prefill's block demand — under memory pressure the pool, not the row
    count, is the binding constraint.

    With ``prefix_cache=True`` a :class:`PrefixIndex` rides on the pool:
    ``prefix_match`` finds the longest cached full-block prefix of a prompt,
    ``admit(..., prefix_blocks=...)`` aliases those blocks into the new
    table (shared blocks are counted ONCE — the admission demand is the
    unmatched suffix only), and ``release(..., cache_tokens=...)`` registers
    a finished request's sealed blocks for future hits. Cached-but-unpinned
    blocks are evicted LRU-first whenever an allocation would otherwise
    fail, so the cache never steals capacity from live requests.
    """

    # counters live in the registry (the single backing store for every
    # stats surface); these descriptors keep every `self.x += 1` site and
    # every test that reads `kv.x` working unchanged
    preemptions = metric_attr("preemptions")
    prefix_queries = metric_attr("prefix_queries")
    prefix_hits = metric_attr("prefix_hits")
    prefix_tokens_hit = metric_attr("prefix_tokens_hit")
    blocks_saved = metric_attr("blocks_saved")
    copy_ops = metric_attr("copy_ops")
    clone_fallbacks = metric_attr("clone_fallbacks")
    handoffs = metric_attr("handoffs")
    handoff_blocks = metric_attr("handoff_blocks")
    handoff_fallbacks = metric_attr("handoff_fallbacks")

    def __init__(self, num_blocks: int, block_size: int, rows: int,
                 max_blocks_per_row: int, prefix_cache: bool = False,
                 metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = NULL_TRACER
        self._now = None                    # zero-arg virtual-clock callable
        self.pool = BlockPool(num_blocks)
        self.block_size = int(block_size)
        self.rows = int(rows)
        self.max_blocks_per_row = int(max_blocks_per_row)
        self.tables: dict[int, PageTable] = {}
        self._free_rows = list(range(rows - 1, -1, -1))
        self.prefix: Optional[PrefixIndex] = (
            PrefixIndex(self.pool, self.block_size) if prefix_cache else None
        )
        # accounting for the serving benchmark. Two distinct pressure
        # signals: ``memory_waits`` = rids whose ADMISSION was blocked by
        # blocks (they sat in the queue); ``extend_stalls`` = already-running
        # rids whose extend/clone was denied (resolved by preemption or by
        # truncating the stream — they never re-queued).
        self.memory_waits: set[int] = set()
        self.extend_stalls: set[int] = set()
        self.preemptions = 0
        # prefix-sharing accounting: queries/hits at admission, tokens and
        # blocks whose prefill was skipped, device block copies performed by
        # clone (CoW partial tails only), and fork_stream clones that fell
        # back to a replay re-prefill because the pool could not serve them
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefix_tokens_hit = 0
        self.blocks_saved = 0
        self.copy_ops = 0
        self.clone_fallbacks = 0
        # cross-pool hand-off accounting (disaggregated prefill/decode):
        # transfers received into this pool, blocks device-copied for them,
        # and receives that failed — the sender falls back to a lossless
        # recompute on the decode worker
        self.handoffs = 0
        self.handoff_blocks = 0
        self.handoff_fallbacks = 0
        # derived numbers are registry views: evaluated at snapshot time so
        # they can never drift from their inputs
        m = self.metrics
        m.view("blocks_in_use_peak", lambda: int(self.pool.peak_in_use))
        m.view("queued_on_memory", lambda: len(self.memory_waits))
        m.view("extend_stalls", lambda: len(self.extend_stalls))
        m.view("num_blocks", lambda: int(self.pool.num_blocks))
        m.view("block_size", lambda: int(self.block_size))
        m.view("prefix_cache", lambda: self.prefix is not None)
        m.view("prefix_hit_rate", lambda: (
            self.prefix_hits / self.prefix_queries if self.prefix_queries else 0.0
        ))
        m.view("blocks_cached", lambda: int(self.blocks_cached))
        m.view("prefix_evictions", lambda: int(self.prefix_evictions))
        m.view("blocks_in_use", lambda: int(self.pool.num_in_use))

    def set_telemetry(self, tracer, clock) -> None:
        """Attach a tracer and the owning engine's virtual clock (a zero-arg
        callable); kv events are stamped on that shared timeline."""
        self.tracer = tracer
        self._now = clock

    def _trace(self, name: str, **args) -> None:
        """Emit one kv instant + refresh the blocks_in_use counter track."""
        t = self._now()
        self.tracer.instant("kv/pool", name, t, cat="kv", args=args)
        self.tracer.value("kv/pool", "blocks_in_use", t, self.pool.num_in_use)

    # -- capacity queries ---------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.pool.num_in_use

    @property
    def blocks_in_use_peak(self) -> int:
        return self.pool.peak_in_use

    @property
    def has_free_row(self) -> bool:
        return bool(self._free_rows)

    @property
    def blocks_cached(self) -> int:
        """Blocks currently held by the prefix cache (0 when disabled)."""
        return 0 if self.prefix is None else self.prefix.num_cached

    @property
    def prefix_evictions(self) -> int:
        return 0 if self.prefix is None else self.prefix.evictions

    def prefill_demand(self, bucket_tokens: int, true_tokens: int | None = None) -> int:
        """Blocks a prefill needs: cover the (bucket-padded) scatter plus the
        first decode token's slot when the true length exactly fills its
        blocks. Bucket padding is *real* allocated memory here — paged
        serving makes that cost visible instead of hiding it in a dense
        max_len reservation."""
        true_tokens = bucket_tokens if true_tokens is None else true_tokens
        demand = max(
            blocks_for_tokens(bucket_tokens, self.block_size),
            blocks_for_tokens(true_tokens + 1, self.block_size),
        )
        return min(demand, self.max_blocks_per_row)

    def prefix_match(self, tokens, record: bool = True) -> list[int]:
        """Longest cached full-block prefix of ``tokens`` — the block ids a
        subsequent ``admit(..., prefix_blocks=...)`` would alias. Capped one
        block short of the whole prompt so the last real position (and its
        first-token logits) is always computed. ``record=False`` makes the
        query side-effect free (admissibility probes re-query at admission).
        Empty when the cache is disabled."""
        if self.prefix is None:
            return []
        n = len(tokens)
        max_blocks = min((n - 1) // self.block_size, self.max_blocks_per_row - 1)
        if max_blocks <= 0:
            return []
        blocks = self.prefix.match(tokens, max_blocks)
        if record:
            self.prefix_queries += 1
            if blocks:
                self.prefix_hits += 1
                self.prefix_tokens_hit += len(blocks) * self.block_size
                self.blocks_saved += len(blocks)
                if self.tracer.enabled and self._now is not None:
                    self._trace(
                        "prefix_hit",
                        blocks=len(blocks),
                        tokens=len(blocks) * self.block_size,
                    )
        return blocks

    def can_admit(self, demand_blocks: int, rid: int | None = None,
                  prefix_blocks: Sequence[int] = ()) -> bool:
        """True when ``demand_blocks`` NEW blocks could be allocated now
        along with a row — counting free blocks plus what LRU eviction could
        reclaim, minus the matched ``prefix_blocks`` the admission is about
        to pin (shared blocks are never double-counted: they are neither
        demanded nor evictable). When blocked by memory (a row is free but
        blocks are not), the rid is recorded in ``memory_waits`` — the
        benchmark's queued-on-memory signal."""
        if not self._free_rows:
            return False
        headroom = self.pool.num_free
        if self.prefix is not None:
            headroom += self.prefix.evictable(exclude=set(prefix_blocks))
        if demand_blocks > headroom:
            if rid is not None:
                self.memory_waits.add(rid)
                if self.tracer.enabled and self._now is not None:
                    self._trace("memory_wait", rid=rid, demand=demand_blocks)
            return False
        return True

    def _alloc_evict(self, n: int,
                     exclude: frozenset | set = frozenset()) -> list[int] | None:
        """Pool alloc that evicts LRU cached prefixes to make room."""
        got = self.pool.alloc(n)
        evicted = 0
        while got is None and self.prefix is not None \
                and self.prefix.evict_one(exclude=exclude):
            evicted += 1
            got = self.pool.alloc(n)
        if evicted and self.tracer.enabled and self._now is not None:
            self._trace("prefix_evict", n=evicted)
        return got

    # -- lifecycle ----------------------------------------------------------

    def admit(self, rid: int, demand_blocks: int, num_tokens: int = 0,
              prefix_blocks: Sequence[int] = ()) -> PageTable | None:
        """Alloc-on-prefill: allocate ``demand_blocks`` fresh blocks and a
        row; ``prefix_blocks`` (a ``prefix_match`` result) are aliased in
        front of them — refcount bump, zero device work, the caller prefills
        only the suffix. Returns None (nothing allocated, nothing pinned)
        when row or blocks are unavailable."""
        if rid in self.tables:
            raise ValueError(f"rid {rid} already admitted")
        prefix_blocks = list(prefix_blocks)
        if not self.can_admit(demand_blocks, rid, prefix_blocks):
            return None
        # pin the matched prefix FIRST so eviction cannot reclaim it while
        # making room for the suffix allocation
        for b in prefix_blocks:
            self.pool.incref(b)
        if self.prefix is not None and prefix_blocks:
            self.prefix.touch(prefix_blocks)
        got = self._alloc_evict(demand_blocks, exclude=set(prefix_blocks))
        if got is None:                      # can_admit raced nothing; defensive
            if prefix_blocks:
                self.pool.free(prefix_blocks)
            self.memory_waits.add(rid)
            return None
        table = PageTable(
            rid, self._free_rows.pop(), prefix_blocks + got, num_tokens,
            num_prefix=len(prefix_blocks),
        )
        self.tables[rid] = table
        if self.tracer.enabled and self._now is not None:
            self._trace(
                "alloc", rid=rid, blocks=len(got), prefix=len(prefix_blocks)
            )
        return table

    def extend(self, rid: int, target_tokens: int) -> bool:
        """Extend-on-decode: grow ``rid``'s table to cover ``target_tokens``
        cache entries. Allocates only when the target crosses a block
        boundary (evicting cached prefixes before giving up); False (table
        unchanged) when the pool is exhausted."""
        table = self.tables[rid]
        need = blocks_for_tokens(target_tokens, self.block_size)
        need = min(need, self.max_blocks_per_row)
        extra = need - table.capacity
        if extra <= 0:
            return True
        got = self._alloc_evict(extra)
        if got is None:
            self.extend_stalls.add(rid)
            if self.tracer.enabled and self._now is not None:
                self._trace("extend_stall", rid=rid, blocks=extra)
            return False
        table.blocks.extend(got)
        if self.tracer.enabled and self._now is not None:
            self._trace("extend", rid=rid, blocks=extra)
        return True

    def shrink(self, rid: int, target_tokens: int) -> int:
        """Inverse of :meth:`extend`: give back the tail blocks past
        ``target_tokens`` cache entries. Speculative verify extends a row's
        table to cover every scored draft position, then shrinks back to the
        accepted prefix — so the request's steady-state block demand charges
        accepted tokens only, and rejected-draft scratch returns to the pool
        within the same tick. Prefix-aliased leading blocks are never
        released (they are owned by the cache, not this table). Returns the
        number of blocks freed."""
        table = self.tables[rid]
        keep = max(
            blocks_for_tokens(target_tokens, self.block_size),
            table.num_prefix,
        )
        tail = table.blocks[keep:]
        if not tail:
            return 0
        del table.blocks[keep:]
        self.pool.free(tail)
        if self.tracer.enabled and self._now is not None:
            self._trace("shrink", rid=rid, blocks=len(tail))
        return len(tail)

    def release(self, rid: int, cache_tokens=None) -> None:
        """Free-on-finish-or-cancel: one reference per block returns to the
        pool immediately (no drain — an unshared block's contents just
        become garbage). ``cache_tokens`` — the token ids actually covering
        the table's written entries (prompt + emitted, truncated to
        ``num_tokens``) — registers the sealed (full) blocks in the prefix
        index before the decref, so a finished, cancelled, or preempted
        request's prefix stays warm for the next hit."""
        table = self.tables.pop(rid, None)
        if table is None:
            return
        if self.prefix is not None and cache_tokens is not None:
            n_full = min(len(cache_tokens) // self.block_size, len(table.blocks))
            if n_full > 0:
                self.prefix.insert(cache_tokens, table.blocks[:n_full])
        self.pool.free(table.blocks)
        self._free_rows.append(table.row)
        if self.tracer.enabled and self._now is not None:
            self._trace("free", rid=rid, blocks=len(table.blocks))

    def clone(self, src_rid: int, dst_rid: int) -> tuple[PageTable, list[tuple[int, int]]] | None:
        """Alias-on-migration (copy-on-write): ``dst_rid``'s table shares the
        source's sealed (full) blocks — a pure refcount bump, zero device
        work — and gets fresh blocks for the rest; the returned
        ``(src_block, dst_block)`` copy pairs cover ONLY a partial tail
        block, the one block both sides will keep writing. The source table
        is untouched (the consistent-prefix hand-off keeps the source
        generating until the target's first token arrives; it only ever
        writes at or past ``num_tokens``, never into a sealed block).
        Returns None when blocks or a row are unavailable."""
        src = self.tables[src_rid]
        if dst_rid in self.tables:
            raise ValueError(f"rid {dst_rid} already admitted")
        if not self._free_rows:
            return None
        n_full = min(src.num_tokens // self.block_size, len(src.blocks))
        shared = src.blocks[:n_full]
        fresh = self._alloc_evict(len(src.blocks) - n_full, exclude=set(shared))
        if fresh is None:
            self.extend_stalls.add(dst_rid)
            return None
        for b in shared:
            self.pool.incref(b)
        pairs = []
        if src.num_tokens % self.block_size and len(src.blocks) > n_full:
            # the partial tail is live on both sides: copy-on-write it
            pairs = [(src.blocks[n_full], fresh[0])]
        self.copy_ops += len(pairs)
        dst = PageTable(
            dst_rid, self._free_rows.pop(), shared + fresh, src.num_tokens,
            num_prefix=n_full,
        )
        self.tables[dst_rid] = dst
        if self.tracer.enabled and self._now is not None:
            self._trace(
                "clone", src=src_rid, dst=dst_rid,
                shared=len(shared), fresh=len(fresh),
            )
            if pairs:
                self._trace("cow_copy", n=len(pairs))
        return dst, pairs

    def detach(self, rid: int) -> PageTable:
        """Hand-off hold: remove ``rid``'s table from the live set, returning
        its batch row to the free list but KEEPING this owner's block
        references, so the blocks cannot be reallocated (and their device
        contents overwritten) while a cross-pool transfer is in flight. The
        caller owns the returned table and must eventually pass it to
        :meth:`release_detached`."""
        table = self.tables.pop(rid)
        self._free_rows.append(table.row)
        if self.tracer.enabled and self._now is not None:
            self._trace("detach", rid=rid, blocks=len(table.blocks))
        return table

    def release_detached(self, table: PageTable, cache_tokens=None) -> None:
        """Drop a :meth:`detach`-ed table's block references (transfer done,
        or the hand-off was cancelled mid-flight). ``cache_tokens`` registers
        the sealed blocks in the prefix index first — a transferred prompt's
        prefix stays warm on the prefill worker for sticky routing hits."""
        if self.prefix is not None and cache_tokens is not None:
            n_full = min(len(cache_tokens) // self.block_size, len(table.blocks))
            if n_full > 0:
                self.prefix.insert(cache_tokens, table.blocks[:n_full])
        self.pool.free(table.blocks)
        if self.tracer.enabled and self._now is not None:
            self._trace("free", rid=table.rid, blocks=len(table.blocks))

    def receive(self, rid: int, src_table: PageTable,
                num_tokens: int | None = None) -> tuple[PageTable, list[tuple[int, int]]] | None:
        """Cross-pool hand-off (the clone extension for disaggregated P/D
        serving): materialize ``src_table`` — a table owned by a DIFFERENT
        pool's manager — into this pool. Unlike :meth:`clone`, nothing can be
        aliased across pools, so every block covering ``num_tokens`` written
        entries gets a fresh local block and shows up in the returned
        ``(src_block, dst_block)`` copy pairs the caller must device-copy.
        Allocation covers the next decode write too (``num_tokens + 1``).
        Returns None — and counts a ``handoff_fallback`` — when a row or the
        blocks are unavailable: the caller recomputes on this worker instead
        (lossless, via the replay-resume admission path)."""
        if rid in self.tables:
            raise ValueError(f"rid {rid} already admitted")
        num_tokens = src_table.num_tokens if num_tokens is None else int(num_tokens)
        used = min(
            blocks_for_tokens(num_tokens, self.block_size), len(src_table.blocks)
        )
        n_alloc = min(
            blocks_for_tokens(num_tokens + 1, self.block_size),
            self.max_blocks_per_row,
        )
        n_alloc = max(n_alloc, used)
        if not self._free_rows:
            self.handoff_fallbacks += 1
            if self.tracer.enabled and self._now is not None:
                self._trace("handoff_fallback", rid=rid, reason="rows")
            return None
        got = self._alloc_evict(n_alloc)
        if got is None:
            self.handoff_fallbacks += 1
            self.memory_waits.add(rid)
            if self.tracer.enabled and self._now is not None:
                self._trace("handoff_fallback", rid=rid, reason="blocks")
            return None
        pairs = list(zip(src_table.blocks[:used], got[:used]))
        table = PageTable(rid, self._free_rows.pop(), got, num_tokens)
        self.tables[rid] = table
        self.handoffs += 1
        self.handoff_blocks += len(pairs)
        if self.tracer.enabled and self._now is not None:
            self._trace("handoff", rid=rid, blocks=len(pairs))
        return table, pairs

    def flush_prefix_cache(self) -> None:
        """Drop every prefix-cache reference (refcount invariant tests and
        cold-cache control runs)."""
        if self.prefix is not None:
            self.prefix.flush()
