"""DiSCo serving runtime: an event-driven middleware loop over two real
engines (Fig. 1), holding MANY concurrent requests.

The runtime is a discrete-event loop on a shared virtual timeline. Compute
times are real JAX wall-clock measurements; network RTT is sampled; server
queueing *emerges* from slot contention in the shared ``BatchedServer``.
Everything is deterministic given the rng.

Per request:
  1. dispatch (§4.2): ``plan_request`` gives {use_server, use_device,
     device_wait}
  2. race: both endpoints stream tokens lazily on the shared timeline; the
     first first-token wins and the loser is **cancelled** — it stops after
     at most one in-flight decode chunk instead of generating all ``max_new``
     tokens (the §4.2 cost saving, measurable via ``wasted_tokens``)
  3. migration (§4.3): if the winner is the expensive decoder, hand off once
     the delivery buffer holds B tokens; the target re-prefills prompt +
     generated token IDs (no state transfer). A server-bound re-prefill is
     submitted to the SAME contended batched scheduler as live traffic. The
     source keeps generating until the target's first token arrives; the
     target's regeneration of tokens the source delivered during the
     hand-off is skipped (consistent-prefix hand-off), so with identical
     endpoint models the delivered stream is bit-identical to no-migration.
  4. delivery: tokens are paced at the consumption rate r_c via TokenBuffer;
     QoE (TTFT, TBT series), unified cost, and wasted compute are recorded.

Event-loop causality: device-side streams are *pull-driven* — a stream is
activated (prefill dispatched) only when the virtual frontier reaches its
start time, and it computes at most one fused chunk beyond the frontier.
The shared server is *clock-driven* — the loop advances it with
``run_until(horizon)`` where the horizon is the earliest other possible
event, so no server compute runs ahead of anything that could cancel it by
more than the one chunk already in flight.

``cancel_losers=False`` turns the runtime into the no-cancellation control
(both streams always run to completion): the baseline against which the
wasted-compute reduction is measured.

With the shared server's prefix cache ON (``BatchedServer(...,
prefix_cache=True)``) the racing/migration pattern stops paying for its own
redundancy: a cancelled server-side loser RELEASES its sealed prompt blocks
into the radix prefix index, so the later migration replay of ``prompt +
generated ids`` — submitted to the same contended scheduler — admits as a
prefix HIT and recomputes only the unsealed tail instead of the whole
conversation. ``stats()`` (one registry-backed surface over the shared
server and the driver ledgers) reports ``prefix_hit_rate`` /
``blocks_saved`` / ``copy_ops`` / ``clone_fallbacks`` alongside the
memory-pressure counters; ``set_tracer`` (or the ``tracer=`` ctor argument)
attaches a ``telemetry.Tracer`` that records the full request lifecycle on
the shared virtual timeline as a Perfetto-loadable trace.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from collections import deque
from typing import Iterable, Optional

import numpy as np

from repro.core import (
    DiSCoScheduler,
    Endpoint,
    TokenBuffer,
)
from repro.core.dispatch import DispatchDecision

from .engine import SPEC_K_MAX
from .endpoint import DeviceEndpoint, ServerEndpoint
from .request import QoEReport, Request, RequestResult
from .telemetry import NULL_TRACER, MetricsRegistry, metric_attr

__all__ = ["ServedRequest", "DiSCoServer"]


def __getattr__(name: str):
    if name == "ServedRequest":
        # deprecated alias: the result type moved to serving.request
        warnings.warn(
            "ServedRequest is deprecated; use "
            "repro.serving.request.RequestResult",
            DeprecationWarning, stacklevel=2,
        )
        return RequestResult
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class _Req:
    rid: int
    req: Request                # the resolved request (rid + seed assigned)
    decision: object
    streams: dict = dataclasses.field(default_factory=dict)   # race streams
    all_streams: list = dataclasses.field(default_factory=list)
    winner: Optional[Endpoint] = None
    delivery: object = None
    buf: Optional[TokenBuffer] = None
    tokens: list = dataclasses.field(default_factory=list)
    first_t: float = math.nan
    plan: object = None
    mig_stream: object = None
    mig_prefix: int = 0
    mig_skip: int = 0
    handoff_done: bool = False
    migrated: bool = False
    done: bool = False
    spec: object = None         # _SpecSession for speculative-mode requests

    @property
    def prompt(self) -> np.ndarray:
        return self.req.prompt

    @property
    def max_new(self) -> int:
        return self.req.max_new

    @property
    def arrival(self) -> float:
        return self.req.arrival


class _SpecSession:
    """One request's device-draft / server-verify protocol (Fig. 1 turned
    collaborative): instead of racing two full decoders and cancelling the
    loser, the device *drafts* k tokens per round and the contended server
    scores them all in ONE fused verify dispatch, accepting a lossless
    prefix by rejection sampling. The race's wasted tokens become accepted
    ones; the server's per-token decode dispatches become per-round ones.

    Timeline honesty: a round's drafts leave the device at its local
    virtual frontier, cross the request's sampled uplink, are scored no
    earlier than their arrival (``verify_step(at=...)``), and the verdict
    crosses the downlink before the next window may start. Committed tokens
    are delivered through the request's normal ``ServerTokenStream`` — one
    delivery path, one QoE series, shared with race mode.

    Adaptive k: an EMA of per-round acceptance doubles the window (up to
    ``SPEC_K_MAX``) while drafts keep landing and halves it when they
    don't; if acceptance collapses the session falls back to plain server
    decode (``end_verify``) and cancels the device — exactly the state a
    race-mode server winner would be in."""

    pull_driven = True

    # adaptive-k policy knobs (powers of two; see engine._spec_k_floor)
    EMA_ALPHA = 0.5
    GROW_AT = 0.75
    SHRINK_AT = 0.4
    COLLAPSE_AT = 0.125
    COLLAPSE_MIN_ROUNDS = 3

    def __init__(self, dev, srv_stream, k_init: int = 4,
                 tracer=NULL_TRACER, drv_rid: Optional[int] = None):
        self.dev = dev                      # DeviceDraftSession
        self.srv = srv_stream               # ServerTokenStream (verify rid)
        self.server = srv_stream.server     # shared BatchedServer
        self.rid = srv_stream.rid
        self.tracer = tracer
        self.drv_rid = drv_rid              # driver-level rid (trace join key)
        self.k = max(1, min(int(k_init), SPEC_K_MAX))
        self.state = "init"     # init -> wait_first -> ready -> done|fallback
        self.rounds = 0
        self.accepted = 0
        self.scored = 0
        self.accept_ema = 1.0
        self.fell_back = False
        self._first_tok: Optional[int] = None
        self._first_t: Optional[float] = None

    # -- event-loop interface ----------------------------------------------

    def candidate_time(self) -> Optional[float]:
        """Virtual time of the session's next self-driven action: the device
        prefill (init) or the next draft window (ready). ``None`` while
        blocked on the server's first token or after done/fallback."""
        if self.state == "init" or self.state == "ready":
            return self.dev.t
        return None

    def on_first_token(self, tok: int, t: float) -> None:
        """The server's committed prefill token reached the device: resync
        the draft chain onto it (whatever the device drew at position S) and
        open the round loop."""
        self._first_tok = int(tok)
        self._first_t = float(t)
        if self.state != "wait_first":
            return                  # device prefill still pending: sync there
        if self.server.is_finished(self.rid):
            self.state = "done"
            return
        self.dev.force_pending(self._first_tok)
        self.dev.wait_until(self._first_t)
        self.state = "ready"

    def run_round(self, rng) -> None:
        """Execute the session's next action at the loop frontier: the
        device prefill, or one full draft -> uplink -> verify -> downlink ->
        rewind round."""
        if self.state == "init":
            try:
                self.dev.prefill()
            except RuntimeError:
                # device KV pool exhausted: plain server decode already runs
                self._fallback()
                return
            self.state = "wait_first"
            if self._first_tok is not None:   # first token already landed
                self.on_first_token(self._first_tok, self._first_t)
            return
        if self.state != "ready":
            return
        slot = self.server.slots.get(self.rid)
        if slot is not None and slot.remaining <= 1:
            # a verify round always commits >= 2 tokens (accepted prefix +
            # bonus/correction) — the final token must decode plainly. This
            # is graceful retirement, not a fallback.
            self._retire()
            return
        w = self.dev.draft_window(self.k)
        if w is None:
            self._fallback()        # device saturated / pool exhausted
            return
        drafts, dev_probs, t_draft_done = w
        res = self.server.verify_step(
            self.rid, drafts, dev_probs, at=t_draft_done + self.srv.uplink,
        )
        if res is None:
            self._fallback()        # preempted / finished / out of budget
            return
        self.dev.draft_rewind(res["accepted"], res["tokens"][-1])
        self.rounds += 1
        self.accepted += res["accepted"]
        self.scored += res["k"]
        rate = res["accepted"] / res["k"]
        self.accept_ema = (
            (1 - self.EMA_ALPHA) * self.accept_ema + self.EMA_ALPHA * rate
        )
        if self.accept_ema >= self.GROW_AT:
            self.k = min(self.k * 2, SPEC_K_MAX)
        elif self.accept_ema < self.SHRINK_AT:
            self.k = max(self.k // 2, 1)
        if self.tracer.enabled and self.drv_rid is not None:
            self.tracer.request_instant(
                self.drv_rid, "spec_round", res["t_end"],
                args={"k": res["k"], "accepted": res["accepted"],
                      "ema": round(self.accept_ema, 4)},
            )
        # the verdict crosses the downlink before the next window can start
        self.dev.wait_until(res["t_end"] + self.srv.downlink)
        if self.server.is_finished(self.rid):
            self.state = "done"
        elif (self.rounds >= self.COLLAPSE_MIN_ROUNDS
              and self.accept_ema < self.COLLAPSE_AT):
            self._fallback()        # acceptance collapsed: drafting is waste

    def _fallback(self) -> None:
        """Revert to plain autonomous server decode (race-winner state):
        the verify rid resumes fused batched decode losslessly (replayable
        sampling) and the device stops drafting."""
        self.fell_back = True
        self.state = "fallback"
        if self.tracer.enabled and self.drv_rid is not None:
            self.tracer.request_instant(
                self.drv_rid, "spec_fallback", self.dev.t,
                args={"rounds": self.rounds, "ema": round(self.accept_ema, 4)},
            )
        self.server.end_verify(self.rid)
        self.dev.cancel()

    def _retire(self) -> None:
        """Normal end-of-request wind-down: hand the tail back to plain
        server decode without marking the session as a fallback."""
        self.state = "done"
        self.server.end_verify(self.rid)
        self.dev.cancel()

    @property
    def verify_positions(self) -> int:
        """Server positions scored inside fused verify dispatches — priced
        like prefill tokens (batch-scored), not decode tokens."""
        return self.server.verify_positions.get(self.rid, 0)


class DiSCoServer:
    """Event-driven multi-request DiSCo runtime.

    ``serve_many`` replays a whole arrival trace through the stack;
    ``serve`` is the single-request convenience wrapper (same event loop,
    one request).
    """

    # driver ledgers live in the registry too (the single backing store);
    # the descriptors keep attribute reads/increments working unchanged
    slo_dispatch_overrides = metric_attr("slo_dispatch_overrides")
    spec_requests = metric_attr("spec_requests")
    spec_fallbacks = metric_attr("spec_fallbacks")

    def __init__(
        self,
        scheduler: DiSCoScheduler,
        device: DeviceEndpoint,
        server: ServerEndpoint,
        rng: Optional[np.random.Generator] = None,
        cancel_losers: bool = True,
        allow_migration: bool = True,
        slo_aware_dispatch: bool = True,
        mode: str = "race",
        spec_k_init: int = 4,
        tracer=None,
    ):
        if mode not in ("race", "speculative"):
            raise ValueError(f"mode must be 'race' or 'speculative' (got {mode!r})")
        self.sched = scheduler
        self.device = device
        self.server = server
        self.rng = rng or np.random.default_rng(0)
        self.cancel_losers = cancel_losers
        self.allow_migration = allow_migration   # False for single-endpoint
                                                 # baselines (vLLM/llama.cpp)
        # consult req.slo when racing endpoints (False pins the pure
        # cost-policy dispatch — the single-endpoint benchmark baselines)
        self.slo_aware_dispatch = slo_aware_dispatch
        self.metrics = MetricsRegistry()         # driver-level ledger store
        self.slo_dispatch_overrides = 0
        # "speculative": requests the dispatch policy sends to BOTH
        # endpoints run device-draft / server-verify rounds instead of the
        # race (requires a speculative BatchedServer and a draftable device
        # engine; ineligible requests fall back to race-and-cancel)
        self.mode = mode
        self.spec_k_init = int(spec_k_init)
        self.spec_requests = 0       # requests served speculatively
        self.spec_fallbacks = 0      # sessions that reverted to plain decode
        self._frontier = 0.0
        self._next_rid = 0
        self.tracer = NULL_TRACER
        self.set_tracer(tracer)

    # -- public API --------------------------------------------------------

    def set_tracer(self, tracer) -> None:
        """Attach one telemetry tracer to EVERY layer of the stack — the
        driver, both endpoints (device/network spans), the shared batched
        server, and its paged KV manager — so all events land on one shared
        virtual timeline. Pass None to detach."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.device.tracer = self.tracer
        self.server.tracer = self.tracer
        self.server.server.set_tracer(tracer)

    def stats(self) -> dict:
        """The one documented stats surface for the whole stack: the shared
        batched server's registry snapshot (memory pressure, SLO accounting,
        prefix cache, speculative verify — see
        :meth:`~repro.serving.engine.BatchedServer.pool_stats`) merged with
        the driver's own ledgers (``slo_dispatch_overrides``,
        ``spec_requests``, ``spec_fallbacks``). Every value is
        registry-backed; ``telemetry.reconcile_trace`` cross-checks a trace
        against this dict. Device engines hold per-request state only and
        have nothing to aggregate."""
        out = self.server.server.pool_stats()
        out.update(self.metrics.snapshot())
        return out

    def pool_stats(self) -> dict:
        """Deprecated alias of :meth:`stats` (it used to passthrough to the
        shared server only; ``stats()`` additionally includes the driver
        ledgers)."""
        warnings.warn(
            "DiSCoServer.pool_stats() is deprecated; use DiSCoServer.stats()",
            DeprecationWarning, stacklevel=2,
        )
        return self.stats()

    def serve(self, prompt, max_new: Optional[int] = None, **req_kwargs
              ) -> RequestResult:
        """Serve one request arriving "now". Thin deprecated shim over
        ``serve_many``: the arrival is the max of the runtime frontier and
        the shared server's clock (and, for a ``Request`` argument, the
        request's own ``arrival``), so repeated calls see a monotonic
        timeline exactly as the old tuple API did.

        Accepts either ``serve(prompt, max_new, **request_fields)`` or a
        ready-built ``Request`` (alone — extra arguments would be silently
        shadowed by the request's own fields, so they are rejected)."""
        warnings.warn(
            "DiSCoServer.serve() is a deprecated shim; build a Request and "
            "use serve_many([req])",
            DeprecationWarning, stacklevel=2,
        )
        at = max(self._frontier, self.server.server.clock)
        if isinstance(prompt, Request):
            if max_new is not None or req_kwargs:
                raise TypeError(
                    "serve(Request, ...) takes no extra arguments: set "
                    "max_new/sampler/slo/... on the Request itself"
                )
            req = dataclasses.replace(prompt, arrival=max(at, prompt.arrival))
        else:
            req = Request(prompt, int(max_new), arrival=at, **req_kwargs)
        return self.serve_many([req])[0]

    def serve_many(self, requests: Iterable[Request]) -> list[RequestResult]:
        """Replay :class:`~repro.serving.request.Request`s through the full
        stack; returns ``RequestResult``s in arrival order."""
        reqs = []
        for r in requests:
            if not isinstance(r, Request):
                raise TypeError(
                    "serve_many now takes repro.serving.Request objects; the "
                    "(arrival, prompt, max_new) tuple API was removed — build "
                    "Request(prompt, max_new, arrival=..., sampler=..., "
                    "slo=...) instead (see serving.request)."
                )
            reqs.append(r)
        pending = deque(sorted(reqs, key=lambda r: r.arrival))
        live: list[_Req] = []
        order: list[int] = []
        results: dict[int, RequestResult] = {}

        while pending or live:
            # finalize requests that can emit nothing further
            for r in list(live):
                if self._ready_to_finalize(r):
                    results[r.rid] = self._finalize(r)
                    live.remove(r)
            if not pending and not live:
                break

            next_arrival = pending[0].arrival if pending else math.inf

            # pull-driven (device-side) candidates: an un-activated stream's
            # candidate is its virtual start time; an activated one computes
            # at most one fused chunk beyond the frontier to learn its next
            # event time. Speculative sessions are pull-driven too: their
            # candidate is the next self-driven action (device prefill or
            # draft window), executed only once the frontier reaches it.
            best = None   # (t, rid, req, stream, kind)
            for r in live:
                if r.spec is not None:
                    t = r.spec.candidate_time()
                    if t is not None:
                        cand = (t, r.rid, r, r.spec, "spec")
                        if best is None or cand[:2] < best[:2]:
                            best = cand
                for st in self._streams_of(r):
                    if not st.pull_driven:
                        continue
                    if not st.activated:
                        cand = (st.start_at, r.rid, r, st, "activate")
                    else:
                        t = st.candidate_time()
                        if t is None:
                            continue
                        cand = (t, r.rid, r, st, "event")
                    if best is None or cand[:2] < best[:2]:
                        best = cand

            # advance the shared contended server: nothing else can happen
            # before this horizon, so any server token earlier than it must
            # be discovered now (the last chunk may overshoot — that is the
            # in-flight compute a cancellation cannot recall)
            horizon = min(next_arrival, best[0] if best else math.inf)
            self.server.server.run_until(horizon)
            for r in live:
                for st in self._streams_of(r):
                    if st.pull_driven:
                        continue
                    t = st.candidate_time()
                    if t is None:
                        continue
                    cand = (t, r.rid, r, st, "event")
                    if best is None or cand[:2] < best[:2]:
                        best = cand

            t_event = best[0] if best else math.inf
            if next_arrival <= t_event:
                if not pending:
                    continue   # nothing runnable; finalize pass handles live
                nxt = pending.popleft()
                self._frontier = max(self._frontier, nxt.arrival)
                r = self._admit(nxt)
                live.append(r)
                order.append(r.rid)
                continue

            t, _, r, st, kind = best
            self._frontier = max(self._frontier, t)
            if kind == "activate":
                st.activate()   # dispatch the device prefill at its start time
                continue
            if kind == "spec":
                st.run_round(self.rng)   # prefill, or one draft→verify round
                continue
            self._on_event(r, st, st.pop())

        return [results[rid] for rid in order]

    # -- request lifecycle -------------------------------------------------

    def _consult_slo(self, req: Request,
                     decision: DispatchDecision) -> DispatchDecision:
        """Deadline-aware dispatch (§4.2 + Andes/Synera: per-request SLO
        metadata at the scheduling boundary): when the request carries a
        finite TTFT deadline, override the pure cost policy where the
        deadline is at risk —

        * if the profiled server-TTFT tail says the server alone is likely
          to miss the deadline, bring the device into the race;
        * never idle-wait the device past half the deadline budget (the
          wait policy trades cost for TTFT — a deadline caps that trade).
        """
        d = req.slo.ttft_deadline
        if not self.slo_aware_dispatch or not math.isfinite(d):
            return decision
        use_server = decision.use_server
        use_device = decision.use_device
        wait = decision.device_wait
        p_server_meets = float(self.sched.server_ttft.cdf(d)) if use_server else 0.0
        if not use_device and p_server_meets < 1.0 - self.sched.tail_ratio:
            use_device = True
            wait = 0.0
        if use_device:
            wait = min(wait, 0.5 * d)
        changed = (use_device != decision.use_device
                   or wait != decision.device_wait)
        if not changed:
            return decision
        self.slo_dispatch_overrides += 1
        return DispatchDecision(
            use_server=use_server, use_device=use_device, device_wait=wait
        )

    def _admit(self, req: Request) -> _Req:
        rid = self._next_rid
        self._next_rid += 1
        # the request's sampling seed: defaulted from the driver rid and
        # handed (inside the resolved Request) to BOTH racing streams and
        # any later migration replay, so with identical endpoint models
        # every stream of this request draws the same token at the same
        # absolute position (models.sampling) — the consistent-prefix
        # hand-off stays bit-identical under temperature
        req = dataclasses.replace(
            req,
            rid=rid if req.rid is None else req.rid,
            seed=rid if req.seed is None else req.seed,
        )
        base = self.sched.plan_request(req.prompt_len, self.rng)
        decision = self._consult_slo(req, base)
        self.sched.observe_prompt_length(req.prompt_len)
        r = _Req(rid=rid, req=req, decision=decision)
        if self.tracer.enabled:
            d = req.slo.ttft_deadline
            self.tracer.begin_request(
                rid, req.arrival,
                args={
                    "prompt_tokens": int(req.prompt_len),
                    "max_new": int(req.max_new),
                    "ttft_deadline_s": float(d) if math.isfinite(d) else None,
                    "priority": int(req.priority),
                    "seed": int(req.seed),
                },
            )
        if self._speculative_eligible(decision):
            # device-draft / server-verify replaces the race: ONE delivery
            # stream (the server's), the device drafts instead of decoding
            self.spec_requests += 1
            st = self.server.open_verify_stream(
                req, self.rng, start_at=req.arrival
            )
            r.streams[Endpoint.SERVER] = st
            r.all_streams.append(st)
            dev = self.device.open_draft_session(
                req, self.rng, start_at=req.arrival
            )
            r.all_streams.append(dev)
            r.spec = _SpecSession(
                dev, st, k_init=self.spec_k_init,
                tracer=self.tracer, drv_rid=rid,
            )
            self._trace_dispatch(r, decision is not base, srv_rid=st.rid,
                                 spec=True)
            return r
        srv_rid = None
        if decision.use_server:
            st = self.server.open_stream(req, self.rng, start_at=req.arrival)
            r.streams[Endpoint.SERVER] = st
            r.all_streams.append(st)
            srv_rid = st.rid
        if decision.use_device and math.isfinite(decision.device_wait):
            st = self.device.open_stream(
                req, self.rng, start_at=req.arrival + decision.device_wait,
            )
            r.streams[Endpoint.DEVICE] = st
            r.all_streams.append(st)
        self._trace_dispatch(r, decision is not base, srv_rid=srv_rid)
        return r

    def _trace_dispatch(self, r: _Req, slo_override: bool,
                        srv_rid: Optional[int] = None,
                        spec: bool = False) -> None:
        """Record the dispatch decision (and which signal drove it) on the
        request's async span. ``srv_rid`` joins the driver-level request to
        its server-side lifecycle in trace analysis."""
        if not self.tracer.enabled:
            return
        d = r.decision
        wait = d.device_wait
        self.tracer.request_instant(
            r.rid, "dispatch", r.req.arrival,
            args={
                "use_server": bool(d.use_server),
                "use_device": bool(d.use_device),
                "device_wait_s": float(wait) if math.isfinite(wait) else None,
                "slo_override": bool(slo_override),
                "mode": "speculative" if spec else "race",
                "srv_rid": srv_rid,
            },
        )

    def _speculative_eligible(self, decision: DispatchDecision) -> bool:
        """A request runs draft/verify only when the dispatch policy would
        have engaged BOTH endpoints anyway (use_server alone → plain server
        decode is already optimal; use_device alone → there is no verifier)
        and both engines support it. Ineligible requests keep the race —
        ``mode="speculative"`` degrades per-request, never hard-fails."""
        return (
            self.mode == "speculative"
            and decision.use_server
            and decision.use_device
            and getattr(self.device, "supports_draft", False)
            and getattr(self.server, "supports_verify", False)
        )

    def _streams_of(self, r: _Req) -> list:
        out = [st for st in r.streams.values() if not st.done]
        if r.mig_stream is not None and not r.mig_stream.done:
            out.append(r.mig_stream)
        return out

    def _ready_to_finalize(self, r: _Req) -> bool:
        if not r.done and self._streams_of(r):
            return False
        if r.done and not self.cancel_losers:
            # control runtime: losers keep generating to completion — hold
            # the request open so their contention and waste are realized
            return not self._streams_of(r)
        # a cancelled server loser keeps wasting tokens until its cancel
        # crosses the uplink: hold the request open so the loop advances the
        # server past the landing and the waste accounting is final
        if any(getattr(st, "cancel_in_flight", False) for st in r.all_streams):
            return False
        return True

    # -- event handling ----------------------------------------------------

    def _on_event(self, r: _Req, st, ev) -> None:
        if r.winner is None:
            # the race (§4.2): earliest first token wins
            r.winner = st.kind
            r.first_t = ev.t
            r.delivery = st
            r.buf = TokenBuffer(
                self.sched.migration_controller.config.consumption_rate, ev.t
            )
            r.tokens = [ev.token]
            if self.tracer.enabled:
                self.tracer.request_instant(
                    r.rid, "first_token", ev.t,
                    args={"winner": st.kind.name.lower(),
                          "ttft_s": ev.t - r.arrival},
                )
            if r.spec is not None:
                # resync the device drafter onto the server's committed
                # token: the next window drafts continuations of ev.token
                r.spec.on_first_token(ev.token, ev.t)
            if self.cancel_losers:
                for other in r.streams.values():
                    if other is not st:
                        # issued at the winner's first-token time: a server-
                        # side loser is reached one uplink RTT later, so a
                        # queued loser can still slip into prefill meanwhile
                        other.cancel(at=ev.t)
                        if self.tracer.enabled:
                            self.tracer.request_instant(
                                r.rid, "cancel_issued", ev.t,
                                args={"target": other.kind.name.lower()},
                            )
            if len(r.tokens) >= r.max_new:
                r.done = True
                return
            if not self.allow_migration or r.spec is not None:
                # speculative requests already use both endpoints in concert;
                # migrating the delivery stream mid-flight would orphan the
                # verify slot
                return
            r.plan = self.sched.plan_migration(
                current=r.winner,
                prompt_len=len(r.prompt),
                generated=1,
                expected_total_tokens=float(r.max_new),
                target_prefill_rate=max(
                    len(r.prompt) / max(ev.t - r.arrival, 1e-3), 1.0
                ),
            )
            return

        if st is r.mig_stream:
            if not r.handoff_done:
                # Fig. 4: the target is ready; the source stops. Tokens the
                # source delivered during the hand-off were regenerated by
                # the target's replay — skip that prefix so delivery stays a
                # single consistent stream.
                r.handoff_done = True
                r.mig_skip = len(r.tokens) - r.mig_prefix
                if self.cancel_losers:
                    r.delivery.cancel(at=ev.t)
                r.delivery = st
                if self.tracer.enabled:
                    self.tracer.request_instant(
                        r.rid, "handoff_done", ev.t,
                        args={"skipped": r.mig_skip},
                    )
            if r.mig_skip > 0:
                r.mig_skip -= 1
                return
            self._deliver(r, ev)
            return

        if st is not r.delivery:
            return   # loser residue (no-cancellation control) — discarded

        self._deliver(r, ev)
        if (
            r.plan is not None
            and r.mig_stream is None
            and not r.done
            and r.buf.occupancy(ev.t) >= r.plan.buffer_needed
            and len(r.tokens) < r.max_new - 1
        ):
            self._start_handoff(r, ev.t)

    def _deliver(self, r: _Req, ev) -> None:
        r.buf.push(ev.t)
        r.tokens.append(ev.token)
        if len(r.tokens) >= r.max_new:
            r.done = True

    def _start_handoff(self, r: _Req, t: float) -> None:
        target_ep = self.device if r.plan.target is Endpoint.DEVICE else self.server
        r.migrated = True     # hand-off initiated (the source may still finish
                              # first if the remaining stream is short)
        r.mig_prefix = len(r.tokens)
        if self.tracer.enabled:
            self.tracer.request_instant(
                r.rid, "migration_start", t,
                args={"target": r.plan.target.name.lower(),
                      "delivered": r.mig_prefix},
            )
        r.mig_stream = target_ep.open_replay_stream(
            r.req, list(r.tokens), self.rng, start_at=t,
        )
        r.all_streams.append(r.mig_stream)

    # -- completion --------------------------------------------------------

    def _finalize(self, r: _Req) -> RequestResult:
        for st in r.all_streams:
            if not st.done:
                st.cancel()
        # online TTFT profiling (§4.2): the server's first-token time is
        # known whenever its prefill actually ran, even for a cancelled loser
        srv = r.streams.get(Endpoint.SERVER)
        if srv is not None:
            t_first = srv.first_token_at
            if t_first is not None:
                self.sched.observe_server_ttft(t_first - r.arrival)

        generated = sum(st.tokens_generated for st in r.all_streams)
        delivered = len(r.tokens)
        # an ACCEPTED draft was computed on the device AND delivered through
        # the server's verify round — useful work on both ends, not waste.
        # Rejected drafts stay in the waste: the device computed them and the
        # server scored them for nothing (satellite accounting contract).
        useful = delivered + (r.spec.accepted if r.spec is not None else 0)
        cost = 0.0
        for st in r.all_streams:
            if st.prefilled:
                cost += self.sched.cost_model.prefill_cost(st.kind) * st.prefill_tokens
            cost += self.sched.cost_model.decode_cost(st.kind) * st.tokens_generated
        if r.spec is not None:
            if r.spec.fell_back:
                self.spec_fallbacks += 1
            # verify rounds score k+1 positions in ONE teacher-forced
            # dispatch — prefill-shaped work, not k+1 sequential decode
            # steps. `generated` above priced them at decode rate; re-price
            # the delta so unified cost reflects the batched scoring.
            cm = self.sched.cost_model
            cost += (
                cm.prefill_cost(Endpoint.SERVER) - cm.decode_cost(Endpoint.SERVER)
            ) * r.spec.verify_positions

        winner = r.winner if r.winner is not None else (
            Endpoint.SERVER if r.decision.use_server else Endpoint.DEVICE
        )
        # Andes-style QoE: score the PACED delivery timeline (what the user
        # saw through the consumption-rate buffer) against the request's SLO
        delivery_times = list(r.buf.delivered_at) if r.buf is not None else []
        qoe = QoEReport.from_timeline(
            r.arrival, delivery_times, r.req.slo, rid=r.rid
        )
        result = RequestResult(
            request=r.req,
            tokens=list(r.tokens),
            ttft=(r.first_t - r.arrival) if r.winner is not None else math.inf,
            tbt_series=r.buf.tbt_series() if r.buf is not None else [],
            cost=cost * r.req.cost_weight,
            winner=winner,
            migrated=r.migrated,
            delayed_tokens=r.buf.delayed_tokens() if r.buf is not None else 0,
            generated_tokens=generated,
            wasted_tokens=generated - useful,
            qoe=qoe,
        )
        if self.tracer.enabled:
            # the delivered token list is the trace's replay-identity payload
            # (telemetry.replay_projection): same-seed runs must match it
            # bit-for-bit even though virtual timestamps legitimately differ
            self.tracer.end_request(
                r.rid, max(self._frontier, r.arrival),
                args={
                    "outcome": "finished",
                    "tokens": [int(t) for t in r.tokens],
                    "delivered": delivered,
                    "generated": int(generated),
                    "wasted": int(generated - useful),
                    "winner": winner.name.lower(),
                    "migrated": bool(r.migrated),
                    "ttft_s": (
                        result.ttft if math.isfinite(result.ttft) else None
                    ),
                    "cost": float(result.cost),
                    "qoe_score": float(qoe.qoe_score),
                },
            )
        return result
