"""DiSCo serving driver: the middleware loop over two real engines (Fig. 1).

For each request:
  1. dispatch (§4.2): plan_request gives {use_server, use_device, device_wait}
  2. race: both endpoints stream tokens on a shared virtual timeline; the
     first first-token wins, the loser is cancelled
  3. migration (§4.3): if the winner is the expensive decoder, hand off to
     the other endpoint once the delivery buffer holds B tokens; the target
     re-prefills prompt + generated token IDs (no state transfer)
  4. delivery: tokens are paced at the consumption rate r_c via TokenBuffer;
     QoE (TTFT, TBT series) and unified cost are recorded

Compute times are real JAX wall-clock; network and queueing are sampled
(see serving.endpoint). Everything is deterministic given the rng.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import (
    CostModel,
    DiSCoScheduler,
    Endpoint,
    MigrationConfig,
    TokenBuffer,
)

from .endpoint import DeviceEndpoint, ServerEndpoint, TokenEvent

__all__ = ["ServedRequest", "DiSCoServer"]


@dataclasses.dataclass
class ServedRequest:
    tokens: list[int]
    ttft: float
    tbt_series: list[float]
    cost: float
    winner: Endpoint
    migrated: bool
    delayed_tokens: int


class DiSCoServer:
    def __init__(
        self,
        scheduler: DiSCoScheduler,
        device: DeviceEndpoint,
        server: ServerEndpoint,
        rng: Optional[np.random.Generator] = None,
    ):
        self.sched = scheduler
        self.device = device
        self.server = server
        self.rng = rng or np.random.default_rng(0)

    def _prefill_cost(self, ep: Endpoint, n: int) -> float:
        return self.sched.cost_model.prefill_cost(ep) * n

    def _decode_cost(self, ep: Endpoint, n: int) -> float:
        return self.sched.cost_model.decode_cost(ep) * n

    def serve(self, prompt: np.ndarray, max_new: int) -> ServedRequest:
        decision = self.sched.plan_request(len(prompt), self.rng)
        cost = 0.0

        streams: dict[Endpoint, list[TokenEvent]] = {}
        if decision.use_server:
            streams[Endpoint.SERVER] = self.server.stream(
                prompt, max_new, self.rng, start_at=0.0
            )
            cost += self._prefill_cost(Endpoint.SERVER, len(prompt))
        if decision.use_device:
            streams[Endpoint.DEVICE] = self.device.stream(
                prompt, max_new, self.rng, start_at=decision.device_wait
            )

        # race: earliest first token wins; the loser terminates (§4.2)
        winner = min(streams, key=lambda e: streams[e][0].t)
        events = streams[winner]
        first_t = events[0].t
        if decision.use_device:
            # device energy is spent only if it actually started prefilling
            # before the server produced a first token
            server_first = (
                streams[Endpoint.SERVER][0].t if decision.use_server else np.inf
            )
            if server_first > decision.device_wait:
                cost += self._prefill_cost(Endpoint.DEVICE, len(prompt))
        self.sched.observe_prompt_length(len(prompt))
        if decision.use_server:
            self.sched.observe_server_ttft(streams[Endpoint.SERVER][0].t)

        # migration decision (§4.3)
        mig_cfg = self.sched.migration_controller.config
        buf = TokenBuffer(mig_cfg.consumption_rate, first_t)
        tokens = [events[0].token]
        cost += self._decode_cost(winner, 1)
        migrated = False

        target_ep = (
            self.device if self.sched.cost_model.cheaper_decode_endpoint()
            is Endpoint.DEVICE else self.server
        )
        plan = self.sched.plan_migration(
            current=winner,
            prompt_len=len(prompt),
            generated=1,
            expected_total_tokens=float(max_new),
            target_prefill_rate=max(
                len(prompt) / max(events[0].t, 1e-3), 1.0
            ),
        )

        if plan is None:
            for ev in events[1:]:
                buf.push(ev.t)
                tokens.append(ev.token)
                cost += self._decode_cost(winner, 1)
            return ServedRequest(
                tokens=tokens,
                ttft=first_t,
                tbt_series=buf.tbt_series(),
                cost=cost,
                winner=winner,
                migrated=False,
                delayed_tokens=0,
            )

        # stream from the source until the buffer can mask the hand-off
        handoff_idx = None
        for i, ev in enumerate(events[1:], start=1):
            buf.push(ev.t)
            tokens.append(ev.token)
            cost += self._decode_cost(winner, 1)
            if buf.occupancy(ev.t) >= plan.buffer_needed:
                handoff_idx = i
                break
        if handoff_idx is not None and handoff_idx < max_new - 1:
            start = events[handoff_idx].t
            cont = target_ep.replay_stream(
                prompt, tokens, max_new - len(tokens), self.rng, start_at=start
            )
            cost += self._prefill_cost(plan.target, len(prompt) + len(tokens))
            # Fig. 4: source keeps generating until the target is ready
            target_ready = cont[0].t if cont else start
            for ev in events[handoff_idx + 1 :]:
                if ev.t >= target_ready:
                    break
                buf.push(ev.t)
                tokens.append(ev.token)
                cost += self._decode_cost(winner, 1)
            for ev in cont:
                if len(tokens) >= max_new:
                    break
                buf.push(max(ev.t, target_ready))
                tokens.append(ev.token)
                cost += self._decode_cost(plan.target, 1)
            migrated = True
        else:
            pass  # buffer never filled: finish on the source

        return ServedRequest(
            tokens=tokens,
            ttft=first_t,
            tbt_series=buf.tbt_series(),
            cost=cost,
            winner=winner,
            migrated=migrated,
            delayed_tokens=buf.delayed_tokens(),
        )
