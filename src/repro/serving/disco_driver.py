"""DiSCo serving runtime: an event-driven middleware loop over two real
engines (Fig. 1), holding MANY concurrent requests.

The runtime is a discrete-event loop on a shared virtual timeline. Compute
times are real JAX wall-clock measurements; network RTT is sampled; server
queueing *emerges* from slot contention in the shared ``BatchedServer``.
Everything is deterministic given the rng.

Per request:
  1. dispatch (§4.2): ``plan_request`` gives {use_server, use_device,
     device_wait}
  2. race: both endpoints stream tokens lazily on the shared timeline; the
     first first-token wins and the loser is **cancelled** — it stops after
     at most one in-flight decode chunk instead of generating all ``max_new``
     tokens (the §4.2 cost saving, measurable via ``wasted_tokens``)
  3. migration (§4.3): if the winner is the expensive decoder, hand off once
     the delivery buffer holds B tokens; the target re-prefills prompt +
     generated token IDs (no state transfer). A server-bound re-prefill is
     submitted to the SAME contended batched scheduler as live traffic. The
     source keeps generating until the target's first token arrives; the
     target's regeneration of tokens the source delivered during the
     hand-off is skipped (consistent-prefix hand-off), so with identical
     endpoint models the delivered stream is bit-identical to no-migration.
  4. delivery: tokens are paced at the consumption rate r_c via TokenBuffer;
     QoE (TTFT, TBT series), unified cost, and wasted compute are recorded.

Event-loop causality: device-side streams are *pull-driven* — a stream is
activated (prefill dispatched) only when the virtual frontier reaches its
start time, and it computes at most one fused chunk beyond the frontier.
The shared server is *clock-driven* — the loop advances it with
``run_until(horizon)`` where the horizon is the earliest other possible
event, so no server compute runs ahead of anything that could cancel it by
more than the one chunk already in flight.

``cancel_losers=False`` turns the runtime into the no-cancellation control
(both streams always run to completion): the baseline against which the
wasted-compute reduction is measured.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Iterable, Optional, Tuple

import numpy as np

from repro.core import (
    DiSCoScheduler,
    Endpoint,
    TokenBuffer,
)

from .endpoint import DeviceEndpoint, ServerEndpoint

__all__ = ["ServedRequest", "DiSCoServer"]


@dataclasses.dataclass
class ServedRequest:
    tokens: list[int]
    ttft: float
    tbt_series: list[float]
    cost: float
    winner: Endpoint
    migrated: bool
    delayed_tokens: int
    arrival: float = 0.0
    generated_tokens: int = 0   # tokens actually computed across all streams
    wasted_tokens: int = 0      # generated but never delivered (race losers,
                                # cancellation overrun, hand-off catch-up)


@dataclasses.dataclass
class _Req:
    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: float
    decision: object
    seed: int = 0               # sampling seed shared by every stream of
                                # this request (race, migration replay)
    streams: dict = dataclasses.field(default_factory=dict)   # race streams
    all_streams: list = dataclasses.field(default_factory=list)
    winner: Optional[Endpoint] = None
    delivery: object = None
    buf: Optional[TokenBuffer] = None
    tokens: list = dataclasses.field(default_factory=list)
    first_t: float = math.nan
    plan: object = None
    mig_stream: object = None
    mig_prefix: int = 0
    mig_skip: int = 0
    handoff_done: bool = False
    migrated: bool = False
    done: bool = False


class DiSCoServer:
    """Event-driven multi-request DiSCo runtime.

    ``serve_many`` replays a whole arrival trace through the stack;
    ``serve`` is the single-request convenience wrapper (same event loop,
    one request).
    """

    def __init__(
        self,
        scheduler: DiSCoScheduler,
        device: DeviceEndpoint,
        server: ServerEndpoint,
        rng: Optional[np.random.Generator] = None,
        cancel_losers: bool = True,
        allow_migration: bool = True,
    ):
        self.sched = scheduler
        self.device = device
        self.server = server
        self.rng = rng or np.random.default_rng(0)
        self.cancel_losers = cancel_losers
        self.allow_migration = allow_migration   # False for single-endpoint
                                                 # baselines (vLLM/llama.cpp)
        self._frontier = 0.0
        self._next_rid = 0

    # -- public API --------------------------------------------------------

    def serve(self, prompt: np.ndarray, max_new: int) -> ServedRequest:
        """Serve one request arriving "now" (at the max of the runtime
        frontier and the shared server's clock, so repeated calls see a
        monotonic timeline)."""
        at = max(self._frontier, self.server.server.clock)
        return self.serve_many([(at, prompt, max_new)])[0]

    def serve_many(
        self, requests: Iterable[Tuple[float, np.ndarray, int]]
    ) -> list[ServedRequest]:
        """Replay ``(arrival, prompt, max_new)`` requests through the full
        stack; returns results in arrival order."""
        pending = deque(
            sorted(
                ((float(a), np.asarray(p, np.int32), int(m)) for a, p, m in requests),
                key=lambda x: x[0],
            )
        )
        live: list[_Req] = []
        order: list[int] = []
        results: dict[int, ServedRequest] = {}

        while pending or live:
            # finalize requests that can emit nothing further
            for r in list(live):
                if self._ready_to_finalize(r):
                    results[r.rid] = self._finalize(r)
                    live.remove(r)
            if not pending and not live:
                break

            next_arrival = pending[0][0] if pending else math.inf

            # pull-driven (device-side) candidates: an un-activated stream's
            # candidate is its virtual start time; an activated one computes
            # at most one fused chunk beyond the frontier to learn its next
            # event time
            best = None   # (t, rid, req, stream, is_activation)
            for r in live:
                for st in self._streams_of(r):
                    if not st.pull_driven:
                        continue
                    if not st.activated:
                        cand = (st.start_at, r.rid, r, st, True)
                    else:
                        t = st.candidate_time()
                        if t is None:
                            continue
                        cand = (t, r.rid, r, st, False)
                    if best is None or cand[:2] < best[:2]:
                        best = cand

            # advance the shared contended server: nothing else can happen
            # before this horizon, so any server token earlier than it must
            # be discovered now (the last chunk may overshoot — that is the
            # in-flight compute a cancellation cannot recall)
            horizon = min(next_arrival, best[0] if best else math.inf)
            self.server.server.run_until(horizon)
            for r in live:
                for st in self._streams_of(r):
                    if st.pull_driven:
                        continue
                    t = st.candidate_time()
                    if t is None:
                        continue
                    cand = (t, r.rid, r, st, False)
                    if best is None or cand[:2] < best[:2]:
                        best = cand

            t_event = best[0] if best else math.inf
            if next_arrival <= t_event:
                if not pending:
                    continue   # nothing runnable; finalize pass handles live
                arrival, prompt, max_new = pending.popleft()
                self._frontier = max(self._frontier, arrival)
                r = self._admit(arrival, prompt, max_new)
                live.append(r)
                order.append(r.rid)
                continue

            t, _, r, st, is_activation = best
            self._frontier = max(self._frontier, t)
            if is_activation:
                st.activate()   # dispatch the device prefill at its start time
                continue
            self._on_event(r, st, st.pop())

        return [results[rid] for rid in order]

    # -- request lifecycle -------------------------------------------------

    def _admit(self, arrival: float, prompt: np.ndarray, max_new: int) -> _Req:
        decision = self.sched.plan_request(len(prompt), self.rng)
        self.sched.observe_prompt_length(len(prompt))
        # the request's sampling seed: derived from the driver rid and handed
        # to BOTH racing streams and any later migration replay, so with
        # identical endpoint models every stream of this request draws the
        # same token at the same absolute position (models.sampling) — the
        # consistent-prefix hand-off stays bit-identical under temperature
        r = _Req(
            rid=self._next_rid, prompt=prompt, max_new=max_new,
            arrival=arrival, decision=decision, seed=self._next_rid,
        )
        self._next_rid += 1
        if decision.use_server:
            st = self.server.open_stream(
                prompt, max_new, self.rng, start_at=arrival, seed=r.seed
            )
            r.streams[Endpoint.SERVER] = st
            r.all_streams.append(st)
        if decision.use_device and math.isfinite(decision.device_wait):
            st = self.device.open_stream(
                prompt, max_new, self.rng,
                start_at=arrival + decision.device_wait, seed=r.seed,
            )
            r.streams[Endpoint.DEVICE] = st
            r.all_streams.append(st)
        return r

    def _streams_of(self, r: _Req) -> list:
        out = [st for st in r.streams.values() if not st.done]
        if r.mig_stream is not None and not r.mig_stream.done:
            out.append(r.mig_stream)
        return out

    def _ready_to_finalize(self, r: _Req) -> bool:
        if not r.done and self._streams_of(r):
            return False
        if r.done and not self.cancel_losers:
            # control runtime: losers keep generating to completion — hold
            # the request open so their contention and waste are realized
            return not self._streams_of(r)
        # a cancelled server loser keeps wasting tokens until its cancel
        # crosses the uplink: hold the request open so the loop advances the
        # server past the landing and the waste accounting is final
        if any(getattr(st, "cancel_in_flight", False) for st in r.all_streams):
            return False
        return True

    # -- event handling ----------------------------------------------------

    def _on_event(self, r: _Req, st, ev) -> None:
        if r.winner is None:
            # the race (§4.2): earliest first token wins
            r.winner = st.kind
            r.first_t = ev.t
            r.delivery = st
            r.buf = TokenBuffer(
                self.sched.migration_controller.config.consumption_rate, ev.t
            )
            r.tokens = [ev.token]
            if self.cancel_losers:
                for other in r.streams.values():
                    if other is not st:
                        # issued at the winner's first-token time: a server-
                        # side loser is reached one uplink RTT later, so a
                        # queued loser can still slip into prefill meanwhile
                        other.cancel(at=ev.t)
            if len(r.tokens) >= r.max_new:
                r.done = True
                return
            if not self.allow_migration:
                return
            r.plan = self.sched.plan_migration(
                current=r.winner,
                prompt_len=len(r.prompt),
                generated=1,
                expected_total_tokens=float(r.max_new),
                target_prefill_rate=max(
                    len(r.prompt) / max(ev.t - r.arrival, 1e-3), 1.0
                ),
            )
            return

        if st is r.mig_stream:
            if not r.handoff_done:
                # Fig. 4: the target is ready; the source stops. Tokens the
                # source delivered during the hand-off were regenerated by
                # the target's replay — skip that prefix so delivery stays a
                # single consistent stream.
                r.handoff_done = True
                r.mig_skip = len(r.tokens) - r.mig_prefix
                if self.cancel_losers:
                    r.delivery.cancel(at=ev.t)
                r.delivery = st
            if r.mig_skip > 0:
                r.mig_skip -= 1
                return
            self._deliver(r, ev)
            return

        if st is not r.delivery:
            return   # loser residue (no-cancellation control) — discarded

        self._deliver(r, ev)
        if (
            r.plan is not None
            and r.mig_stream is None
            and not r.done
            and r.buf.occupancy(ev.t) >= r.plan.buffer_needed
            and len(r.tokens) < r.max_new - 1
        ):
            self._start_handoff(r, ev.t)

    def _deliver(self, r: _Req, ev) -> None:
        r.buf.push(ev.t)
        r.tokens.append(ev.token)
        if len(r.tokens) >= r.max_new:
            r.done = True

    def _start_handoff(self, r: _Req, t: float) -> None:
        target_ep = self.device if r.plan.target is Endpoint.DEVICE else self.server
        r.migrated = True     # hand-off initiated (the source may still finish
                              # first if the remaining stream is short)
        r.mig_prefix = len(r.tokens)
        r.mig_stream = target_ep.open_replay_stream(
            r.prompt, list(r.tokens), r.max_new - len(r.tokens), self.rng,
            start_at=t, seed=r.seed,
        )
        r.all_streams.append(r.mig_stream)

    # -- completion --------------------------------------------------------

    def _finalize(self, r: _Req) -> ServedRequest:
        for st in r.all_streams:
            if not st.done:
                st.cancel()
        # online TTFT profiling (§4.2): the server's first-token time is
        # known whenever its prefill actually ran, even for a cancelled loser
        srv = r.streams.get(Endpoint.SERVER)
        if srv is not None:
            t_first = srv.first_token_at
            if t_first is not None:
                self.sched.observe_server_ttft(t_first - r.arrival)

        generated = sum(st.tokens_generated for st in r.all_streams)
        delivered = len(r.tokens)
        cost = 0.0
        for st in r.all_streams:
            if st.prefilled:
                cost += self.sched.cost_model.prefill_cost(st.kind) * st.prefill_tokens
            cost += self.sched.cost_model.decode_cost(st.kind) * st.tokens_generated

        winner = r.winner if r.winner is not None else (
            Endpoint.SERVER if r.decision.use_server else Endpoint.DEVICE
        )
        return ServedRequest(
            tokens=list(r.tokens),
            ttft=(r.first_t - r.arrival) if r.winner is not None else math.inf,
            tbt_series=r.buf.tbt_series() if r.buf is not None else [],
            cost=cost,
            winner=winner,
            migrated=r.migrated,
            delayed_tokens=r.buf.delayed_tokens() if r.buf is not None else 0,
            arrival=r.arrival,
            generated_tokens=generated,
            wasted_tokens=generated - delivered,
        )
