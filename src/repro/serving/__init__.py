"""Event-driven DiSCo serving stack over real JAX engines.

Three layers on one shared virtual timeline (compute = measured wall-clock,
network = sampled RTT, queueing = emergent slot contention):

* ``engine``  — jitted prefill/decode + ``EngineStream`` (lazy pulled token
  source) + ``BatchedServer`` (virtual-time continuous batching with
  per-row admission, incremental delivery, and ``cancel(rid)``).
* ``endpoint`` — ``DeviceTokenStream`` / ``ServerTokenStream`` incremental
  event sources racing on the timeline; cancellation stops a loser after at
  most one in-flight decode chunk.
* ``disco_driver`` — the discrete-event loop holding many concurrent
  requests: dispatch racing (§4.2), loser cancellation, token-ID migration
  into the same contended scheduler (§4.3), paced delivery + QoE/cost/waste
  accounting.
"""
from .disco_driver import DiSCoServer, ServedRequest
from .endpoint import (
    DeviceEndpoint,
    DeviceTokenStream,
    NetworkModel,
    ServerEndpoint,
    ServerTokenStream,
    TokenEvent,
)
from .engine import BatchedServer, EngineStream, GenerationResult, InferenceEngine

__all__ = [
    "DiSCoServer", "ServedRequest",
    "DeviceEndpoint", "NetworkModel", "ServerEndpoint", "TokenEvent",
    "DeviceTokenStream", "ServerTokenStream",
    "BatchedServer", "EngineStream", "GenerationResult", "InferenceEngine",
]
