"""Event-driven DiSCo serving stack over real JAX engines.

Three layers on one shared virtual timeline (compute = measured wall-clock,
network = sampled RTT, queueing = emergent slot contention):

* ``kv_pool``  — the paged KV-cache memory manager: a shared pool of fixed-
  size token blocks with per-request page tables (``BlockPool`` free-list +
  ``KVPoolManager`` alloc-on-prefill / extend-on-decode / free-on-cancel /
  clone-on-migration). Physical pool arrays live in ``repro.models.paged``;
  the Pallas paged-decode kernel in ``repro.kernels.paged_decode_attention``.
* ``engine``  — jitted prefill/decode + ``EngineStream`` (lazy pulled token
  source, per-request block allocation on paged engines) + ``BatchedServer``
  (virtual-time continuous batching; admission is block-capacity-driven on
  paged models, with recompute preemption when the pool runs dry, and
  ``cancel(rid)`` returns blocks within the same tick).
* ``endpoint`` — ``DeviceTokenStream`` / ``ServerTokenStream`` incremental
  event sources racing on the timeline; cancelling a server-side loser takes
  one uplink RTT to land (a queued loser can slip into prefill meanwhile),
  a device-side loser stops after at most one in-flight decode chunk.
* ``disco_driver`` — the discrete-event loop holding many concurrent
  requests: dispatch racing (§4.2), loser cancellation, token-ID migration
  into the same contended scheduler (§4.3), paced delivery + QoE/cost/waste
  accounting.

Sampling: every layer accepts a ``SamplerConfig`` (re-exported from
``repro.models.sampling`` — greedy argmax by default, or
temperature/top-k/top-p) plus a per-request integer seed
(``InferenceEngine.generate/open_stream``, ``BatchedServer.submit``,
endpoint ``open_stream``/``open_replay_stream``). Tokens are drawn with a
counter-based key — ``fold_in(request_key(seed), absolute_position)`` — so
migration, recompute preemption, and ``fork_stream`` stay bit-identical
under temperature > 0; the DiSCo driver derives one seed per request and
shares it across the device/server race and any migration replay.
"""
from repro.models.sampling import GREEDY, SamplerConfig, request_key

from .disco_driver import DiSCoServer, ServedRequest
from .endpoint import (
    DeviceEndpoint,
    DeviceTokenStream,
    NetworkModel,
    ServerEndpoint,
    ServerTokenStream,
    TokenEvent,
)
from .engine import BatchedServer, EngineStream, GenerationResult, InferenceEngine
from .kv_pool import BlockPool, KVPoolManager, PageTable, blocks_for_tokens

__all__ = [
    "DiSCoServer", "ServedRequest",
    "DeviceEndpoint", "NetworkModel", "ServerEndpoint", "TokenEvent",
    "DeviceTokenStream", "ServerTokenStream",
    "BatchedServer", "EngineStream", "GenerationResult", "InferenceEngine",
    "BlockPool", "KVPoolManager", "PageTable", "blocks_for_tokens",
    "GREEDY", "SamplerConfig", "request_key",
]
