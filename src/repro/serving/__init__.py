from .disco_driver import DiSCoServer, ServedRequest
from .endpoint import DeviceEndpoint, NetworkModel, ServerEndpoint, TokenEvent
from .engine import BatchedServer, GenerationResult, InferenceEngine

__all__ = [
    "DiSCoServer", "ServedRequest",
    "DeviceEndpoint", "NetworkModel", "ServerEndpoint", "TokenEvent",
    "BatchedServer", "GenerationResult", "InferenceEngine",
]
