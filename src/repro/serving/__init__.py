"""Event-driven DiSCo serving stack over real JAX engines.

The serving surface is built around one first-class contract
(``serving.request``): a :class:`Request` — prompt, token budget,
per-request :class:`SamplerConfig` + seed, :class:`SLO` deadline contract,
priority tier, cost weight — is the ONE argument threaded end-to-end
(``DiSCoServer.serve_many(list[Request])``, endpoint
``open_stream(req, rng, start_at)``, ``BatchedServer.submit(req, at=)``,
``InferenceEngine.open_stream(req)``), and every served request comes back
as a :class:`RequestResult` carrying an Andes-style :class:`QoEReport`
(expected-vs-actual delivery score, SLO attainment, TTFT/TBT stats).

Four layers on one shared virtual timeline (compute = measured wall-clock,
network = sampled RTT, queueing = emergent contention):

* ``kv_pool``  — the paged KV-cache memory manager: a shared pool of fixed-
  size REFCOUNTED token blocks with per-request page tables (``BlockPool``
  free-list + ``KVPoolManager`` alloc-on-prefill / extend-on-decode /
  free-on-cancel / clone-on-migration). Sealed (full) blocks can be ALIASED:
  ``clone`` and ``fork_stream`` are O(1) refcount bumps with copy-on-write
  only on a partial tail, and a radix :class:`PrefixIndex` keyed on
  token-ID block hashes caches released prefixes — an admission-time hit
  maps the matched blocks into the new request's table (zero device work)
  and ``paged_suffix_prefill`` computes only the unmatched suffix, bitwise-
  identical to the cold path. Unpinned cached prefixes are LRU-evicted
  under pool pressure and count as admission headroom. Physical pool arrays
  live in ``repro.models.paged``; the Pallas paged-decode kernel in
  ``repro.kernels.paged_decode_attention``.
* ``engine``  — jitted prefill/decode + ``EngineStream`` (lazy pulled token
  source, per-request block allocation on paged engines) + ``BatchedServer``
  (virtual-time continuous batching; admission is block-capacity-driven on
  paged models with recompute preemption when the pool runs dry, and
  **deadline-aware**: queued requests are ordered by priority tier then
  earliest TTFT deadline — EDF — with ``admission="fifo"`` as the baseline;
  ``slo_misses``/``deadline_reorders`` surface the effect).
* ``endpoint`` — ``DeviceTokenStream`` / ``ServerTokenStream`` incremental
  event sources racing on the timeline behind ONE shared signature
  ``open_stream(req, rng, start_at)``; cancelling a server-side loser takes
  one uplink RTT to land (a queued loser can slip into prefill meanwhile),
  a device-side loser stops after at most one in-flight decode chunk.
* ``disco_driver`` — the discrete-event loop holding many concurrent
  requests: dispatch racing (§4.2) that consults ``req.slo`` (a tight TTFT
  deadline pulls the device into the race and caps the wait policy), loser
  cancellation, token-ID migration into the same contended scheduler
  (§4.3), paced delivery + QoE/cost/waste accounting per request.
* ``cluster``  — the server tier scaled out: a
  :class:`DisaggregatedServer` splits one logical server into a prefill
  worker and a decode worker whose pools exchange finished KV state over a
  modeled :class:`InterconnectModel` (cross-pool ``detach``/``receive``
  block copy, lossless recompute fallback when the target pool is full),
  and a :class:`ClusterEndpoint` puts N replicas behind the ordinary
  ``ServerEndpoint`` surface — ``DiSCoServer`` races device-vs-FLEET
  unchanged, with load- and prefix-aware (sticky) routing per request.

Observability (``serving.telemetry``): every stat above is backed by one
:class:`MetricsRegistry` — ``BatchedServer.pool_stats()`` and
``DiSCoServer.stats()`` are registry *snapshots*, no number computed twice —
and a :class:`Tracer` (attach via ``DiSCoServer(..., tracer=...)`` or
``set_tracer``) records the full request lifecycle (dispatch, queueing,
prefill, decode chunks, preemption, cancel issue→land, migration, prefix
hits, block alloc/free/CoW, draft→verify rounds) on the shared virtual
timeline as Chrome trace-event JSON — open it at https://ui.perfetto.dev, or
run ``tools/trace_report.py`` for per-request TTFT attribution.  With no
tracer attached every hook is a :data:`NULL_TRACER` no-op.

Sampling is **per request**: ``Request.sampler`` (greedy argmax default, or
temperature/top-k/top-p) is stacked into per-row ``SamplerOperands`` — (B,)
runtime arrays threaded through the jitted step functions, never baked into
a jit closure — so heterogeneous configs coexist in one fused batch.
Tokens are drawn with a counter-based key —
``fold_in(request_key(seed), absolute_position)`` — so migration, recompute
preemption, and ``fork_stream`` stay bit-identical under temperature > 0;
the DiSCo driver derives one seed per request and shares it across the
device/server race and any migration replay.

``ServedRequest`` is the deprecated alias of ``RequestResult``;
``DiSCoServer.serve(prompt, max_new)`` is the one thin shim over the old
positional API (it builds the ``Request`` with the monotonic-frontier
arrival the tuple API had).
"""
from repro.models.sampling import (
    GREEDY,
    SamplerConfig,
    SamplerOperands,
    request_key,
    sampler_operands,
)

from .cluster import (
    ClusterEndpoint,
    ClusterServer,
    DisaggregatedServer,
    InterconnectModel,
)
from .disco_driver import DiSCoServer
from .endpoint import (
    DeviceDraftSession,
    DeviceEndpoint,
    DeviceTokenStream,
    NetworkModel,
    ServerEndpoint,
    ServerTokenStream,
    TokenEvent,
)
from .engine import BatchedServer, EngineStream, GenerationResult, InferenceEngine
from .kv_pool import (
    BlockPool,
    KVPoolManager,
    PageTable,
    PrefixIndex,
    blocks_for_tokens,
)
from .request import NO_SLO, SLO, QoEReport, Request, RequestResult
from .telemetry import (
    NULL_TRACER,
    MetricsRegistry,
    NullTracer,
    Tracer,
    reconcile_trace,
    replay_projection,
    request_records,
    trace_instants,
    trace_spans,
    ttft_attribution,
    validate_trace,
)


def __getattr__(name: str):
    if name == "ServedRequest":
        # deprecated alias — the warning fires in disco_driver's __getattr__
        from . import disco_driver

        return disco_driver.ServedRequest
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Request", "SLO", "NO_SLO", "QoEReport", "RequestResult",
    "DiSCoServer", "ServedRequest",
    "DeviceEndpoint", "NetworkModel", "ServerEndpoint", "TokenEvent",
    "ClusterEndpoint", "ClusterServer", "DisaggregatedServer",
    "InterconnectModel",
    "DeviceDraftSession", "DeviceTokenStream", "ServerTokenStream",
    "BatchedServer", "EngineStream", "GenerationResult", "InferenceEngine",
    "BlockPool", "KVPoolManager", "PageTable", "PrefixIndex",
    "blocks_for_tokens",
    "GREEDY", "SamplerConfig", "SamplerOperands", "request_key",
    "sampler_operands",
    "Tracer", "NullTracer", "NULL_TRACER", "MetricsRegistry",
    "validate_trace", "replay_projection", "reconcile_trace",
    "request_records", "trace_spans", "trace_instants", "ttft_attribution",
]
