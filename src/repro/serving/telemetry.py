"""Unified telemetry: virtual-timeline tracing + a metrics registry.

This module is the single observability substrate for the serving stack:

* :class:`MetricsRegistry` — counters / gauges / histograms that back every
  existing stats surface.  ``BatchedServer.pool_stats()`` and the DiSCo
  driver ledgers are *views* over one registry, so no number is computed
  twice and trace-derived aggregates can be reconciled against it exactly.
* :class:`Tracer` — records spans and instant events on the shared virtual
  timeline (seconds) and exports Chrome trace-event JSON that loads directly
  in Perfetto (https://ui.perfetto.dev).  Tracks map to processes/threads:
  a track name ``"server/row0"`` becomes process ``server``, thread ``row0``.
  Each request is one async span (``ph: b/n/e``) keyed by its request id.
* :class:`NullTracer` / :data:`NULL_TRACER` — the disabled path.  Every
  method is a no-op ``pass``; instrumentation call sites additionally guard
  args-dict construction behind ``if tracer.enabled`` so the overhead with
  telemetry off is a single attribute check + no-op call, far below the
  <2% budget asserted by ``bench_decode_throughput``.

Trace helpers (:func:`validate_trace`, :func:`replay_projection`,
:func:`reconcile_trace`, :func:`request_records`) are used by the tests, the
determinism gate in ``bench_e2e_serving`` and ``tools/trace_report.py``.

Why ``replay_projection`` instead of timestamp equality: virtual time is
advanced by *measured wall-clock* of the real JAX engines, so two same-seed
runs produce identical token streams but not identical timestamps.  The
replay-identity check therefore compares the projection of a trace onto
per-request delivered token streams, which must be bit-identical.
"""

from __future__ import annotations

import json
from collections import defaultdict
from typing import Any, Callable

_US = 1e6  # virtual seconds -> trace microseconds


def _jsonable(o: Any) -> Any:
    """json.dump fallback: numpy scalars/arrays -> python numbers/lists."""
    if hasattr(o, "item"):
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic (by convention) integer counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v: int) -> None:
        self.value = int(v)


class Gauge:
    """Point-in-time numeric value (e.g. blocks currently in use)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Streaming summary of observations (count/total/min/max)."""

    __slots__ = ("name", "count", "total", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def summary(self) -> dict:
        if self.count == 0:
            return {"count": 0, "total": 0.0, "mean": 0.0, "min": 0.0, "max": 0.0}
        return {
            "count": self.count,
            "total": self.total,
            "mean": self.total / self.count,
            "min": self.min,
            "max": self.max,
        }


class _View:
    __slots__ = ("name", "fn")

    def __init__(self, name: str, fn: Callable[[], Any]):
        self.name = name
        self.fn = fn


class MetricsRegistry:
    """Named metrics store; ``snapshot()`` renders every stats dict.

    Metrics are get-or-create by name; asking for an existing name with a
    different kind raises ``TypeError`` (one name, one meaning).  A *view*
    is a zero-arg callable evaluated at snapshot time — used for derived
    numbers (rates, set sizes, config echoes) so they are never stored and
    can never drift from their inputs.
    """

    def __init__(self):
        self._metrics: dict[str, Any] = {}

    def _get(self, name: str, kind: type):
        m = self._metrics.get(name)
        if m is None:
            m = kind(name)
            self._metrics[name] = m
        elif not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} is {type(m).__name__}, requested {kind.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def view(self, name: str, fn: Callable[[], Any]) -> None:
        self._metrics[name] = _View(name, fn)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def value(self, name: str) -> Any:
        m = self._metrics[name]
        if isinstance(m, _View):
            return m.fn()
        if isinstance(m, Histogram):
            return m.summary()
        return m.value

    def snapshot(self) -> dict:
        return {name: self.value(name) for name in self._metrics}


class metric_attr:
    """Data descriptor exposing a registry counter as a plain int attribute.

    ``self.preemptions += 1`` keeps working at every existing call site (and
    in every existing test) while the number itself lives in the registry —
    the registry is the single backing store, the attribute is a view.
    """

    __slots__ = ("metric", "registry_attr")

    def __init__(self, metric: str, registry_attr: str = "metrics"):
        self.metric = metric
        self.registry_attr = registry_attr

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return getattr(obj, self.registry_attr).counter(self.metric).value

    def __set__(self, obj, value) -> None:
        getattr(obj, self.registry_attr).counter(self.metric).set(value)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


class NullTracer:
    """Disabled tracer: every method is a no-op.

    Call sites keep a reference to this singleton-ish object and guard any
    non-trivial argument construction behind ``if tracer.enabled``.
    """

    __slots__ = ()
    enabled = False

    def span(self, track, name, t0, t1, cat="span", args=None) -> None:
        pass

    def instant(self, track, name, t, cat="instant", args=None) -> None:
        pass

    def value(self, track, name, t, v) -> None:
        pass

    def begin_request(self, rid, t, cat="request", name=None, args=None) -> None:
        pass

    def request_instant(self, rid, name, t, cat="request", args=None) -> None:
        pass

    def end_request(self, rid, t, cat="request", args=None) -> None:
        pass

    def export(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def save(self, path, metadata=None) -> None:
        raise RuntimeError("cannot save a NullTracer trace; pass a Tracer()")


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Records events on the virtual timeline; exports Chrome trace JSON.

    Track naming: ``"group/lane"`` -> process ``group`` / thread ``lane``
    (e.g. ``server/row0``, ``device/req3``, ``network/req3``, ``kv/pool``).
    Request lifecycles are async spans (``ph`` b/n/e) keyed by ``(cat, id)``
    so driver-level requests (cat ``request``) and server-side requests
    (cat ``server_request``, distinct id space) never collide.
    """

    __slots__ = ("events", "_pids", "_tids", "_open_async")
    enabled = True

    def __init__(self):
        self.events: list[dict] = []
        self._pids: dict[str, int] = {}
        self._tids: dict[str, tuple[int, int]] = {}
        self._open_async: dict[tuple, list[str]] = defaultdict(list)

    # -- track bookkeeping --------------------------------------------------

    def _ids(self, track: str) -> tuple[int, int]:
        ids = self._tids.get(track)
        if ids is not None:
            return ids
        group, _, lane = track.partition("/")
        pid = self._pids.get(group)
        if pid is None:
            pid = len(self._pids) + 1
            self._pids[group] = pid
            self.events.append(
                {
                    "ph": "M",
                    "name": "process_name",
                    "pid": pid,
                    "tid": 0,
                    "args": {"name": group},
                }
            )
        tid = sum(1 for t in self._tids.values() if t[0] == pid) + 1
        ids = (pid, tid)
        self._tids[track] = ids
        self.events.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": pid,
                "tid": tid,
                "args": {"name": lane or group},
            }
        )
        return ids

    # -- synchronous events -------------------------------------------------

    def span(self, track, name, t0, t1, cat="span", args=None) -> None:
        pid, tid = self._ids(track)
        ev = {
            "ph": "X",
            "name": name,
            "cat": cat,
            "pid": pid,
            "tid": tid,
            "ts": t0 * _US,
            "dur": max(0.0, (t1 - t0) * _US),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, track, name, t, cat="instant", args=None) -> None:
        pid, tid = self._ids(track)
        ev = {
            "ph": "i",
            "name": name,
            "cat": cat,
            "pid": pid,
            "tid": tid,
            "ts": t * _US,
            "s": "t",
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def value(self, track, name, t, v) -> None:
        pid, tid = self._ids(track)
        self.events.append(
            {
                "ph": "C",
                "name": name,
                "pid": pid,
                "tid": tid,
                "ts": t * _US,
                "args": {name: v},
            }
        )

    # -- async (per-request) spans ------------------------------------------

    def begin_request(self, rid, t, cat="request", name=None, args=None) -> None:
        name = name or f"req{rid}"
        pid, tid = self._ids(cat)
        ev = {
            "ph": "b",
            "name": name,
            "cat": cat,
            "id": rid,
            "pid": pid,
            "tid": tid,
            "ts": t * _US,
        }
        if args:
            ev["args"] = args
        self.events.append(ev)
        self._open_async[(cat, rid)].append(name)

    def request_instant(self, rid, name, t, cat="request", args=None) -> None:
        open_names = self._open_async.get((cat, rid))
        span_name = open_names[-1] if open_names else f"req{rid}"
        pid, tid = self._ids(cat)
        ev = {
            "ph": "n",
            "name": span_name,
            "cat": cat,
            "id": rid,
            "pid": pid,
            "tid": tid,
            "ts": t * _US,
            "args": {"event": name, **(args or {})},
        }
        self.events.append(ev)

    def end_request(self, rid, t, cat="request", args=None) -> None:
        open_names = self._open_async.get((cat, rid))
        name = open_names.pop() if open_names else f"req{rid}"
        pid, tid = self._ids(cat)
        ev = {
            "ph": "e",
            "name": name,
            "cat": cat,
            "id": rid,
            "pid": pid,
            "tid": tid,
            "ts": t * _US,
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    # -- export -------------------------------------------------------------

    def export(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path, metadata=None) -> None:
        trace = self.export()
        if metadata:
            trace["otherData"] = metadata
        with open(path, "w") as f:
            json.dump(trace, f, default=_jsonable)


# ---------------------------------------------------------------------------
# Trace analysis helpers
# ---------------------------------------------------------------------------


def _events(trace: dict | list) -> list[dict]:
    if isinstance(trace, dict):
        return trace.get("traceEvents", [])
    return list(trace)


def trace_spans(trace, cat: str | None = None, name: str | None = None) -> list[dict]:
    """All complete (``ph: X``) spans, optionally filtered by cat / name."""
    out = []
    for ev in _events(trace):
        if ev.get("ph") != "X":
            continue
        if cat is not None and ev.get("cat") != cat:
            continue
        if name is not None and ev.get("name") != name:
            continue
        out.append(ev)
    return out


def trace_instants(trace, cat: str | None = None, name: str | None = None) -> list[dict]:
    """All instant (``ph: i``) events, optionally filtered by cat / name."""
    out = []
    for ev in _events(trace):
        if ev.get("ph") != "i":
            continue
        if cat is not None and ev.get("cat") != cat:
            continue
        if name is not None and ev.get("name") != name:
            continue
        out.append(ev)
    return out


def validate_trace(trace) -> list[str]:
    """Schema invariants; returns a list of human-readable problems.

    Checks: required fields per phase, non-negative ts/dur, every async
    ``b`` matched by exactly one ``e`` (per ``(cat, id)``), and proper
    nesting of complete spans within each (pid, tid) lane — a span must
    either contain or be disjoint from every other span on its lane.
    """
    problems: list[str] = []
    events = _events(trace)
    async_open: dict[tuple, int] = defaultdict(int)
    lanes: dict[tuple, list[tuple[float, float, str]]] = defaultdict(list)

    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None:
            problems.append(f"event {i}: missing ph")
            continue
        if ph == "M":
            continue
        if "ts" not in ev:
            problems.append(f"event {i} ({ph} {ev.get('name')}): missing ts")
            continue
        if ev["ts"] < 0:
            problems.append(f"event {i} ({ph} {ev.get('name')}): negative ts")
        if "name" not in ev:
            problems.append(f"event {i}: missing name")
        if ph == "X":
            dur = ev.get("dur")
            if dur is None:
                problems.append(f"event {i} (X {ev.get('name')}): missing dur")
            elif dur < 0:
                problems.append(f"event {i} (X {ev.get('name')}): negative dur")
            else:
                lanes[(ev.get("pid"), ev.get("tid"))].append(
                    (ev["ts"], dur, str(ev.get("name")))
                )
        elif ph in ("b", "n", "e"):
            key = (ev.get("cat"), ev.get("id"))
            if ev.get("id") is None:
                problems.append(f"event {i} ({ph} {ev.get('name')}): missing id")
                continue
            if ph == "b":
                async_open[key] += 1
            elif ph == "e":
                async_open[key] -= 1
                if async_open[key] < 0:
                    problems.append(f"async end without begin: {key}")
            elif ph == "n" and async_open[key] <= 0:
                problems.append(f"async instant outside open span: {key}")

    for key, n in async_open.items():
        if n > 0:
            problems.append(f"async span never closed: {key} ({n} open)")

    eps = 0.5  # µs slack: virtual times are floats rounded through 1e6
    for lane, spans in lanes.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list[tuple[float, float, str]] = []
        for ts, dur, name in spans:
            while stack and ts >= stack[-1][0] + stack[-1][1] - eps:
                stack.pop()
            if stack:
                p_ts, p_dur, p_name = stack[-1]
                if ts + dur > p_ts + p_dur + eps:
                    problems.append(
                        f"lane {lane}: span {name!r} [{ts:.1f},{ts + dur:.1f}] "
                        f"overlaps parent {p_name!r} [{p_ts:.1f},{p_ts + p_dur:.1f}]"
                    )
            stack.append((ts, dur, name))
    return problems


def request_records(trace, cat: str = "request") -> dict:
    """Per-request async lifecycle: ``{id: {begin, end, instants: [...]}}``."""
    recs: dict[Any, dict] = {}
    for ev in _events(trace):
        if ev.get("cat") != cat or ev.get("ph") not in ("b", "n", "e"):
            continue
        rec = recs.setdefault(ev["id"], {"begin": None, "end": None, "instants": []})
        if ev["ph"] == "b":
            rec["begin"] = ev
        elif ev["ph"] == "e":
            rec["end"] = ev
        else:
            rec["instants"].append(ev)
    return recs


def replay_projection(trace) -> dict:
    """Project a trace onto per-request delivered token streams.

    This is the replay-identity invariant for the determinism gate: virtual
    timestamps legitimately differ across same-seed runs (compute time is
    *measured*, so race winners / migrations / preemption points can move),
    but the delivered tokens, their count, and each request's terminal
    outcome must be identical.  Returns ``{rid: {tokens, outcome, delivered}}``.
    """
    out = {}
    for rid, rec in sorted(request_records(trace).items()):
        end = rec["end"]
        args = (end or {}).get("args", {})
        out[rid] = {
            "tokens": list(args.get("tokens", [])),
            "outcome": args.get("outcome"),
            "delivered": args.get("delivered"),
        }
    return out


# (trace instant/span name, args key) -> stats key; used by reconcile_trace.
_RECONCILE_INSTANTS = [
    ("preempt", None, "preemptions"),
    ("deadline_reorder", None, "deadline_reorders"),
    ("slo_miss", None, "server_slo_misses"),
    ("cancel_lag", "n", "cancel_lag_tokens"),
    ("prefix_hit", None, "prefix_hits"),
    ("prefix_hit", "blocks", "blocks_saved"),
    ("prefix_evict", "n", "prefix_evictions"),
    ("cow_copy", "n", "copy_ops"),
    # cross-pool hand-off (disaggregated prefill/decode, cluster.py): kv
    # instants on the receiving pool vs the summed worker pool_stats
    ("handoff", None, "handoffs"),
    ("handoff", "blocks", "handoff_blocks"),
    ("handoff_fallback", None, "handoff_fallbacks"),
]


def reconcile_trace(trace, stats: dict) -> list[str]:
    """Cross-check span/instant-derived sums against registry counters.

    For every stats key with a trace-derivable definition, recompute it from
    the trace and compare; returns a list of mismatch descriptions (empty
    means the trace reconciles exactly).  Only keys present in ``stats`` are
    checked, so dense (non-paged) or non-speculative stacks skip the
    inapplicable ones.
    """
    problems: list[str] = []
    instants = trace_instants(trace)

    def check(key, derived):
        if key in stats and stats[key] != derived:
            problems.append(f"{key}: stats={stats[key]} trace={derived}")

    for ev_name, args_key, stats_key in _RECONCILE_INSTANTS:
        matching = [ev for ev in instants if ev.get("name") == ev_name]
        if args_key is None:
            derived = len(matching)
        else:
            derived = sum(ev.get("args", {}).get(args_key, 0) for ev in matching)
        check(stats_key, derived)

    verify = trace_spans(trace, name="verify")
    if verify:
        check("verify_rounds", len(verify))
        check(
            "accepted_draft_tokens",
            sum(ev["args"].get("accepted", 0) for ev in verify if "args" in ev),
        )
        check(
            "drafts_scored",
            sum(ev["args"].get("k", 0) for ev in verify if "args" in ev),
        )

    prefill = trace_spans(trace, name="prefill", cat="server")
    if prefill:
        check(
            "prefill_tokens_admitted",
            sum(ev["args"].get("tokens_admitted", 0) for ev in prefill if "args" in ev),
        )
        check(
            "prefill_tokens_computed",
            sum(ev["args"].get("tokens_computed", 0) for ev in prefill if "args" in ev),
        )
    return problems


def ttft_attribution(trace) -> list[dict]:
    """Per-request TTFT breakdown: queue / prefill / network / draft-stall,
    plus post-first-token ``decode_stall_s`` interference.

    Joins driver-level request records (cat ``request``) with server-side
    spans via the ``srv_rid`` recorded on the dispatch instant, and with
    network/device tracks via the driver rid.  Returns one dict per request
    with the component seconds; components that do not apply are 0.0.

    A chunked prefill emits one server span per PIECE: all of a request's
    prefill spans sum into ``prefill_s`` and the queue wait rides on the
    first piece only, so the breakdown is exact in both modes.
    ``decode_stall_s`` is the overlap of OTHER requests' server prefill
    spans with this request's post-first-token lifetime — the decode
    interference that chunked prefill bounds (watch it collapse in
    ``tools/trace_report.py`` when ``prefill_chunk`` is on).  In a
    disaggregated/cluster stack (``cluster.py``) spans carry a ``replica``
    scope tag: it is reported per row, interference counts only spans on
    the worker the stream decodes on, and ``handoff_s`` is the modeled
    cross-pool KV transfer time (post-first-token, so not part of TTFT).
    """
    recs = request_records(trace, cat="request")
    spans = trace_spans(trace)

    # Index server prefill spans by server rid (ALL spans: a chunked
    # prefill emits one per piece), network spans by driver rid.
    prefill_by_srv: dict[Any, list[dict]] = defaultdict(list)
    handoff_by_srv: dict[Any, list[dict]] = defaultdict(list)
    for ev in spans:
        if ev.get("cat") == "server" and ev.get("name") == "prefill":
            rid = ev.get("args", {}).get("rid")
            if rid is not None:
                prefill_by_srv[rid].append(ev)
        elif ev.get("cat") == "server" and ev.get("name") == "handoff":
            rid = ev.get("args", {}).get("rid")
            if rid is not None:
                handoff_by_srv[rid].append(ev)
    net_by_rid: dict[Any, list[dict]] = defaultdict(list)
    dev_prefill_by_rid: dict[Any, dict] = {}
    stall_by_rid: dict[Any, list[dict]] = defaultdict(list)
    for ev in spans:
        cat, name = ev.get("cat"), ev.get("name")
        args = ev.get("args", {})
        rid = args.get("rid")
        if rid is None:
            continue
        if cat == "network":
            net_by_rid[rid].append(ev)
        elif cat == "device" and name in ("prefill", "draft_prefill"):
            if rid not in dev_prefill_by_rid:
                dev_prefill_by_rid[rid] = ev
        elif cat == "device" and name == "await_verdict":
            stall_by_rid[rid].append(ev)

    def _before(ev, horizon) -> float:
        """Portion of a span (seconds) that lies before the TTFT horizon —
        a span can straddle the first token (e.g. an uplink still in flight
        when the device wins the race); only the pre-TTFT part attributes."""
        ts, dur = ev["ts"], ev.get("dur", 0.0)
        if ts >= horizon:
            return 0.0
        return (min(ts + dur, horizon) - ts) / _US

    rows = []
    for rid, rec in sorted(recs.items()):
        begin, end = rec["begin"], rec["end"]
        if begin is None:
            continue
        t0 = begin["ts"]
        info = {
            "rid": rid,
            "arrival_s": t0 / _US,
            "queue_s": 0.0,
            "prefill_s": 0.0,
            "network_s": 0.0,
            "draft_stall_s": 0.0,
            "decode_stall_s": 0.0,
            "handoff_s": 0.0,
            "replica": None,
            "ttft_s": None,
            "outcome": (end or {}).get("args", {}).get("outcome"),
            "winner": (end or {}).get("args", {}).get("winner"),
        }
        srv_rid = None
        first_token_ts = None
        for n in rec["instants"]:
            args = n.get("args", {})
            if args.get("event") == "dispatch":
                srv_rid = args.get("srv_rid")
            elif args.get("event") == "first_token" and first_token_ts is None:
                first_token_ts = n["ts"]
                if args.get("ttft_s") is not None:
                    info["ttft_s"] = args["ttft_s"]
        if info["ttft_s"] is None and first_token_ts is not None:
            info["ttft_s"] = (first_token_ts - t0) / _US

        horizon = first_token_ts if first_token_ts is not None else float("inf")
        own = sorted(prefill_by_srv.get(srv_rid, []), key=lambda e: e["ts"])
        for sp in own:
            info["prefill_s"] += _before(sp, horizon)
        for sp in own:
            qw = sp.get("args", {}).get("queue_wait_s")
            if qw is not None:
                info["queue_s"] = qw
                break
        # worker/replica scope: _ScopedTracer stamps spans with a "replica"
        # tag ("r1.prefill"); a monolithic stack has none.  In a
        # disaggregated stack the stream DECODES on the sibling decode
        # worker, so interference only counts from spans on that worker.
        own_scope = None
        for sp in own:
            own_scope = sp.get("args", {}).get("replica")
            if own_scope is not None:
                break
        info["replica"] = own_scope
        decode_scope = (
            own_scope.replace("prefill", "decode")
            if own_scope is not None else None
        )
        # hand-off wire time is post-first-token by construction (the first
        # token departs WITH the KV), so it is reported unclipped rather
        # than folded into the TTFT horizon
        for ev in handoff_by_srv.get(srv_rid, []):
            info["handoff_s"] += ev.get("dur", 0.0) / _US
        if first_token_ts is not None and srv_rid is not None:
            # decode interference: other requests' prefill work overlapping
            # this request's streaming phase (first token -> request end)
            t_end = end["ts"] if end is not None else float("inf")
            for other, evs in prefill_by_srv.items():
                if other == srv_rid:
                    continue
                for ev in evs:
                    if ev.get("args", {}).get("replica") != decode_scope:
                        continue
                    lo = max(ev["ts"], first_token_ts)
                    hi = min(ev["ts"] + ev.get("dur", 0.0), t_end)
                    if hi > lo:
                        info["decode_stall_s"] += (hi - lo) / _US
        dp = dev_prefill_by_rid.get(rid)
        if dp is not None:
            info["prefill_s"] = max(info["prefill_s"], _before(dp, horizon))
        for ev in net_by_rid.get(rid, []):
            info["network_s"] += _before(ev, horizon)
        for ev in stall_by_rid.get(rid, []):
            info["draft_stall_s"] += _before(ev, horizon)
        rows.append(info)
    return rows


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "metric_attr",
    "NullTracer",
    "NULL_TRACER",
    "Tracer",
    "trace_spans",
    "trace_instants",
    "validate_trace",
    "request_records",
    "replay_projection",
    "reconcile_trace",
    "ttft_attribution",
]
