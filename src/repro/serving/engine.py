"""Inference engine: jitted prefill / decode step functions + a
continuous-batching scheduler for batched request serving.

The engine is endpoint-agnostic: DiSCo's device and server endpoints each
wrap one ``InferenceEngine`` (different model sizes / latency envelopes).

Decode hot path: tokens are generated in fused chunks (``decode_n`` — one
``lax.scan`` dispatch per chunk) and the host syncs once per chunk instead of
once per token. Prompts are right-padded to power-of-two length buckets so a
new prompt length does not trigger a fresh XLA compile; the model masks the
pad tail via per-row ``lengths``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_n, decode_step, init_cache, prefill
from repro.models.config import ModelConfig

__all__ = ["InferenceEngine", "GenerationResult", "BatchedServer"]

_MIN_BUCKET = 16


def _bucket_len(s: int, cap: int) -> int:
    """Smallest power-of-two >= s (floor _MIN_BUCKET), capped at ``cap``."""
    b = _MIN_BUCKET
    while b < s:
        b *= 2
    return max(min(b, cap), s)


def _bucketed_prefill_ok(cfg: ModelConfig) -> bool:
    """Bucketed prefill padding is only sound when pad tokens cannot leak
    into real positions: causal attention-only token models. Recurrent state
    (SSM/hybrid) would absorb the pads; bidirectional attention would let
    real positions see them."""
    return cfg.embed_inputs and not cfg.has_ssm and cfg.causal


def _pad_to_bucket(tokens: np.ndarray, cap: int, bucketed: bool):
    """Right-pad (B, S) int tokens to the bucketed length so each distinct
    prompt length does not trigger a fresh XLA compile. Returns
    (padded_tokens, true_lengths)."""
    b, s = tokens.shape
    lengths = np.full((b,), s, np.int32)
    if not bucketed:
        return tokens, lengths
    sb = _bucket_len(s, cap)
    if sb > s:
        tokens = np.pad(tokens, ((0, 0), (0, sb - s)))
    return tokens, lengths


def _tail_steps(n: int, chunk: int) -> int:
    """Round a tail chunk up to the next power of two (capped at ``chunk``):
    bounds the distinct compiled scan lengths to log2(chunk)+1 — so warmup
    can precompile them all and no compile lands inside a timed region —
    while wasting at most the final chunk's rounding on discarded steps."""
    return min(1 << max(n - 1, 0).bit_length(), chunk)


def _tail_sizes(chunk: int) -> list[int]:
    """The set of scan lengths _tail_steps can produce for this chunk."""
    return sorted({_tail_steps(n, chunk) for n in range(1, chunk + 1)})


@dataclasses.dataclass
class GenerationResult:
    tokens: list[int]
    ttft: float                  # seconds (compute only; network added by endpoint)
    token_times: list[float]     # wall-clock time of each token, relative to start
    prefill_s: float
    decode_s_per_token: float


def _engine_compute_cfg(cfg: ModelConfig) -> ModelConfig:
    """Backend-aware compute dtype: bfloat16 matmuls are software-emulated on
    the CPU backend (every weight re-converted per step), so serving engines
    compute in float32 there. TPU/GPU keep the configured dtype."""
    if jax.default_backend() == "cpu" and jnp.dtype(cfg.dtype) == jnp.bfloat16:
        return dataclasses.replace(cfg, dtype="float32")
    return cfg


def _cast_params(params, dtype):
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.bfloat16 and dt != jnp.bfloat16 else a,
        params,
    )


class InferenceEngine:
    """Single-model engine with jitted prefill/decode and greedy sampling.

    ``decode_chunk`` tokens are decoded per device dispatch / host sync.
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 decode_chunk: int = 8):
        cfg = _engine_compute_cfg(cfg)
        self.cfg = cfg
        self.params = _cast_params(params, cfg.dtype)
        self.max_len = max_len
        self.decode_chunk = max(decode_chunk, 1)
        self._bucketed = _bucketed_prefill_ok(cfg)

        @jax.jit
        def _prefill(params, tokens, lengths):
            logits, cache = prefill(params, cfg, tokens, max_len, lengths=lengths)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        # the cache flows linearly through decode (old cache never reused), so
        # its buffers are donated: XLA updates the KV cache in place instead
        # of copying it every step.
        @functools.partial(jax.jit, donate_argnums=(1,))
        def _decode(params, cache, token):
            logits, cache = decode_step(params, cfg, cache, token)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        @functools.partial(
            jax.jit, donate_argnums=(1,), static_argnames=("num_steps",)
        )
        def _decode_n(params, cache, token, num_steps):
            # unguarded: pure scan over decode_step, zero extra cache copies.
            # The host never consumes tokens past max_len-1 (see generate).
            return decode_n(params, cfg, cache, token, num_steps)

        self._prefill = _prefill
        self._decode = _decode
        self._decode_n = _decode_n

    # -- prefill -----------------------------------------------------------

    def warmup(self, batch: int = 1, prompt_len: int = 8) -> None:
        tok = np.zeros((batch, prompt_len), np.int32)
        t, cache = self.prefill(tok)
        # decode donates the cache: thread it, never reuse a donated buffer
        tok_dev, cache = self._decode(self.params, cache, jnp.asarray(t))
        # precompile every tail scan length generate can dispatch, so no XLA
        # compile ever lands inside the wall-clock-timed decode region
        for n in _tail_sizes(self.decode_chunk):
            toks, cache = self._decode_n(self.params, cache, tok_dev, n)
            tok_dev = toks[-1]
        jax.block_until_ready(tok_dev)

    def _chunk_stream(self, cache, tok_dev, start_len: int, max_new: int):
        """Yield (tokens_np (n_valid, B), n_valid) decode chunks after the
        prefill token: one fused dispatch + one host sync per chunk, stopping
        at max_new or cache saturation (lengths == max_len - 1, exactly the
        seed per-token guard). Shared by generate and replay_then_continue."""
        emitted = 1
        cur_len = start_len
        while emitted < max_new:
            n_valid = min(
                self.decode_chunk,
                max_new - emitted,
                max(0, (self.max_len - 1) - cur_len),
            )
            if n_valid <= 0:
                return
            n_steps = _tail_steps(n_valid, self.decode_chunk)
            toks, cache = self._decode_n(self.params, cache, tok_dev, n_steps)
            toks_np = np.asarray(jax.block_until_ready(toks))  # ONE sync/chunk
            yield toks_np[:n_valid], n_valid
            emitted += n_valid
            cur_len += n_valid
            tok_dev = toks[-1]

    def prefill(self, tokens: np.ndarray):
        """tokens: (B, S) int32. Returns (first_token (B,), cache)."""
        padded, lengths = _pad_to_bucket(
            np.asarray(tokens, np.int32), self.max_len, self._bucketed
        )
        t, cache = self._prefill(
            self.params, jnp.asarray(padded, jnp.int32), jnp.asarray(lengths)
        )
        return np.asarray(jax.block_until_ready(t)), cache

    def decode(self, cache, token: np.ndarray):
        """One decode step. NOTE: ``cache`` is donated (updated in place on
        the device) — callers must use the returned cache, not the argument."""
        t, cache = self._decode(self.params, cache, jnp.asarray(token, jnp.int32))
        return np.asarray(jax.block_until_ready(t)), cache

    # -- generation --------------------------------------------------------

    def generate(self, prompt: np.ndarray, max_new: int, replay: bool = False) -> GenerationResult:
        """Greedy generation for one prompt (1, S). Wall-clock timed.

        Decodes in fused chunks of ``decode_chunk`` tokens: one device
        dispatch and one host sync per chunk. The host only observes chunk
        boundaries, but the device produces tokens sequentially inside the
        chunk, so per-token timestamps are linearly interpolated across the
        chunk interval — downstream TBT/QoE series (DiSCo endpoints) keep
        their token-by-token meaning instead of a bursty 0/spike pattern.
        """
        t0 = time.perf_counter()
        tok, cache = self.prefill(prompt[None, :])
        t_first = time.perf_counter()
        tokens, times = [int(tok[0])], [t_first - t0]
        t_prev = t_first - t0
        for toks_np, n_valid in self._chunk_stream(
            cache, jnp.asarray(tok, jnp.int32), int(prompt.shape[0]), max_new
        ):
            now = time.perf_counter() - t0
            for i in range(n_valid):
                tokens.append(int(toks_np[i, 0]))
                times.append(t_prev + (i + 1) * (now - t_prev) / n_valid)
            t_prev = now
        n_dec = max(len(tokens) - 1, 1)
        return GenerationResult(
            tokens=tokens,
            ttft=t_first - t0,
            token_times=times,
            prefill_s=t_first - t0,
            decode_s_per_token=(times[-1] - times[0]) / n_dec,
        )

    def replay_then_continue(
        self, prompt: np.ndarray, generated: list[int], max_new: int
    ) -> tuple[float, "Iterator[int]"]:
        """Migration target path (§4.3): re-prefill prompt + received token IDs
        (no KV transfer), then continue decoding. Returns (replay_seconds,
        iterator of continuation tokens). The continuation decodes in fused
        chunks and buffers them host-side."""
        t0 = time.perf_counter()
        full = np.concatenate([prompt, np.asarray(generated, np.int32)])
        tok, cache = self.prefill(full[None, :])
        replay_s = time.perf_counter() - t0
        start_len = int(full.shape[0])

        def continuation():
            yield int(tok[0])
            for toks_np, n_valid in self._chunk_stream(
                cache, jnp.asarray(tok, jnp.int32), start_len, max_new
            ):
                for i in range(n_valid):
                    yield int(toks_np[i, 0])

        return replay_s, continuation()


# ---------------------------------------------------------------------------
# Continuous batching (server-side request batching, §2.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Slot:
    request_id: int
    remaining: int
    tokens: list


class BatchedServer:
    """Continuous-batching scheduler: one *batched* KV cache with per-row
    lengths; requests join free rows after prefill and all active rows share
    a single batched decode step.

    This models the server-side request batching the paper identifies as the
    source of TTFT tail latency (§2.3): arrivals beyond ``max_slots`` queue.

    Each tick decodes a fused chunk of ``decode_chunk`` tokens for all active
    rows with one dispatch + one host sync; per-row lengths are tracked
    host-side so the scheduler never reads the device cache. Rows freeze on
    the device (cache and lengths untouched) once inactive or at max_len.
    """

    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_len: int = 256, decode_chunk: int = 4):
        cfg = _engine_compute_cfg(cfg)
        self.cfg = cfg
        self.params = _cast_params(params, cfg.dtype)
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_chunk = max(decode_chunk, 1)
        self._bucketed = _bucketed_prefill_ok(cfg)

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _prefill_row(params, batched_cache, tokens, lengths, row):
            """Prefill (1, S) and write its cache into row ``row``. The
            batched cache is donated: the row write happens in place."""
            logits, cache = prefill(params, cfg, tokens, max_len, lengths=lengths)
            new = {}
            for k, v in batched_cache.items():
                if k == "lengths":
                    new[k] = v.at[row].set(cache[k][0])
                else:
                    new[k] = v.at[:, row].set(cache[k][:, 0])
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[0], new

        @functools.partial(
            jax.jit, donate_argnums=(1,), static_argnames=("num_steps",)
        )
        def _decode_chunk(params, cache, tokens, active, num_steps):
            """Fused multi-token batched decode; inactive/saturated rows keep
            their cache untouched."""
            return decode_n(
                params, cfg, cache, tokens, num_steps,
                max_len=max_len, active=active,
            )

        self._prefill_row = _prefill_row
        self._decode_chunk = _decode_chunk
        self.cache = init_cache(cfg, max_slots, max_len)
        self._warm = False
        self.queue: deque = deque()
        self.slots: dict[int, _Slot] = {}
        self.rows: dict[int, int] = {}
        self.free_rows = list(range(max_slots))
        self.row_len = [0] * max_slots      # host-side mirror of cache lengths
        self.next_id = 0
        self.completed: dict[int, list[int]] = {}
        self.submit_time: dict[int, float] = {}
        self.first_token_time: dict[int, float] = {}

    def warmup(self, prompt_len: int = 8) -> None:
        """Precompile the row prefill (one bucket) and every tail scan length
        step() can dispatch, so live scheduler ticks — and the TTFTs measured
        through them — never include an XLA compile. Optional: skipping it
        only means the first tick at each new shape pays the compile."""
        if self._warm:
            return
        prompt = np.zeros((prompt_len,), np.int32)
        padded, lengths = _pad_to_bucket(
            prompt[None, :], self.max_len, self._bucketed
        )
        tok, self.cache = self._prefill_row(
            self.params, self.cache, jnp.asarray(padded), jnp.asarray(lengths), 0
        )
        tokens = np.zeros((self.max_slots,), np.int32)
        inactive = jnp.zeros((self.max_slots,), bool)  # rows stay frozen
        for n in _tail_sizes(self.decode_chunk):
            toks, self.cache = self._decode_chunk(
                self.params, self.cache, jnp.asarray(tokens), inactive, n
            )
        jax.block_until_ready(toks)
        # reset to a pristine cache: warmup must not leave row 0 populated
        self.cache = init_cache(self.cfg, self.max_slots, self.max_len)
        self._warm = True

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        rid = self.next_id
        self.next_id += 1
        self.queue.append((rid, prompt, max_new))
        self.submit_time[rid] = time.perf_counter()
        return rid

    def _admit(self) -> None:
        while self.queue and self.free_rows:
            rid, prompt, max_new = self.queue.popleft()
            row = self.free_rows.pop()
            s = int(prompt.shape[0])
            padded, lengths = _pad_to_bucket(
                np.asarray(prompt, np.int32)[None, :], self.max_len, self._bucketed
            )
            tok, self.cache = self._prefill_row(
                self.params, self.cache, jnp.asarray(padded),
                jnp.asarray(lengths), row,
            )
            jax.block_until_ready(tok)
            self.first_token_time[rid] = time.perf_counter()
            self.slots[rid] = _Slot(rid, max_new - 1, [int(tok)])
            self.rows[rid] = row
            self.row_len[row] = s

    def step(self) -> bool:
        """One scheduler tick: admit, then one fused decode chunk for all
        active rows (single dispatch + host sync). Returns False when fully
        idle."""
        self._admit()
        if not self.slots:
            return False
        done = [
            rid
            for rid, slot in self.slots.items()
            if slot.remaining <= 0
            or self.row_len[self.rows[rid]] >= self.max_len - 1
        ]
        for rid in done:
            self.completed[rid] = self.slots.pop(rid).tokens
            self.free_rows.append(self.rows.pop(rid))
        if not self.slots:
            return bool(self.queue)
        tokens = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        need = {}
        for rid, slot in self.slots.items():
            row = self.rows[rid]
            tokens[row] = slot.tokens[-1]
            active[row] = True
            need[rid] = min(
                self.decode_chunk,
                slot.remaining,
                max(0, (self.max_len - 1) - self.row_len[row]),
            )
        # cap the scan at the largest per-row need (rounded to a warm tail
        # size) so request tails don't pay for discarded decode steps
        num_steps = _tail_steps(max(need.values()), self.decode_chunk)
        toks, self.cache = self._decode_chunk(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(active),
            num_steps,
        )
        toks = np.asarray(jax.block_until_ready(toks))   # (num_steps, max_slots)
        for rid, slot in self.slots.items():
            row = self.rows[rid]
            n_valid = need[rid]
            for i in range(n_valid):
                slot.tokens.append(int(toks[i, row]))
            slot.remaining -= n_valid
            self.row_len[row] += n_valid
        return True

    def run_to_completion(self) -> dict[int, list[int]]:
        while self.step() or self.queue:
            pass
        return self.completed

    def ttft(self, rid: int) -> float:
        return self.first_token_time[rid] - self.submit_time[rid]
