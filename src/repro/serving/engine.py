"""Inference engine: jitted prefill / decode step functions + an
event-driven continuous-batching scheduler for multi-request serving.

The engine is endpoint-agnostic: DiSCo's device endpoint wraps one
``InferenceEngine`` per user device; the server endpoint wraps the shared
``BatchedServer`` so queueing delay *emerges* from slot contention.

Decode hot path: tokens are generated in fused chunks (``decode_n`` — one
``lax.scan`` dispatch per chunk) and the host syncs once per chunk instead of
once per token. Prompts are right-padded to power-of-two length buckets so a
new prompt length does not trigger a fresh XLA compile; the model masks the
pad tail via per-row ``lengths``.

Two incremental interfaces feed the DiSCo event loop:

* ``EngineStream`` (via ``InferenceEngine.open_stream`` / ``open_replay``) —
  a lazily *pulled* token source: compute is dispatched one fused chunk per
  pull, per-token times are interpolated across the measured chunk interval,
  and ``cancel()`` stops all future dispatches, so an abandoned stream wastes
  at most one in-flight decode chunk.
* ``BatchedServer`` — a virtual-time scheduler: each tick (one row-prefill
  admission or one fused decode chunk across active rows) advances a virtual
  clock by the tick's measured wall-clock compute, requests queue until a row
  frees, tokens are delivered incrementally per request id, and
  ``cancel(rid)`` frees the row immediately for the next admission.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from collections import deque
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_n, decode_step, init_cache, prefill
from repro.models.config import ModelConfig

__all__ = ["InferenceEngine", "GenerationResult", "EngineStream", "BatchedServer"]

_MIN_BUCKET = 16


def _bucket_len(s: int, cap: int) -> int:
    """Smallest power-of-two >= s (floor _MIN_BUCKET), capped at ``cap``."""
    b = _MIN_BUCKET
    while b < s:
        b *= 2
    return max(min(b, cap), s)


def _bucketed_prefill_ok(cfg: ModelConfig) -> bool:
    """Bucketed prefill padding is only sound when pad tokens cannot leak
    into real positions: causal attention-only token models. Recurrent state
    (SSM/hybrid) would absorb the pads; bidirectional attention would let
    real positions see them."""
    return cfg.embed_inputs and not cfg.has_ssm and cfg.causal


def _pad_to_bucket(tokens: np.ndarray, cap: int, bucketed: bool):
    """Right-pad (B, S) int tokens to the bucketed length so each distinct
    prompt length does not trigger a fresh XLA compile. Returns
    (padded_tokens, true_lengths)."""
    b, s = tokens.shape
    lengths = np.full((b,), s, np.int32)
    if not bucketed:
        return tokens, lengths
    sb = _bucket_len(s, cap)
    if sb > s:
        tokens = np.pad(tokens, ((0, 0), (0, sb - s)))
    return tokens, lengths


def _tail_steps(n: int, chunk: int) -> int:
    """Round a tail chunk up to the next power of two (capped at ``chunk``):
    bounds the distinct compiled scan lengths to log2(chunk)+1 — so warmup
    can precompile them all and no compile lands inside a timed region —
    while wasting at most the final chunk's rounding on discarded steps."""
    return min(1 << max(n - 1, 0).bit_length(), chunk)


def _tail_sizes(chunk: int) -> list[int]:
    """The set of scan lengths _tail_steps can produce for this chunk."""
    return sorted({_tail_steps(n, chunk) for n in range(1, chunk + 1)})


@dataclasses.dataclass
class GenerationResult:
    tokens: list[int]
    ttft: float                  # seconds (compute only; network added by endpoint)
    token_times: list[float]     # wall-clock time of each token, relative to start
    prefill_s: float
    decode_s_per_token: float


def _engine_compute_cfg(cfg: ModelConfig) -> ModelConfig:
    """Backend-aware compute dtype: bfloat16 matmuls are software-emulated on
    the CPU backend (every weight re-converted per step), so serving engines
    compute in float32 there. TPU/GPU keep the configured dtype."""
    if jax.default_backend() == "cpu" and jnp.dtype(cfg.dtype) == jnp.bfloat16:
        return dataclasses.replace(cfg, dtype="float32")
    return cfg


def _cast_params(params, dtype):
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.bfloat16 and dt != jnp.bfloat16 else a,
        params,
    )


class InferenceEngine:
    """Single-model engine with jitted prefill/decode and greedy sampling.

    ``decode_chunk`` tokens are decoded per device dispatch / host sync.
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 decode_chunk: int = 8):
        cfg = _engine_compute_cfg(cfg)
        self.cfg = cfg
        self.params = _cast_params(params, cfg.dtype)
        self.max_len = max_len
        self.decode_chunk = max(decode_chunk, 1)
        self._bucketed = _bucketed_prefill_ok(cfg)

        @jax.jit
        def _prefill(params, tokens, lengths):
            logits, cache = prefill(params, cfg, tokens, max_len, lengths=lengths)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        # the cache flows linearly through decode (old cache never reused), so
        # its buffers are donated: XLA updates the KV cache in place instead
        # of copying it every step.
        @functools.partial(jax.jit, donate_argnums=(1,))
        def _decode(params, cache, token):
            logits, cache = decode_step(params, cfg, cache, token)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        @functools.partial(
            jax.jit, donate_argnums=(1,), static_argnames=("num_steps",)
        )
        def _decode_n(params, cache, token, num_steps):
            # unguarded: pure scan over decode_step, zero extra cache copies.
            # The host never consumes tokens past max_len-1 (see generate).
            return decode_n(params, cfg, cache, token, num_steps)

        self._prefill = _prefill
        self._decode = _decode
        self._decode_n = _decode_n

    # -- prefill -----------------------------------------------------------

    def warmup(self, batch: int = 1, prompt_len: int = 8,
               prompt_lens: tuple = ()) -> None:
        """Precompile prefill bucket(s) and decode scan lengths. Pass every
        prompt length the workload will see via ``prompt_lens`` so no XLA
        compile lands inside a wall-clock-timed (virtual-timeline) region."""
        buckets = sorted({
            _bucket_len(s, self.max_len) if self._bucketed else s
            for s in (prompt_len, *prompt_lens)
        })
        for s in buckets[1:]:
            t, _ = self.prefill(np.zeros((batch, s), np.int32))
        tok = np.zeros((batch, buckets[0]), np.int32)
        t, cache = self.prefill(tok)
        # decode donates the cache: thread it, never reuse a donated buffer
        tok_dev, cache = self._decode(self.params, cache, jnp.asarray(t))
        # precompile every tail scan length generate can dispatch, so no XLA
        # compile ever lands inside the wall-clock-timed decode region
        for n in _tail_sizes(self.decode_chunk):
            toks, cache = self._decode_n(self.params, cache, tok_dev, n)
            tok_dev = toks[-1]
        jax.block_until_ready(tok_dev)

    def _chunk_stream(self, cache, tok_dev, start_len: int, max_new: int):
        """Yield (tokens_np (n_valid, B), n_valid) decode chunks after the
        prefill token: one fused dispatch + one host sync per chunk, stopping
        at max_new or cache saturation (lengths == max_len - 1, exactly the
        seed per-token guard). Shared by generate and replay_then_continue."""
        emitted = 1
        cur_len = start_len
        while emitted < max_new:
            n_valid = min(
                self.decode_chunk,
                max_new - emitted,
                max(0, (self.max_len - 1) - cur_len),
            )
            if n_valid <= 0:
                return
            n_steps = _tail_steps(n_valid, self.decode_chunk)
            toks, cache = self._decode_n(self.params, cache, tok_dev, n_steps)
            toks_np = np.asarray(jax.block_until_ready(toks))  # ONE sync/chunk
            yield toks_np[:n_valid], n_valid
            emitted += n_valid
            cur_len += n_valid
            tok_dev = toks[-1]

    def prefill(self, tokens: np.ndarray):
        """tokens: (B, S) int32. Returns (first_token (B,), cache)."""
        padded, lengths = _pad_to_bucket(
            np.asarray(tokens, np.int32), self.max_len, self._bucketed
        )
        t, cache = self._prefill(
            self.params, jnp.asarray(padded, jnp.int32), jnp.asarray(lengths)
        )
        return np.asarray(jax.block_until_ready(t)), cache

    def decode(self, cache, token: np.ndarray):
        """One decode step. NOTE: ``cache`` is donated (updated in place on
        the device) — callers must use the returned cache, not the argument."""
        t, cache = self._decode(self.params, cache, jnp.asarray(token, jnp.int32))
        return np.asarray(jax.block_until_ready(t)), cache

    # -- generation --------------------------------------------------------

    def generate(self, prompt: np.ndarray, max_new: int, replay: bool = False) -> GenerationResult:
        """Greedy generation for one prompt (1, S). Wall-clock timed.

        Decodes in fused chunks of ``decode_chunk`` tokens: one device
        dispatch and one host sync per chunk. The host only observes chunk
        boundaries, but the device produces tokens sequentially inside the
        chunk, so per-token timestamps are linearly interpolated across the
        chunk interval — downstream TBT/QoE series (DiSCo endpoints) keep
        their token-by-token meaning instead of a bursty 0/spike pattern.
        """
        t0 = time.perf_counter()
        tok, cache = self.prefill(prompt[None, :])
        t_first = time.perf_counter()
        tokens, times = [int(tok[0])], [t_first - t0]
        t_prev = t_first - t0
        for toks_np, n_valid in self._chunk_stream(
            cache, jnp.asarray(tok, jnp.int32), int(prompt.shape[0]), max_new
        ):
            now = time.perf_counter() - t0
            for i in range(n_valid):
                tokens.append(int(toks_np[i, 0]))
                times.append(t_prev + (i + 1) * (now - t_prev) / n_valid)
            t_prev = now
        n_dec = max(len(tokens) - 1, 1)
        return GenerationResult(
            tokens=tokens,
            ttft=t_first - t0,
            token_times=times,
            prefill_s=t_first - t0,
            decode_s_per_token=(times[-1] - times[0]) / n_dec,
        )

    def replay_then_continue(
        self, prompt: np.ndarray, generated: list[int], max_new: int
    ) -> tuple[float, "Iterator[int]"]:
        """Migration target path (§4.3): re-prefill prompt + received token IDs
        (no KV transfer), then continue decoding. Returns (replay_seconds,
        iterator of continuation tokens). The continuation decodes in fused
        chunks and buffers them host-side."""
        t0 = time.perf_counter()
        full = np.concatenate([prompt, np.asarray(generated, np.int32)])
        tok, cache = self.prefill(full[None, :])
        replay_s = time.perf_counter() - t0
        start_len = int(full.shape[0])

        def continuation():
            yield int(tok[0])
            for toks_np, n_valid in self._chunk_stream(
                cache, jnp.asarray(tok, jnp.int32), start_len, max_new
            ):
                for i in range(n_valid):
                    yield int(toks_np[i, 0])

        return replay_s, continuation()

    # -- incremental (event-loop) interface --------------------------------

    def open_stream(self, prompt: np.ndarray, max_new: int) -> "EngineStream":
        """Lazy token source for ``prompt`` (S,): nothing is dispatched until
        the first pull. See :class:`EngineStream`."""
        return EngineStream(self, np.asarray(prompt, np.int32), max_new)

    def open_replay(self, prompt: np.ndarray, generated, max_new: int) -> "EngineStream":
        """Migration-target source (§4.3): first pull re-prefills
        prompt + received token IDs (no KV transfer); the stream then emits
        up to ``max_new`` *continuation* tokens (the replay-prefill's next
        token is the first of them)."""
        full = np.concatenate(
            [np.asarray(prompt, np.int32), np.asarray(generated, np.int32)]
        )
        return EngineStream(self, full, max_new)


class EngineStream:
    """Lazily pulled incremental generation from one :class:`InferenceEngine`.

    Compute happens on pull: the first ``next_chunk()`` dispatches the
    prefill and returns its token; each later call dispatches one fused
    decode chunk. Pull wall-clock is measured and per-token times are
    interpolated across the chunk interval (the device emits sequentially
    inside a chunk), so downstream TBT series keep token-by-token meaning —
    this applies to replayed (migration) streams too, which previously
    stamped a whole host-buffered chunk with one burst timestamp.

    ``cancel()`` stops all future dispatches and drops the cache reference:
    a cancelled race loser wastes at most the one chunk that was in flight.
    """

    def __init__(self, engine: InferenceEngine, prompt: np.ndarray, max_new: int):
        self.engine = engine
        self._prompt = prompt
        self._max_new = max_new
        self._chunks = None           # generator once prefill has run
        self.cancelled = False
        self.exhausted = False
        self.prefill_s: Optional[float] = None
        self.decode_dispatches = 0    # fused decode-chunk dispatches
        self.tokens_emitted = 0       # includes the prefill token
        self._elapsed = 0.0           # compute-seconds consumed so far

    @property
    def prefilled(self) -> bool:
        return self.prefill_s is not None

    @property
    def done(self) -> bool:
        return self.cancelled or self.exhausted

    def next_chunk(self):
        """Pull the next chunk: ``(tokens, rel_times)`` or ``None`` when the
        stream is exhausted or cancelled. Times are seconds of *compute*
        since the stream started (prefill included)."""
        if self.done:
            return None
        if self._chunks is None:
            t0 = time.perf_counter()
            tok, cache = self.engine.prefill(self._prompt[None, :])
            self.prefill_s = time.perf_counter() - t0
            self._elapsed = self.prefill_s
            self._chunks = self.engine._chunk_stream(
                cache, jnp.asarray(tok, jnp.int32),
                int(self._prompt.shape[0]), self._max_new,
            )
            self.tokens_emitted = 1
            return [int(tok[0])], [self.prefill_s]
        t0 = time.perf_counter()
        nxt = next(self._chunks, None)
        dur = time.perf_counter() - t0
        if nxt is None:
            self.exhausted = True
            self._chunks = None
            return None
        toks_np, n_valid = nxt
        self.decode_dispatches += 1
        start = self._elapsed
        self._elapsed += dur
        self.tokens_emitted += n_valid
        tokens = [int(toks_np[i, 0]) for i in range(n_valid)]
        times = [start + (i + 1) * dur / n_valid for i in range(n_valid)]
        return tokens, times

    def cancel(self) -> None:
        self.cancelled = True
        self._chunks = None           # free the KV cache reference


# ---------------------------------------------------------------------------
# Continuous batching (server-side request batching, §2.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Slot:
    request_id: int
    remaining: int
    tokens: list


class BatchedServer:
    """Event-driven continuous-batching scheduler on a *virtual* timeline.

    One batched KV cache with per-row lengths; requests join free rows after
    a row prefill and all active rows share fused batched decode chunks.
    This models the server-side request batching the paper identifies as the
    source of TTFT tail latency (§2.3): arrivals beyond ``max_slots`` queue,
    so queueing delay is *emergent contention*, not a sampled scalar.

    Timeline semantics: each scheduler tick is either (a) the admission of
    ONE queued request into a free row — a single row-prefill dispatch, no
    global barrier, interleaved between decode chunks — or (b) one fused
    decode chunk of ``decode_chunk`` tokens across all active rows (one
    dispatch + one host sync). The virtual clock advances by each tick's
    measured wall-clock compute; per-token event times are interpolated
    inside the chunk. ``submit(..., at=t)`` stamps a virtual arrival;
    ``run_until(t)`` processes ticks until the clock passes ``t`` (the last
    tick may overshoot — that is the "in-flight chunk" a cancellation cannot
    recall). Tokens are delivered incrementally per request id via
    ``pop_events``; ``cancel(rid)`` frees the row immediately, so a queued
    request can be admitted within the same tick.
    """

    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_len: int = 256, decode_chunk: int = 4):
        cfg = _engine_compute_cfg(cfg)
        self.cfg = cfg
        self.params = _cast_params(params, cfg.dtype)
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_chunk = max(decode_chunk, 1)
        self._bucketed = _bucketed_prefill_ok(cfg)

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _prefill_row(params, batched_cache, tokens, lengths, row):
            """Prefill (1, S) and write its cache into row ``row``. The
            batched cache is donated: the row write happens in place."""
            logits, cache = prefill(params, cfg, tokens, max_len, lengths=lengths)
            new = {}
            for k, v in batched_cache.items():
                if k == "lengths":
                    new[k] = v.at[row].set(cache[k][0])
                else:
                    new[k] = v.at[:, row].set(cache[k][:, 0])
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[0], new

        @functools.partial(
            jax.jit, donate_argnums=(1,), static_argnames=("num_steps",)
        )
        def _decode_chunk(params, cache, tokens, active, num_steps):
            """Fused multi-token batched decode; inactive/saturated rows keep
            their cache untouched."""
            return decode_n(
                params, cfg, cache, tokens, num_steps,
                max_len=max_len, active=active,
            )

        self._prefill_row = _prefill_row
        self._decode_chunk = _decode_chunk
        self.cache = init_cache(cfg, max_slots, max_len)
        self._warm = False
        self.clock = 0.0                    # virtual seconds
        self.queue: deque = deque()         # (rid, prompt, max_new), FIFO
        self.slots: dict[int, _Slot] = {}
        self.rows: dict[int, int] = {}
        self.free_rows = list(range(max_slots))
        self.row_len = [0] * max_slots      # host-side mirror of cache lengths
        self.next_id = 0
        self.completed: dict[int, list[int]] = {}
        self.cancelled: set[int] = set()
        self.submit_time: dict[int, float] = {}     # virtual arrival
        self.first_token_time: dict[int, float] = {}  # virtual, admitted rids only
        self.events: dict[int, deque] = {}  # rid -> deque[(token, virtual_t)]
        self.decode_dispatches: dict[int, int] = {}  # chunks the rid was active in
        self.generated: dict[int, int] = {}          # tokens emitted per rid

    def warmup(self, prompt_len: int = 8, prompt_lens: tuple = ()) -> None:
        """Precompile the row prefill bucket(s) and every tail scan length
        step() can dispatch, so live scheduler ticks — and the virtual-time
        TTFTs measured through them — never include an XLA compile. Pass the
        workload's prompt lengths via ``prompt_lens``; skipping one only
        means the first tick at that shape pays the compile."""
        if self._warm:
            return
        buckets = sorted({
            _bucket_len(s, self.max_len) if self._bucketed else s
            for s in (prompt_len, *prompt_lens)
        })
        tok = None
        for s in buckets:
            prompt = np.zeros((s,), np.int32)
            padded, lengths = _pad_to_bucket(
                prompt[None, :], self.max_len, self._bucketed
            )
            tok, self.cache = self._prefill_row(
                self.params, self.cache, jnp.asarray(padded), jnp.asarray(lengths), 0
            )
        tokens = np.zeros((self.max_slots,), np.int32)
        inactive = jnp.zeros((self.max_slots,), bool)  # rows stay frozen
        for n in _tail_sizes(self.decode_chunk):
            toks, self.cache = self._decode_chunk(
                self.params, self.cache, jnp.asarray(tokens), inactive, n
            )
        jax.block_until_ready(toks)
        # reset to a pristine cache: warmup must not leave row 0 populated
        self.cache = init_cache(self.cfg, self.max_slots, self.max_len)
        self._warm = True

    # -- request lifecycle -------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int, at: Optional[float] = None) -> int:
        """Enqueue a request arriving at virtual time ``at`` (defaults to the
        current clock). FIFO admission; callers submit in arrival order."""
        rid = self.next_id
        self.next_id += 1
        self.queue.append((rid, np.asarray(prompt, np.int32), max_new))
        self.submit_time[rid] = self.clock if at is None else float(at)
        self.events[rid] = deque()
        self.generated[rid] = 0
        return rid

    def cancel(self, rid: int) -> None:
        """Stop a request now. A queued request is dropped before admission;
        an active one frees its row immediately — the row is reusable by the
        very next admission tick (no drain, the cache row just freezes)."""
        if rid in self.completed or rid in self.cancelled:
            return
        self.cancelled.add(rid)
        if rid in self.slots:
            slot = self.slots.pop(rid)
            self.free_rows.append(self.rows.pop(rid))
            self.completed[rid] = slot.tokens
            return
        for item in self.queue:
            if item[0] == rid:
                self.queue.remove(item)
                self.completed[rid] = []
                return

    def is_finished(self, rid: int) -> bool:
        """True once the rid can emit no further events."""
        if rid not in self.submit_time:
            raise ValueError(f"unknown request id {rid}")
        return rid in self.completed and not self.events[rid]

    def pop_events(self, rid: int) -> list:
        """Drain this request's undelivered ``(token, virtual_time)`` events."""
        q = self.events[rid]
        out = list(q)
        q.clear()
        return out

    # -- scheduler ticks ---------------------------------------------------

    def _retire_done(self) -> None:
        done = [
            rid
            for rid, slot in self.slots.items()
            if slot.remaining <= 0
            or self.row_len[self.rows[rid]] >= self.max_len - 1
        ]
        for rid in done:
            self.completed[rid] = self.slots.pop(rid).tokens
            self.free_rows.append(self.rows.pop(rid))

    def _head_arrival(self) -> Optional[float]:
        return self.submit_time[self.queue[0][0]] if self.queue else None

    def _admit_one(self) -> None:
        """Admission tick: prefill ONE queued request into a free row. The
        measured prefill wall-clock advances the virtual clock; the prompt's
        first token lands at the new clock."""
        rid, prompt, max_new = self.queue.popleft()
        row = self.free_rows.pop()
        s = int(prompt.shape[0])
        padded, lengths = _pad_to_bucket(
            prompt[None, :], self.max_len, self._bucketed
        )
        t0 = time.perf_counter()
        tok, self.cache = self._prefill_row(
            self.params, self.cache, jnp.asarray(padded),
            jnp.asarray(lengths), row,
        )
        tok = int(jax.block_until_ready(tok))
        self.clock += time.perf_counter() - t0
        self.first_token_time[rid] = self.clock
        self.events[rid].append((tok, self.clock))
        self.generated[rid] += 1
        self.slots[rid] = _Slot(rid, max_new - 1, [tok])
        self.rows[rid] = row
        self.row_len[row] = s

    def _decode_tick(self) -> None:
        """Decode tick: one fused chunk for all active rows (single dispatch
        + host sync). Per-token virtual times are interpolated across the
        measured chunk interval."""
        tokens = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        need = {}
        for rid, slot in self.slots.items():
            row = self.rows[rid]
            tokens[row] = slot.tokens[-1]
            active[row] = True
            need[rid] = min(
                self.decode_chunk,
                slot.remaining,
                max(0, (self.max_len - 1) - self.row_len[row]),
            )
        # cap the scan at the largest per-row need (rounded to a warm tail
        # size) so request tails don't pay for discarded decode steps
        num_steps = _tail_steps(max(need.values()), self.decode_chunk)
        t_start = self.clock
        t0 = time.perf_counter()
        toks, self.cache = self._decode_chunk(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(active),
            num_steps,
        )
        toks = np.asarray(jax.block_until_ready(toks))   # (num_steps, max_slots)
        dur = time.perf_counter() - t0
        self.clock = t_start + dur
        for rid, slot in self.slots.items():
            row = self.rows[rid]
            n_valid = need[rid]
            for i in range(n_valid):
                tok = int(toks[i, row])
                slot.tokens.append(tok)
                self.events[rid].append(
                    (tok, t_start + (i + 1) * dur / num_steps)
                )
            slot.remaining -= n_valid
            self.row_len[row] += n_valid
            self.generated[rid] += n_valid
            self.decode_dispatches[rid] = self.decode_dispatches.get(rid, 0) + 1

    def run_until(self, t_limit: float = math.inf) -> None:
        """Process ticks until the virtual clock passes ``t_limit`` or there
        is no work. The final tick may overshoot ``t_limit``: its chunk was
        already in flight when the horizon passed (cancellations land after
        it, which is exactly the paper's one-chunk cancellation latency)."""
        while self.clock < t_limit:
            self._retire_done()
            head = self._head_arrival()
            if self.free_rows and head is not None and head <= self.clock:
                self._admit_one()        # one row per tick, between chunks
                continue
            if self.slots:
                self._decode_tick()
                continue
            if head is None or head > t_limit:
                break                    # idle, or next arrival beyond horizon
            self.clock = head            # idle gap: jump to the next arrival
        self._retire_done()

    def step(self) -> bool:
        """One scheduler tick (admission or decode chunk). Returns False when
        fully idle. Compatibility wrapper over the event-driven core; the
        clock only jumps over idle gaps, never past in-flight decode work."""
        self._retire_done()
        head = self._head_arrival()
        if not self.slots and head is not None:
            self.clock = max(self.clock, head)   # idle gap: jump to arrival
        if self.free_rows and head is not None and head <= self.clock:
            self._admit_one()
        elif self.slots:
            self._decode_tick()
        self._retire_done()
        return bool(self.slots or self.queue)

    def run_to_completion(self) -> dict[int, list[int]]:
        self.run_until(math.inf)
        return self.completed

    # -- bookkeeping -------------------------------------------------------

    def ttft(self, rid: int) -> Optional[float]:
        """Virtual-time TTFT. ``None`` for a request that was never admitted
        (still queued, or cancelled while queued); raises ``ValueError`` for
        an unknown rid instead of leaking a bare ``KeyError``."""
        if rid not in self.submit_time:
            raise ValueError(
                f"unknown request id {rid}: never submitted to this server"
            )
        if rid not in self.first_token_time:
            return None
        return self.first_token_time[rid] - self.submit_time[rid]
