"""Inference engine: jitted prefill / decode step functions + an
event-driven continuous-batching scheduler for multi-request serving.

The engine is endpoint-agnostic: DiSCo's device endpoint wraps one
``InferenceEngine`` per user device; the server endpoint wraps the shared
``BatchedServer`` so queueing delay *emerges* from slot contention.

Decode hot path: tokens are generated in fused chunks (``decode_n`` — one
``lax.scan`` dispatch per chunk) and the host syncs once per chunk instead of
once per token. Prompts are right-padded to power-of-two length buckets so a
new prompt length does not trigger a fresh XLA compile; the model masks the
pad tail via per-row ``lengths``.

Two incremental interfaces feed the DiSCo event loop:

* ``EngineStream`` (via ``InferenceEngine.open_stream`` / ``open_replay``) —
  a lazily *pulled* token source: compute is dispatched one fused chunk per
  pull, per-token times are interpolated across the measured chunk interval,
  and ``cancel()`` stops all future dispatches, so an abandoned stream wastes
  at most one in-flight decode chunk.
* ``BatchedServer`` — a virtual-time scheduler: each tick (one row-prefill
  admission or one fused decode chunk across active rows) advances a virtual
  clock by the tick's measured wall-clock compute, tokens are delivered
  incrementally per request id, and ``cancel(rid)`` frees the row — and its
  KV blocks — immediately for the next admission. On paged-capable models
  (causal attention-only) KV memory is a shared block pool managed by
  ``kv_pool.KVPoolManager``: admission is block-capacity-driven, decode
  extends page tables block-by-block, and pool exhaustion preempts the
  newest request (recompute) instead of overcommitting.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from collections import deque
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    SamplerConfig,
    decode_n,
    decode_step,
    draft_n,
    init_cache,
    init_paged_pages,
    paged_decode_n,
    paged_draft_n,
    paged_piece_prefill,
    paged_prefill,
    paged_suffix_prefill,
    paged_verify_n,
    prefill,
    request_key,
    sample_tokens,
    sampler_operands,
    supports_paged,
    verify_n,
)
from repro.kernels.compat import on_tpu
from repro.models.config import ModelConfig

from .kv_pool import NULL_BLOCK, KVPoolManager
from .request import Request
from .telemetry import NULL_TRACER, MetricsRegistry, metric_attr

__all__ = ["InferenceEngine", "GenerationResult", "EngineStream", "BatchedServer"]


def _request_keys(seeds) -> np.ndarray:
    """(B, 2) uint32 per-request sampling keys for a batch of integer seeds
    (host-side; one row per request). Greedy paths pass these through
    untouched-and-unused so the jitted signatures stay uniform."""
    return np.stack([np.asarray(request_key(int(s))) for s in seeds])


def _zero_keys(batch: int) -> jnp.ndarray:
    """(B, 2) uint32 placeholder keys for paths with no request seed
    (warmup, greedy-only callers)."""
    return jnp.zeros((batch, 2), jnp.uint32)


def _greedy_ops(batch: int):
    """(B,) all-greedy sampler operands (warmup, direct greedy callers)."""
    return sampler_operands([], batch=batch)


def _require_request(req, method: str) -> Request:
    if not isinstance(req, Request):
        raise TypeError(
            f"{method} now takes a repro.serving.Request as its single "
            "request argument — the (prompt, max_new, seed=...) form was "
            "removed. Build Request(prompt, max_new, sampler=..., seed=..., "
            "slo=...) instead."
        )
    return req


@functools.partial(jax.jit, donate_argnums=(1,))
def _xfer_pool_blocks(src_pages, dst_pages, src_ids, dst_ids):
    """Cross-pool KV block copy: gather ``src_ids`` from one pool's page
    arrays and scatter them into ``dst_ids`` of another pool's (donated)
    arrays — the device half of a prefill→decode hand-off. Padding pairs
    point both sides at the trash block, so bucketing the pair count to a
    power of two (bounded compile count) writes only garbage into garbage."""
    return {
        k: dst_pages[k].at[:, dst_ids].set(src_pages[k][:, src_ids])
        for k in dst_pages
    }


def _pad_copy_pairs(pairs):
    """(src_ids, dst_ids) int32 arrays padded to a power-of-two length with
    trash-block self-copies (see ``_xfer_pool_blocks``)."""
    n = 1 << max(0, len(pairs) - 1).bit_length()
    pad = n - len(pairs)
    src = [p[0] for p in pairs] + [NULL_BLOCK] * pad
    dst = [p[1] for p in pairs] + [NULL_BLOCK] * pad
    return jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)


_MIN_BUCKET = 16


def _bucket_len(s: int, cap: int) -> int:
    """Smallest power-of-two >= s (floor _MIN_BUCKET), capped at ``cap``."""
    b = _MIN_BUCKET
    while b < s:
        b *= 2
    return max(min(b, cap), s)


def _bucketed_prefill_ok(cfg: ModelConfig) -> bool:
    """Bucketed prefill padding is only sound when pad tokens cannot leak
    into real positions: causal attention-only token models. Recurrent state
    (SSM/hybrid) would absorb the pads; bidirectional attention would let
    real positions see them."""
    return cfg.embed_inputs and not cfg.has_ssm and cfg.causal


def _pad_to_bucket(tokens: np.ndarray, cap: int, bucketed: bool):
    """Right-pad (B, S) int tokens to the bucketed length so each distinct
    prompt length does not trigger a fresh XLA compile. Returns
    (padded_tokens, true_lengths)."""
    b, s = tokens.shape
    lengths = np.full((b,), s, np.int32)
    if not bucketed:
        return tokens, lengths
    sb = _bucket_len(s, cap)
    if sb > s:
        tokens = np.pad(tokens, ((0, 0), (0, sb - s)))
    return tokens, lengths


def _tail_steps(n: int, chunk: int) -> int:
    """Round a tail chunk up to the next power of two (capped at ``chunk``):
    bounds the distinct compiled scan lengths to log2(chunk)+1 — so warmup
    can precompile them all and no compile lands inside a timed region —
    while wasting at most the final chunk's rounding on discarded steps."""
    return min(1 << max(n - 1, 0).bit_length(), chunk)


def _tail_sizes(chunk: int) -> list[int]:
    """The set of scan lengths _tail_steps can produce for this chunk."""
    return sorted({_tail_steps(n, chunk) for n in range(1, chunk + 1)})


def _check_prefill_chunk(chunk: int, block_size: int) -> int:
    """Normalize a chunked-prefill piece budget: floored to a power of two
    (a power of two always divides the power-of-two prefill buckets, so
    every piece of a bucket has the same shape) and at least ``block_size``
    (pieces scatter whole blocks). The compile budget follows: a bucket
    dispatches at most ONE distinct piece shape (plus the monolithic bucket
    shape for prompts at or under the budget) — see ``_piece_steps``."""
    c = int(chunk)
    if c < block_size:
        raise ValueError(
            f"prefill_chunk must be >= block_size={block_size} (got {chunk})"
        )
    return 1 << (c.bit_length() - 1)


def _piece_steps(sb: int, piece: int) -> list[int]:
    """Per-dispatch piece lengths an admission of bucket ``sb`` issues under
    piece budget ``piece`` (0 = chunking off): equal power-of-two pieces
    when the bucket exceeds the budget, else one monolithic dispatch. The
    distinct compiled prefill shapes per bucket are therefore <=
    log2(chunk)+1 for ANY budget sweep — a single piece size per bucket,
    same bound as ``_tail_sizes`` gives the decode scan."""
    if piece <= 0 or sb <= piece:
        return [sb]
    assert sb % piece == 0, (sb, piece)
    return [piece] * (sb // piece)


# Speculative draft-window sizes are powers of two: the verify scan length is
# k+1 and the device draft scan length is k or k+1 (one-token resync after a
# fully accepted window), so restricting k to powers of two bounds the
# distinct compiled scan lengths exactly like _tail_steps does for decode —
# warmup precompiles them all and adaptive-k never compiles mid-trace.
SPEC_K_MAX = 8


def _spec_k_sizes(k_max: int = SPEC_K_MAX) -> list[int]:
    """The draft-window sizes adaptive k can visit: powers of two <= k_max."""
    return [1 << i for i in range(max(int(k_max), 1).bit_length())
            if (1 << i) <= k_max]


def _spec_k_floor(n: int, k_max: int = SPEC_K_MAX) -> int:
    """Largest warm draft-window size <= n (0 when n < 1)."""
    if n < 1:
        return 0
    return min(1 << (int(n).bit_length() - 1), k_max)


def _spec_draft_sizes(k_max: int = SPEC_K_MAX) -> list[int]:
    """Draft-window scan lengths T = chain + k - 1 a device stream can
    dispatch: the pending chain is one token (post-rejection correction or
    warmup resync) or two (last draft + bonus after a full accept)."""
    return sorted({c + k - 1 for c in (1, 2) for k in _spec_k_sizes(k_max)})


@dataclasses.dataclass
class GenerationResult:
    tokens: list[int]
    ttft: float                  # seconds (compute only; network added by endpoint)
    token_times: list[float]     # wall-clock time of each token, relative to start
    prefill_s: float
    decode_s_per_token: float


def _check_block_size(block_size: int) -> int:
    """Paged prefill scatters whole blocks of the bucket-padded prompt, so
    ``block_size`` must divide every bucket length (powers of two from
    ``_MIN_BUCKET``): it must itself be a power of two <= _MIN_BUCKET."""
    bs = int(block_size)
    if bs < 1 or bs > _MIN_BUCKET or bs & (bs - 1):
        raise ValueError(
            f"block_size must be a power of two in [1, {_MIN_BUCKET}] "
            f"(got {block_size}): it has to divide the prefill buckets"
        )
    return bs


def _paged_windowed(cfg: ModelConfig) -> bool:
    return any(
        cfg.window and not cfg.layer_is_global(i) for i in range(cfg.n_layers)
    )


def _make_paged_step_fns(cfg: ModelConfig, max_len: int, use_kernel: bool):
    """The two jitted paged dispatches shared by InferenceEngine (1-row) and
    BatchedServer (R-row): a row prefill scattering into the donated pool,
    and a fused multi-token decode over page tables. Nothing per-request is
    closed over: the sampler rides in as per-row runtime operands (``ops``)
    next to the per-request keys, so heterogeneous SamplerConfigs share one
    compiled dispatch."""

    @functools.partial(jax.jit, donate_argnums=(1,))
    def prefill_fn(params, pages, tokens, lengths, block_ids, keys, ops):
        """Prefill (1, S) and scatter its K/V into the request's blocks.
        The pool is donated: blocks are written in place."""
        return paged_prefill(
            params, cfg, pages, tokens, lengths, block_ids,
            sampler=ops, keys=keys,
        )

    @functools.partial(jax.jit, donate_argnums=(1,))
    def suffix_fn(params, pages, tokens, lengths, prefix_bt, block_ids, keys, ops):
        """Prefix-hit prefill: compute only the unmatched suffix, attending
        over the cached prefix blocks. Shapes (suffix length × matched
        blocks) key the jit cache; warmup precompiles every combination the
        buckets can produce."""
        return paged_suffix_prefill(
            params, cfg, pages, tokens, lengths, prefix_bt, block_ids,
            sampler=ops, keys=keys,
        )

    @functools.partial(jax.jit, donate_argnums=(1,))
    def piece_fn(params, pages, tokens, lengths, full_bt, n_pre, block_ids,
                 keys, ops):
        """Chunked-prefill piece: one token-budget-bounded slice of a prompt
        whose blocks are all reserved, appended at absolute positions over
        the row's page table. ``n_pre`` (tokens already written) is a traced
        operand, so every piece of a bucket shares ONE compile keyed by
        (bucket, piece) shapes only."""
        return paged_piece_prefill(
            params, cfg, pages, tokens, lengths, full_bt, n_pre, block_ids,
            sampler=ops, keys=keys,
        )

    @functools.partial(jax.jit, donate_argnums=(1,), static_argnames=("num_steps",))
    def decode_fn(params, pages, bt, lengths, tokens, active, keys, ops, num_steps):
        """Fused multi-token paged decode; inactive/saturated rows write the
        trash block and keep their lengths frozen."""
        return paged_decode_n(
            params, cfg, pages, bt, lengths, tokens, num_steps,
            max_len=max_len, active=active, use_kernel=use_kernel,
            sampler=ops, keys=keys,
        )

    @functools.partial(jax.jit, donate_argnums=(1,))
    def draft_fn(params, pages, bt, lengths, forced, use_forced, active, keys, ops):
        """Speculative draft window (device half): a teacher-forced resync
        prefix then sampled drafting, one fused dispatch, emitting the
        device's sampling distribution per position. The scan length (shape
        of ``forced``) keys the jit cache; ``use_forced`` is a runtime
        operand so different resync lengths share a compile."""
        return paged_draft_n(
            params, cfg, pages, bt, lengths, forced, use_forced,
            max_len=max_len, active=active, use_kernel=use_kernel,
            sampler=ops, keys=keys,
        )

    @functools.partial(jax.jit, donate_argnums=(1,))
    def verify_fn(params, pages, bt, lengths, tokens, drafts, dev_probs,
                  active, keys, ops):
        """Speculative verify (server half): score k draft positions plus
        the bonus position in one fused dispatch and return the
        rejection-sampling verdict (see ``models.paged.paged_verify_n``)."""
        return paged_verify_n(
            params, cfg, pages, bt, lengths, tokens, drafts, dev_probs,
            max_len=max_len, active=active, use_kernel=use_kernel,
            sampler=ops, keys=keys,
        )

    return prefill_fn, suffix_fn, piece_fn, decode_fn, draft_fn, verify_fn


def _warmup_paged_pool(prefill_fn, decode_fn, params, cfg, pages, *,
                       buckets, block_size, rows, max_blocks_per_row,
                       decode_chunk, num_blocks, suffix_fn=None,
                       piece_fn=None, prefill_chunk=0):
    """Precompile the paged prefill bucket(s) and decode tail lengths, then
    return a pristine pool (warmup scribbles on low block ids, never through
    the allocator). When ``suffix_fn`` is given (prefix cache enabled),
    every (matched blocks × suffix length) combination a bucket can produce
    is precompiled too, so a first prefix hit never pays an XLA compile
    inside a virtual-time-measured admission tick. When ``piece_fn`` /
    ``prefill_chunk`` are given (chunked prefill), the single piece shape
    each long bucket dispatches is precompiled (``n_pre`` is traced, so one
    compile covers every piece of the bucket)."""
    for s in buckets:
        nb = s // block_size
        _, pages = prefill_fn(
            params, pages, jnp.zeros((1, s), jnp.int32),
            jnp.asarray([s], jnp.int32),
            jnp.arange(1, nb + 1, dtype=jnp.int32),
            _zero_keys(1), _greedy_ops(1),
        )
        if piece_fn is not None and 0 < prefill_chunk < s:
            _, pages = piece_fn(
                params, pages, jnp.zeros((1, prefill_chunk), jnp.int32),
                jnp.asarray([s], jnp.int32),
                jnp.arange(1, nb + 1, dtype=jnp.int32)[None, :],
                jnp.asarray(0, jnp.int32),
                jnp.arange(1, prefill_chunk // block_size + 1, dtype=jnp.int32),
                _zero_keys(1), _greedy_ops(1),
            )
        if suffix_fn is None:
            continue
        for n_hit in range(1, nb):
            s2 = s - n_hit * block_size
            _, pages = suffix_fn(
                params, pages, jnp.zeros((1, s2), jnp.int32),
                jnp.asarray([s], jnp.int32),
                jnp.arange(1, n_hit + 1, dtype=jnp.int32)[None, :],
                jnp.arange(1, s2 // block_size + 1, dtype=jnp.int32),
                _zero_keys(1), _greedy_ops(1),
            )
    bt = jnp.zeros((rows, max_blocks_per_row), jnp.int32)
    lengths = jnp.zeros((rows,), jnp.int32)
    tokens = jnp.zeros((rows,), jnp.int32)
    keys = _zero_keys(rows)
    ops = _greedy_ops(rows)
    inactive = jnp.zeros((rows,), bool)       # rows stay frozen
    for n in _tail_sizes(decode_chunk):
        toks, pages, _ = decode_fn(
            params, pages, bt, lengths, tokens, inactive, keys, ops, n
        )
    jax.block_until_ready(toks)
    return init_paged_pages(cfg, num_blocks, block_size)


def _engine_compute_cfg(cfg: ModelConfig) -> ModelConfig:
    """Backend-aware compute dtype: bfloat16 matmuls are software-emulated on
    the CPU backend (every weight re-converted per step), so serving engines
    compute in float32 there. TPU/GPU keep the configured dtype."""
    if jax.default_backend() == "cpu" and jnp.dtype(cfg.dtype) == jnp.bfloat16:
        return dataclasses.replace(cfg, dtype="float32")
    return cfg


def _cast_params(params, dtype):
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.bfloat16 and dt != jnp.bfloat16 else a,
        params,
    )


class InferenceEngine:
    """Single-model engine with jitted prefill/decode.

    ``decode_chunk`` tokens are decoded per device dispatch / host sync.

    Sampling is *per request*: each :class:`~repro.serving.request.Request`
    carries its own ``SamplerConfig`` and ``seed`` (``sampler`` here is only
    the default for requests that don't specify one; greedy argmax when
    omitted). The sampler is threaded through the jitted step functions as
    per-row runtime operands — never baked into a jit closure — and with
    temperature > 0 every token is drawn with the position-keyed counter RNG
    of ``models.sampling``: the token at position *i* depends only on
    (config, seed, i, logits), so replay (``open_replay``,
    ``replay_then_continue``) and ``fork_stream`` continue a stream
    bit-identically when given the same seed and config.

    ``paged=True`` switches the generation paths (``generate``,
    ``open_stream``/``open_replay``, ``replay_then_continue``) onto the
    block-pooled KV cache: each request allocates fixed-size token blocks
    from a shared pool on prefill, extends block-by-block as it decodes, and
    returns them the moment it finishes or is cancelled — so ``kv_rows``
    concurrent streams share ``num_blocks`` blocks of physical cache instead
    of each reserving a dense ``max_len`` buffer. ``fork_stream`` clones a
    live stream's page table + blocks (copy-on-migration) to continue it
    without a re-prefill. The dense ``prefill``/``decode`` methods remain
    for callers that manage their own cache.
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 decode_chunk: int = 8, paged: bool = False,
                 block_size: int = 16, kv_rows: int = 4,
                 num_blocks: Optional[int] = None,
                 use_kernel: Optional[bool] = None,
                 sampler: Optional[SamplerConfig] = None,
                 prefix_cache: bool = False,
                 speculative: bool = False):
        cfg = _engine_compute_cfg(cfg)
        self.cfg = cfg
        self.params = _cast_params(params, cfg.dtype)
        self.max_len = max_len
        self.decode_chunk = max(decode_chunk, 1)
        self._bucketed = _bucketed_prefill_ok(cfg)
        # per-request default only: requests may carry their own SamplerConfig
        self.default_sampler: Optional[SamplerConfig] = sampler
        self._next_rid = 0
        self.paged = bool(paged)
        if prefix_cache and not self.paged:
            raise ValueError("prefix_cache requires a paged engine")
        if self.paged:
            if not supports_paged(cfg):
                raise ValueError(
                    f"{cfg.name}: paged KV needs a causal attention-only "
                    "token model (SSM/MLA/encoder caches are not paged)"
                )
            self.block_size = _check_block_size(block_size)
            self.max_blocks_per_row = -(-max_len // self.block_size)
            if num_blocks is None:
                num_blocks = kv_rows * self.max_blocks_per_row + 1
            self.kv = KVPoolManager(
                num_blocks, self.block_size, kv_rows, self.max_blocks_per_row,
                prefix_cache=prefix_cache,
            )
            self.pages = init_paged_pages(cfg, num_blocks, self.block_size)
            if use_kernel is None:
                use_kernel = on_tpu() and not _paged_windowed(cfg)
            self.use_kernel = bool(use_kernel)
            (self._paged_prefill_fn, self._paged_suffix_fn,
             self._paged_piece_fn, self._paged_decode_fn,
             self._paged_draft_fn, self._paged_verify_fn) = (
                _make_paged_step_fns(cfg, max_len, self.use_kernel)
            )

            @functools.partial(jax.jit, donate_argnums=(0,))
            def _copy_blocks(pages, src_ids, dst_ids):
                return {
                    k: v.at[:, dst_ids].set(v[:, src_ids])
                    for k, v in pages.items()
                }

            self._copy_blocks = _copy_blocks

        @jax.jit
        def _prefill(params, tokens, lengths, keys, ops):
            logits, cache = prefill(params, cfg, tokens, max_len, lengths=lengths)
            # first token sampled at its absolute position = true prompt
            # length, so replay prefills resume the same position counter
            return sample_tokens(ops, logits, keys, lengths), cache

        # the cache flows linearly through decode (old cache never reused), so
        # its buffers are donated: XLA updates the KV cache in place instead
        # of copying it every step.
        @functools.partial(jax.jit, donate_argnums=(1,))
        def _decode(params, cache, token, keys, ops):
            logits, cache = decode_step(params, cfg, cache, token)
            return sample_tokens(ops, logits, keys, cache["lengths"]), cache

        @functools.partial(
            jax.jit, donate_argnums=(1,), static_argnames=("num_steps",)
        )
        def _decode_n(params, cache, token, keys, ops, num_steps):
            # unguarded: pure scan over decode_step, zero extra cache copies.
            # The host never consumes tokens past max_len-1 (see generate).
            return decode_n(params, cfg, cache, token, num_steps,
                            sampler=ops, keys=keys)

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _draft(params, cache, forced, use_forced, keys, ops):
            # unguarded: EngineStream.draft_window caps T host-side so the
            # scan never writes past max_len - 1 (same contract as _decode_n)
            return draft_n(params, cfg, cache, forced, use_forced,
                           sampler=ops, keys=keys)

        self._prefill = _prefill
        self._decode = _decode
        self._decode_n = _decode_n
        self._draft = _draft
        # speculative=True widens warmup to precompile the draft-window scan
        # lengths so no XLA compile lands inside a virtual-timed draft round
        self.speculative = bool(speculative)

    @property
    def supports_draft(self) -> bool:
        """Speculative rollback trims ``lengths`` — sound only for pure
        attention caches (recurrent/SSM state cannot be rewound)."""
        return not self.cfg.has_ssm and not self.cfg.is_encoder

    # -- prefill -----------------------------------------------------------

    def warmup(self, batch: int = 1, prompt_len: int = 8,
               prompt_lens: tuple = ()) -> None:
        """Precompile prefill bucket(s) and decode scan lengths. Pass every
        prompt length the workload will see via ``prompt_lens`` so no XLA
        compile lands inside a wall-clock-timed (virtual-timeline) region."""
        if self.paged:
            self._warmup_paged(prompt_len, prompt_lens)
            return
        buckets = sorted({
            _bucket_len(s, self.max_len) if self._bucketed else s
            for s in (prompt_len, *prompt_lens)
        })
        for s in buckets[1:]:
            t, _ = self.prefill(np.zeros((batch, s), np.int32))
        tok = np.zeros((batch, buckets[0]), np.int32)
        keys = _zero_keys(batch)
        ops = _greedy_ops(batch)
        t, cache = self.prefill(tok)
        # decode donates the cache: thread it, never reuse a donated buffer
        tok_dev, cache = self._decode(self.params, cache, jnp.asarray(t), keys, ops)
        # precompile every tail scan length generate can dispatch, so no XLA
        # compile ever lands inside the wall-clock-timed decode region
        for n in _tail_sizes(self.decode_chunk):
            toks, cache = self._decode_n(self.params, cache, tok_dev, keys, ops, n)
            tok_dev = toks[-1]
        if self.speculative and self.supports_draft:
            for t in _spec_draft_sizes():
                forced = jnp.zeros((t, batch), jnp.int32)
                toks, _, cache = self._draft(
                    self.params, cache, forced, jnp.zeros((t,), bool),
                    keys, ops,
                )
                tok_dev = toks[-1]
        jax.block_until_ready(tok_dev)

    def _warmup_paged(self, prompt_len: int, prompt_lens: tuple) -> None:
        buckets = sorted({
            _bucket_len(s, self.max_len) for s in (prompt_len, *prompt_lens)
        })
        self.pages = _warmup_paged_pool(
            self._paged_prefill_fn, self._paged_decode_fn, self.params,
            self.cfg, self.pages, buckets=buckets, block_size=self.block_size,
            rows=1, max_blocks_per_row=self.max_blocks_per_row,
            decode_chunk=self.decode_chunk, num_blocks=self.kv.pool.num_blocks,
            suffix_fn=self._paged_suffix_fn if self.kv.prefix is not None else None,
        )
        if self.speculative and self.supports_draft:
            # inactive rows write the trash block (NULL_BLOCK) and keep their
            # lengths frozen, so precompiling on the live pool leaves it
            # pristine
            bt = jnp.zeros((1, self.max_blocks_per_row), jnp.int32)
            keys = jnp.asarray(_zero_keys(1))
            ops = _greedy_ops(1)
            last = None
            for t in _spec_draft_sizes():
                toks, _, self.pages, _ = self._paged_draft_fn(
                    self.params, self.pages, bt, jnp.zeros((1,), jnp.int32),
                    jnp.zeros((t, 1), jnp.int32), jnp.zeros((t,), bool),
                    jnp.zeros((1,), bool), keys, ops,
                )
                last = toks
            if last is not None:
                jax.block_until_ready(last)

    def _chunk_stream(self, cache, tok_dev, start_len: int, max_new: int,
                      keys=None, ops=None):
        """Yield (tokens_np (n_valid, B), n_valid) decode chunks after the
        prefill token: one fused dispatch + one host sync per chunk, stopping
        at max_new or cache saturation (lengths == max_len - 1, exactly the
        seed per-token guard). Shared by generate and replay_then_continue."""
        if keys is None:
            keys = _zero_keys(1)
        if ops is None:
            ops = _greedy_ops(1)
        emitted = 1
        cur_len = start_len
        while emitted < max_new:
            n_valid = min(
                self.decode_chunk,
                max_new - emitted,
                max(0, (self.max_len - 1) - cur_len),
            )
            if n_valid <= 0:
                return
            n_steps = _tail_steps(n_valid, self.decode_chunk)
            toks, cache = self._decode_n(
                self.params, cache, tok_dev, keys, ops, n_steps
            )
            toks_np = np.asarray(jax.block_until_ready(toks))  # ONE sync/chunk
            yield toks_np[:n_valid], n_valid
            emitted += n_valid
            cur_len += n_valid
            tok_dev = toks[-1]

    # -- paged request lifecycle (alloc / extend / free / clone) -----------

    def _paged_admit_prefill(self, rid: int, prompt: np.ndarray,
                             keys=None, ops=None) -> int:
        """Alloc-on-prefill: admit ``rid`` (blocks + row) and run the paged
        row prefill. Raises ``RuntimeError`` when the pool cannot hold the
        prompt — the device engine has no queue to fall back on."""
        if keys is None:
            keys = _zero_keys(1)
        if ops is None:
            ops = _greedy_ops(1)
        s = int(prompt.shape[0])
        padded, lengths = _pad_to_bucket(
            prompt[None, :], self.max_len, self._bucketed
        )
        sb = int(padded.shape[1])
        matched = self.kv.prefix_match(prompt)       # [] when cache disabled
        n_hit = len(matched)
        demand = self.kv.prefill_demand(sb, s) - n_hit
        table = self.kv.admit(rid, demand, num_tokens=s, prefix_blocks=matched)
        if table is None:
            raise RuntimeError(
                f"KV pool exhausted: request needs {demand} blocks "
                f"({self.kv.pool.num_free} free, "
                f"{'no' if not self.kv.has_free_row else 'a'} free row)"
            )
        nb = sb // self.block_size
        if n_hit:
            # suffix-only prefill: the matched blocks are read-only aliases
            tok, self.pages = self._paged_suffix_fn(
                self.params, self.pages,
                jnp.asarray(padded[:, n_hit * self.block_size:], jnp.int32),
                jnp.asarray(lengths), jnp.asarray([matched], jnp.int32),
                jnp.asarray(table.blocks[n_hit:nb], jnp.int32),
                jnp.asarray(keys), ops,
            )
        else:
            tok, self.pages = self._paged_prefill_fn(
                self.params, self.pages, jnp.asarray(padded, jnp.int32),
                jnp.asarray(lengths), jnp.asarray(table.blocks[:nb], jnp.int32),
                jnp.asarray(keys), ops,
            )
        # numpy conversion, not jax indexing: tok[0] on a device array jit-
        # compiles tiny slice/squeeze executables on first use (~tens of ms)
        return int(np.asarray(jax.block_until_ready(tok))[0])

    def _paged_release(self, rid: int, cache_tokens=None) -> None:
        """Free-on-finish-or-cancel: blocks return to the pool immediately
        (sealed blocks stay warm in the prefix index when ``cache_tokens``
        names their contents and the cache is enabled)."""
        self.kv.release(rid, cache_tokens=cache_tokens)

    def _paged_chunks(self, rid: int, tok_dev, start_len: int, max_new: int,
                      emitted: int = 1, keys=None, ops=None):
        """Paged twin of ``_chunk_stream``: extend-on-decode grows the page
        table just ahead of each fused chunk; an extension the pool cannot
        serve ends the stream early (the rid lands in ``kv.extend_stalls`` —
        the stream's ``oom`` flag)."""
        if keys is None:
            keys = _zero_keys(1)
        if ops is None:
            ops = _greedy_ops(1)
        keys = jnp.asarray(keys)
        cur = start_len
        while emitted < max_new:
            n_valid = min(
                self.decode_chunk,
                max_new - emitted,
                max(0, (self.max_len - 1) - cur),
            )
            if n_valid <= 0 or rid not in self.kv.tables:
                return
            if not self.kv.extend(rid, cur + n_valid):
                return                          # pool exhausted mid-decode
            bt = jnp.asarray(
                np.asarray(
                    [self.kv.tables[rid].padded(self.max_blocks_per_row)],
                    np.int32,
                )
            )
            n_steps = _tail_steps(n_valid, self.decode_chunk)
            toks, self.pages, _ = self._paged_decode_fn(
                self.params, self.pages, bt,
                jnp.asarray([cur], jnp.int32), tok_dev,
                jnp.ones((1,), bool), keys, ops, n_steps,
            )
            toks_np = np.asarray(jax.block_until_ready(toks))  # ONE sync/chunk
            cur += n_valid
            self.kv.tables[rid].num_tokens = cur
            yield toks_np[:n_valid], n_valid
            emitted += n_valid
            tok_dev = toks[-1]

    def fork_stream(self, src: "EngineStream", max_new: int) -> "EngineStream":
        """Alias-on-migration (device-local consistent-prefix hand-off):
        clone ``src``'s page table sharing its sealed (full) blocks — an
        O(1) refcount bump, zero device block copies — with copy-on-write
        only on a partial tail block, and return a new stream that continues
        decoding from the source's current state with no re-prefill. The
        source keeps its own table and may keep generating (the hand-off
        race). The fork inherits the source's request (seed AND sampler
        config), so under temperature > 0 it continues the exact
        per-position RNG stream the source would.

        When the pool cannot serve even the clone's tail blocks, the fork
        degrades gracefully instead of raising mid-migration: it falls back
        to a replay re-prefill stream (prompt + emitted token IDs, the same
        recompute path migration uses across engines) whose admission is
        deferred to its first pull — by which time the source may have
        released its blocks. ``kv.clone_fallbacks`` counts these."""
        if not self.paged:
            raise ValueError("fork_stream requires a paged engine")
        if src._rid is None or src._rid not in self.kv.tables:
            raise ValueError("source stream has no live KV allocation")
        rid = self._next_rid
        self._next_rid += 1
        res = self.kv.clone(src._rid, rid)
        if res is None:
            self.kv.clone_fallbacks += 1
            full = np.concatenate(
                [src._prompt, np.asarray(src._emitted, np.int32)]
            )
            st = EngineStream(self, src.req, prompt=full, max_new=max_new)
            st._soft_admit = True          # pool-full at pull => oom, not raise
            return st
        table, pairs = res
        if pairs:                          # partial tail only: CoW copy
            src_ids = jnp.asarray([a for a, _ in pairs], jnp.int32)
            dst_ids = jnp.asarray([b for _, b in pairs], jnp.int32)
            self.pages = self._copy_blocks(self.pages, src_ids, dst_ids)
        st = EngineStream(self, src.req, prompt=src._prompt, max_new=max_new)
        st._rid = rid
        st._emitted = list(src._emitted)   # cache contents = prompt + these
        st._last_tok = src._last_tok
        st.prefill_s = 0.0                 # no prefill: state was aliased
        st.tokens_emitted = 0
        st._chunks = self._paged_chunks(
            rid, jnp.asarray([src._last_tok], jnp.int32),
            table.num_tokens, max_new, emitted=0, keys=st.keys, ops=st.ops,
        )
        return st

    def prefill(self, tokens: np.ndarray, keys=None, ops=None):
        """tokens: (B, S) int32. Returns (first_token (B,), cache).
        ``keys``/``ops``: optional (B,)-shaped per-row request keys and
        sampler operands (sampling engines; omitted means greedy rows)."""
        padded, lengths = _pad_to_bucket(
            np.asarray(tokens, np.int32), self.max_len, self._bucketed
        )
        if keys is None:
            keys = _zero_keys(padded.shape[0])
        if ops is None:
            ops = _greedy_ops(padded.shape[0])
        t, cache = self._prefill(
            self.params, jnp.asarray(padded, jnp.int32), jnp.asarray(lengths),
            jnp.asarray(keys), ops,
        )
        return np.asarray(jax.block_until_ready(t)), cache

    def decode(self, cache, token: np.ndarray, keys=None, ops=None):
        """One decode step. NOTE: ``cache`` is donated (updated in place on
        the device) — callers must use the returned cache, not the argument."""
        token = np.asarray(token, np.int32)
        if keys is None:
            keys = _zero_keys(token.shape[0])
        if ops is None:
            ops = _greedy_ops(token.shape[0])
        t, cache = self._decode(
            self.params, cache, jnp.asarray(token), jnp.asarray(keys), ops
        )
        return np.asarray(jax.block_until_ready(t)), cache

    # -- generation --------------------------------------------------------

    def generate(self, prompt: np.ndarray, max_new: int, replay: bool = False,
                 seed: int = 0,
                 sampler: Optional[SamplerConfig] = None) -> GenerationResult:
        """Generation for one prompt (1, S). Wall-clock timed. Convenience
        wrapper over the Request API (``open_stream``).

        ``seed`` keys the request's sampling stream and ``sampler``
        overrides the engine default for this request (greedy rows ignore
        both): two generations with the same (seed, sampler) are
        bit-identical, as is any replay/fork that carries them forward.

        Decodes in fused chunks of ``decode_chunk`` tokens: one device
        dispatch and one host sync per chunk. The host only observes chunk
        boundaries, but the device produces tokens sequentially inside the
        chunk, so per-token timestamps are linearly interpolated across the
        chunk interval — downstream TBT/QoE series (DiSCo endpoints) keep
        their token-by-token meaning instead of a bursty 0/spike pattern.
        """
        req = Request(prompt, max_new, seed=seed, sampler=sampler)
        if self.paged:
            st = self.open_stream(req)
            tokens, times = [], []
            while (chunk := st.next_chunk()) is not None:
                tokens += chunk[0]
                times += chunk[1]
            n_dec = max(len(tokens) - 1, 1)
            return GenerationResult(
                tokens=tokens,
                ttft=st.prefill_s,
                token_times=times,
                prefill_s=st.prefill_s,
                decode_s_per_token=(times[-1] - times[0]) / n_dec,
            )
        keys = _request_keys([seed])
        ops = sampler_operands([sampler or self.default_sampler])
        t0 = time.perf_counter()
        tok, cache = self.prefill(prompt[None, :], keys=keys, ops=ops)
        t_first = time.perf_counter()
        tokens, times = [int(tok[0])], [t_first - t0]
        t_prev = t_first - t0
        for toks_np, n_valid in self._chunk_stream(
            cache, jnp.asarray(tok, jnp.int32), int(prompt.shape[0]), max_new,
            keys=keys, ops=ops,
        ):
            now = time.perf_counter() - t0
            for i in range(n_valid):
                tokens.append(int(toks_np[i, 0]))
                times.append(t_prev + (i + 1) * (now - t_prev) / n_valid)
            t_prev = now
        n_dec = max(len(tokens) - 1, 1)
        return GenerationResult(
            tokens=tokens,
            ttft=t_first - t0,
            token_times=times,
            prefill_s=t_first - t0,
            decode_s_per_token=(times[-1] - times[0]) / n_dec,
        )

    def replay_then_continue(
        self, prompt: np.ndarray, generated: list[int], max_new: int,
        seed: int = 0, sampler: Optional[SamplerConfig] = None
    ) -> tuple[float, "Iterator[int]"]:
        """Migration target path (§4.3): re-prefill prompt + received token IDs
        (no KV transfer), then continue decoding. Returns (replay_seconds,
        iterator of continuation tokens). The continuation decodes in fused
        chunks and buffers them host-side. With the source's ``seed`` (and
        sampler config) the continuation is bit-identical to what the source
        would have produced (the replay prefill samples at position
        len(prompt) + len(generated), exactly the source's next counter
        value)."""
        if self.paged:
            req = Request(prompt, max_new + len(generated), seed=seed,
                          sampler=sampler)
            st = self.open_replay(req, generated, max_new=max_new)
            first = st.next_chunk()          # replay prefill, eager

            def paged_continuation():
                if first is not None:
                    yield from first[0]
                while (c := st.next_chunk()) is not None:
                    yield from c[0]

            return st.prefill_s, paged_continuation()
        keys = _request_keys([seed])
        ops = sampler_operands([sampler or self.default_sampler])
        t0 = time.perf_counter()
        full = np.concatenate([prompt, np.asarray(generated, np.int32)])
        tok, cache = self.prefill(full[None, :], keys=keys, ops=ops)
        replay_s = time.perf_counter() - t0
        start_len = int(full.shape[0])

        def continuation():
            yield int(tok[0])
            for toks_np, n_valid in self._chunk_stream(
                cache, jnp.asarray(tok, jnp.int32), start_len, max_new,
                keys=keys, ops=ops,
            ):
                for i in range(n_valid):
                    yield int(toks_np[i, 0])

        return replay_s, continuation()

    # -- incremental (event-loop) interface --------------------------------

    def open_stream(self, req: Request) -> "EngineStream":
        """Lazy token source for one :class:`~repro.serving.request.Request`:
        nothing is dispatched until the first pull. The request's ``seed``
        keys its sampling stream and its ``sampler`` (engine default when
        None) rides the jitted dispatches as per-row runtime operands. See
        :class:`EngineStream`."""
        return EngineStream(self, _require_request(req, "open_stream"))

    def open_replay(self, req: Request, generated,
                    max_new: Optional[int] = None) -> "EngineStream":
        """Migration-target source (§4.3): first pull re-prefills
        prompt + received token IDs (no KV transfer); the stream then emits
        up to ``max_new`` continuation tokens (default: the request's
        remaining budget ``req.max_new - len(generated)``; the
        replay-prefill's next token is the first of them). ``req`` must be
        the SOURCE's request so the continuation resumes the same
        per-position sampling stream with the same config."""
        req = _require_request(req, "open_replay")
        generated = np.asarray(generated, np.int32)
        full = np.concatenate([req.prompt, generated])
        if max_new is None:
            max_new = max(req.max_new - int(generated.shape[0]), 1)
        return EngineStream(self, req, prompt=full, max_new=max_new)


class EngineStream:
    """Lazily pulled incremental generation from one :class:`InferenceEngine`.

    Compute happens on pull: the first ``next_chunk()`` dispatches the
    prefill and returns its token; each later call dispatches one fused
    decode chunk. Pull wall-clock is measured and per-token times are
    interpolated across the chunk interval (the device emits sequentially
    inside a chunk), so downstream TBT series keep token-by-token meaning —
    this applies to replayed (migration) streams too, which previously
    stamped a whole host-buffered chunk with one burst timestamp.

    ``cancel()`` stops all future dispatches and drops the cache reference
    (on a paged engine the request's blocks return to the shared pool the
    same instant): a cancelled race loser wastes at most the one chunk that
    was in flight.
    """

    def __init__(self, engine: InferenceEngine, req: Request,
                 prompt: Optional[np.ndarray] = None,
                 max_new: Optional[int] = None):
        """``req`` carries the contract (sampler/seed/SLO); ``prompt`` /
        ``max_new`` override the compute inputs for replay and fork streams
        (a replay prefills prompt + delivered tokens but keeps the request's
        sampler and seed)."""
        self.engine = engine
        self.req = req
        self._prompt = req.prompt if prompt is None else np.asarray(prompt, np.int32)
        self._max_new = req.max_new if max_new is None else int(max_new)
        self.seed = 0 if req.seed is None else int(req.seed)
        self.sampler = (
            req.sampler if req.sampler is not None else engine.default_sampler
        )
        self._keys: Optional[np.ndarray] = None
        self._ops = None
        self._chunks = None           # generator once prefill has run
        self.cancelled = False
        self.exhausted = False
        self.prefill_s: Optional[float] = None
        self.decode_dispatches = 0    # fused decode-chunk dispatches
        self.tokens_emitted = 0       # includes the prefill token
        self._elapsed = 0.0           # compute-seconds consumed so far
        self._rid: Optional[int] = None   # paged engines: pool allocation id
        self._last_tok: Optional[int] = None
        # token ids following the prompt in this stream's KV rows (a fork
        # seeds them with the source's): prefix-cache registration at release
        # and the fork fallback's replay prompt both need them
        self._emitted: list[int] = []
        self._soft_admit = False      # fork fallback: pool-full => oom flag
        # speculative draft mode (device half of draft/verify): the stream
        # stops running its autonomous decode generator and instead serves
        # fused draft windows that the driver verifies on the server
        self._draft_mode = False
        self._cache = None            # dense draft mode: the KV cache
        self._cur_len = 0             # tokens whose KV is written
        self._chain: list[int] = []   # committed tokens not yet in the KV
        self._win_base = 0            # KV length covering the forced chain
        self._win_k = 0
        self._win_drafts: Optional[list[int]] = None

    @property
    def keys(self) -> np.ndarray:
        """(1, 2) uint32 request key, derived once from the seed."""
        if self._keys is None:
            self._keys = _request_keys([self.seed])
        return self._keys

    @property
    def ops(self):
        """(1,) per-row sampler operands, derived once from the request."""
        if self._ops is None:
            self._ops = sampler_operands([self.sampler])
        return self._ops

    @property
    def prefilled(self) -> bool:
        return self.prefill_s is not None

    @property
    def done(self) -> bool:
        return self.cancelled or self.exhausted

    @property
    def oom(self) -> bool:
        """True when a paged stream was truncated because the pool could not
        extend its page table mid-decode."""
        return (
            self.engine.paged
            and self._rid is not None
            and self._rid in self.engine.kv.extend_stalls
        )

    def next_chunk(self):
        """Pull the next chunk: ``(tokens, rel_times)`` or ``None`` when the
        stream is exhausted or cancelled. Times are seconds of *compute*
        since the stream started (prefill included)."""
        if self.done:
            return None
        if self._chunks is None:
            keys = self.keys              # derived before t0, not timed compute
            ops = self.ops
            t0 = time.perf_counter()
            if self.engine.paged:
                self._rid = self.engine._next_rid
                self.engine._next_rid += 1
                try:
                    tok0 = self.engine._paged_admit_prefill(
                        self._rid, self._prompt, keys=keys, ops=ops
                    )
                except RuntimeError:
                    if not self._soft_admit:
                        raise
                    # fork fallback whose deferred re-prefill still found the
                    # pool full: end the stream with its oom flag set instead
                    # of crashing the driver mid-migration
                    self.engine.kv.extend_stalls.add(self._rid)
                    self.exhausted = True
                    return None
                self.prefill_s = time.perf_counter() - t0
                self._elapsed = self.prefill_s
                self._chunks = self.engine._paged_chunks(
                    self._rid, jnp.asarray([tok0], jnp.int32),
                    int(self._prompt.shape[0]), self._max_new, keys=keys,
                    ops=ops,
                )
                self.tokens_emitted = 1
                self._last_tok = tok0
                self._emitted.append(tok0)
                return [tok0], [self.prefill_s]
            tok, cache = self.engine.prefill(
                self._prompt[None, :], keys=keys, ops=ops
            )
            self.prefill_s = time.perf_counter() - t0
            self._elapsed = self.prefill_s
            self._chunks = self.engine._chunk_stream(
                cache, jnp.asarray(tok, jnp.int32),
                int(self._prompt.shape[0]), self._max_new, keys=keys, ops=ops,
            )
            self.tokens_emitted = 1
            return [int(tok[0])], [self.prefill_s]
        t0 = time.perf_counter()
        nxt = next(self._chunks, None)
        dur = time.perf_counter() - t0
        if nxt is None:
            self.exhausted = True
            self._chunks = None
            self._release()
            return None
        toks_np, n_valid = nxt
        self.decode_dispatches += 1
        start = self._elapsed
        self._elapsed += dur
        self.tokens_emitted += n_valid
        tokens = [int(toks_np[i, 0]) for i in range(n_valid)]
        times = [start + (i + 1) * dur / n_valid for i in range(n_valid)]
        self._last_tok = tokens[-1]
        self._emitted.extend(tokens)
        return tokens, times

    def _release(self) -> None:
        if self.engine.paged and self._rid is not None:
            cache_tokens = None
            table = self.engine.kv.tables.get(self._rid)
            if self._draft_mode:
                # a mid-window cancel leaves unverified draft tokens in the
                # KV rows, so sealed blocks must not enter the prefix index
                table = None
            if table is not None and self.engine.kv.prefix is not None:
                # the rows actually written: prompt + emitted, truncated to
                # the covered entry count (the last token is not cached yet)
                cache_tokens = np.concatenate(
                    [self._prompt, np.asarray(self._emitted, np.int32)]
                )[:table.num_tokens]
            self.engine._paged_release(self._rid, cache_tokens=cache_tokens)

    def cancel(self) -> None:
        self.cancelled = True
        self._chunks = None           # free the KV cache reference
        self._cache = None            # dense draft mode: drop the cache too
        self._release()               # paged: blocks back to the pool now

    # -- speculative draft mode (device half of draft/verify) ---------------
    #
    # The stream keeps a host-side (cur_len, chain) state machine instead of
    # its autonomous decode generator: ``cur_len`` counts tokens whose KV is
    # written, ``chain`` holds committed tokens not yet written (the next
    # window teacher-forces them first). A window of k drafts dispatches
    # T = len(chain) + k - 1 fused steps — the last forced step's sample IS
    # draft 1 — and the verify verdict rewinds the cache by trimming lengths
    # (pure-attention caches only; see ``InferenceEngine.supports_draft``).

    def draft_prefill(self) -> tuple[int, float]:
        """Prefill only, entering draft mode (no decode generator). Returns
        ``(first_token, prefill_s)`` — the device's own position-S draw; the
        driver resyncs the chain onto the server's committed prefill token
        via :meth:`force_pending` before the first window."""
        if not self.engine.supports_draft:
            raise ValueError(
                f"{self.engine.cfg.name}: draft mode needs a rewindable "
                "(pure-attention) cache"
            )
        keys = self.keys
        ops = self.ops
        t0 = time.perf_counter()
        if self.engine.paged:
            self._rid = self.engine._next_rid
            self.engine._next_rid += 1
            tok0 = self.engine._paged_admit_prefill(
                self._rid, self._prompt, keys=keys, ops=ops
            )
        else:
            tok, cache = self.engine.prefill(
                self._prompt[None, :], keys=keys, ops=ops
            )
            self._cache = cache
            tok0 = int(tok[0])
        self.prefill_s = time.perf_counter() - t0
        self._elapsed = self.prefill_s
        self._draft_mode = True
        self._cur_len = int(self._prompt.shape[0])
        self._chain = [tok0]
        self.tokens_emitted = 1
        self._last_tok = tok0
        self._emitted.append(tok0)
        return tok0, self.prefill_s

    def force_pending(self, tok: int) -> None:
        """Replace the pending (not yet KV-written) chain with the server's
        committed continuation — the warmup resync: whatever the device drew
        at position S, the stream's next window forces the server's token."""
        del self._emitted[len(self._emitted) - len(self._chain):]
        self._chain = [int(tok)]
        self._emitted.append(int(tok))

    def draft_window(self, k: int):
        """Dispatch one fused draft window of up to ``k`` tokens (floored to
        a warm power of two). Returns ``(drafts, device_probs, compute_s)``
        — ``drafts`` the k sampled tokens, ``device_probs`` their (k, V)
        sampling distributions for the server's rejection test — or ``None``
        when the stream cannot draft (cache saturated / pool exhausted):
        the driver falls back to plain server decode."""
        if self._win_drafts is not None:
            raise RuntimeError("draft_window before draft_rewind")
        if not self._chain:
            raise RuntimeError("draft mode has no pending chain")
        m = len(self._chain)
        cap = self.engine.max_len - self._cur_len - m   # max k this window
        k_eff = _spec_k_floor(min(int(k), cap))
        if k_eff < 1:
            return None
        n_steps = m + k_eff - 1
        forced = np.zeros((n_steps, 1), np.int32)
        forced[:m, 0] = self._chain
        use_forced = np.zeros((n_steps,), bool)
        use_forced[:m] = True
        keys = jnp.asarray(self.keys)
        ops = self.ops
        t0 = time.perf_counter()
        if self.engine.paged:
            kv = self.engine.kv
            if self._rid not in kv.tables or not kv.extend(
                self._rid, self._cur_len + n_steps
            ):
                return None            # pool exhausted: fall back
            bt = jnp.asarray(np.asarray(
                [kv.tables[self._rid].padded(self.engine.max_blocks_per_row)],
                np.int32,
            ))
            toks, probs, self.engine.pages, _ = self.engine._paged_draft_fn(
                self.engine.params, self.engine.pages, bt,
                jnp.asarray([self._cur_len], jnp.int32),
                jnp.asarray(forced), jnp.asarray(use_forced),
                jnp.ones((1,), bool), keys, ops,
            )
        else:
            toks, probs, self._cache = self.engine._draft(
                self.engine.params, self._cache, jnp.asarray(forced),
                jnp.asarray(use_forced), keys, ops,
            )
        toks_np = np.asarray(jax.block_until_ready(toks))[:, 0]
        probs_np = np.asarray(probs)[:, 0, :]
        dur = time.perf_counter() - t0
        self._cur_len += n_steps
        if self.engine.paged:
            self.engine.kv.tables[self._rid].num_tokens = self._cur_len
        self._win_base = self._cur_len - (k_eff - 1)
        self._win_k = k_eff
        self._win_drafts = [int(t) for t in toks_np[m - 1:]]
        self._chain = []
        self.decode_dispatches += 1
        self.tokens_emitted += k_eff   # rejected drafts count as device waste
        self._elapsed += dur
        return list(self._win_drafts), probs_np[m - 1:], dur

    def draft_rewind(self, n_acc: int, token: int) -> list[int]:
        """Apply the server's verify verdict: keep the first ``n_acc``
        drafts, rewind the KV past the rejection point, and chain ``token``
        (the server's residual correction, or the bonus token on a full
        accept). Returns the tokens committed this round."""
        if self._win_drafts is None:
            raise RuntimeError("draft_rewind without a pending window")
        k = self._win_k
        a = min(max(int(n_acc), 0), k)
        drafts = self._win_drafts
        if a < k:
            cur = self._win_base + a
            self._chain = [int(token)]
            committed = drafts[:a] + [int(token)]
        else:
            # full accept: the last draft's KV was never written (it is the
            # window's final sample), so it re-enters as forced chain along
            # with the server's bonus token
            cur = self._win_base + k - 1
            self._chain = [drafts[-1], int(token)]
            committed = drafts + [int(token)]
        if self.engine.paged:
            if self._rid in self.engine.kv.tables:
                self.engine.kv.shrink(self._rid, cur)
                self.engine.kv.tables[self._rid].num_tokens = cur
        else:
            self._cache["lengths"] = jnp.asarray(np.full(
                np.shape(self._cache["lengths"]), cur, np.int32
            ))
        self._cur_len = cur
        self._win_drafts = None
        self._last_tok = committed[-1]
        self._emitted.extend(committed)
        return committed


# ---------------------------------------------------------------------------
# Continuous batching (server-side request batching, §2.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Slot:
    request_id: int
    remaining: int
    tokens: list
    prompt: Optional[np.ndarray] = None   # original prompt (preemption resume)
    seed: int = 0                         # request sampling seed
    key: Optional[np.ndarray] = None      # (2,) uint32 request key
    sampler: Optional[SamplerConfig] = None   # per-request sampler config
    deadline: float = math.inf            # absolute TTFT deadline (SLO proxy:
                                          # preemption evicts the most relaxed
                                          # row first; survives resume)


@dataclasses.dataclass
class _Queued:
    """One queue entry. ``prompt`` is always the ORIGINAL prompt; a
    preemption-resume entry additionally carries the tokens already emitted
    (the admission prefill replays prompt + tokens — vLLM-style recompute),
    ``resume=True`` (resumes outrank fresh admissions), and the request's
    sampling ``seed``/``sampler``, so the resumed continuation draws the
    exact same per-position samples. ``deadline`` is the ABSOLUTE virtual
    time of the request's TTFT deadline (inf when un-SLO'd); ``priority`` is
    the admission tier (lower admits first)."""

    rid: int
    prompt: np.ndarray
    max_new: int                           # tokens still to emit
    tokens: list = dataclasses.field(default_factory=list)
    seed: int = 0
    sampler: Optional[SamplerConfig] = None
    priority: int = 0
    deadline: float = math.inf
    resume: bool = False


@dataclasses.dataclass
class _Partial:
    """A half-prefilled prompt under chunked admission: the row and ALL its
    blocks are reserved (same memory dynamics as a monolithic admission —
    ``_admissible`` tested the full demand), but the prompt's K/V is
    computed piecewise, one token-budget-bounded dispatch per piece tick,
    interleaved with decode chunks. ``item`` keeps the original queue entry
    so cancellation / preemption mid-prefill can requeue or retire it
    losslessly (no token has been sampled before the final piece)."""

    item: _Queued
    row: int
    table: object                 # kv_pool.BlockTable — all blocks reserved
    padded: np.ndarray            # (1, sb) bucket-padded prompt (+ resume)
    lengths: np.ndarray           # (1,) true total length
    s: int                        # true total length (host int)
    sb: int                       # bucket length
    n_done: int = 0               # tokens whose K/V is written
    key: Optional[np.ndarray] = None   # (1, 2) uint32 request key
    ops: object = None
    t_admit: float = 0.0          # virtual time the admission began


class BatchedServer:
    """Event-driven continuous-batching scheduler on a *virtual* timeline.

    Requests join free rows after a row prefill and all active rows share
    fused batched decode chunks. This models the server-side request
    batching the paper identifies as the source of TTFT tail latency (§2.3):
    queueing delay is *emergent contention*, not a sampled scalar.

    KV memory is PAGED by default (causal attention-only token models): all
    rows share one block pool (``kv_pool.KVPoolManager``) and admission is
    capacity-driven — a request is admitted when a row is free AND its
    prefill's block demand fits the free pool, so under load the *memory*,
    not an arbitrary slot count, is what queues requests. Decode extends
    each row's page table block-by-block; when the pool runs dry mid-decode
    the newest-admitted request is preempted (blocks freed, requeued at the
    head; on re-admission it re-prefills prompt + emitted tokens and
    continues — deterministic decoding makes the resume lossless: greedy
    argmax, or under temperature > 0 the position-keyed replayable sampler
    of ``models.sampling`` with the request's ``seed``). ``cancel(rid)``
    returns the blocks within the same tick. Architectures without a paged
    layout (SSM/MLA) keep the dense per-row cache.

    Timeline semantics: each scheduler tick is either (a) the admission of
    ONE queued request into a free row — a single row-prefill dispatch, no
    global barrier, interleaved between decode chunks — or (b) one fused
    decode chunk of ``decode_chunk`` tokens across all active rows (one
    dispatch + one host sync). The virtual clock advances by each tick's
    measured wall-clock compute; per-token event times are interpolated
    inside the chunk. ``submit(req, at=t)`` stamps a virtual arrival;
    ``run_until(t)`` processes ticks until the clock passes ``t`` (the last
    tick may overshoot — that is the "in-flight chunk" a cancellation cannot
    recall). Tokens are delivered incrementally per request id via
    ``pop_events``. ``cancel(rid, at=t)`` models cancel-propagation latency:
    the cancel takes effect only once the virtual clock reaches ``t`` (one
    uplink RTT after the driver issued it), so a queued race loser can slip
    into prefill and waste blocks meanwhile — ``cancel_lag_tokens`` counts
    the tokens generated in that window.

    Admission ordering (``admission=``): ``"edf"`` (default) is
    deadline-aware — among ARRIVED queue entries, preemption resumes first,
    then priority tier (lower first), then earliest absolute TTFT deadline
    (EDF; equivalently max TTFT slack), then FIFO. Requests without an SLO
    carry an infinite deadline, so an un-SLO'd workload orders exactly like
    FIFO. ``"fifo"`` ignores deadlines and priorities (the baseline the
    serving benchmark compares against). ``deadline_reorders`` counts
    admissions where the deadline-aware pick differed from FIFO's, and
    ``slo_misses`` counts first tokens that landed after their request's
    TTFT deadline. Sampling is per request: every entry carries its own
    ``SamplerConfig``, stacked into per-row runtime operands each tick, so
    one fused batch mixes greedy and stochastic rows bit-identically to
    running each alone.
    """

    # every scalar counter lives in the metrics registry (the single backing
    # store behind pool_stats()); these descriptors keep `self.x += 1` sites
    # and test reads working unchanged while the registry holds the number
    cancel_lag_tokens = metric_attr("cancel_lag_tokens")
    slo_misses = metric_attr("server_slo_misses")
    deadline_reorders = metric_attr("deadline_reorders")
    prefill_tokens_computed = metric_attr("prefill_tokens_computed")
    prefill_tokens_admitted = metric_attr("prefill_tokens_admitted")

    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_len: int = 256, decode_chunk: int = 4,
                 paged: Optional[bool] = None, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 use_kernel: Optional[bool] = None,
                 sampler: Optional[SamplerConfig] = None,
                 admission: str = "edf",
                 prefix_cache: bool = False,
                 speculative: bool = False,
                 prefill_chunk: Optional[int] = None,
                 tracer=None):
        cfg = _engine_compute_cfg(cfg)
        self.cfg = cfg
        self.params = _cast_params(params, cfg.dtype)
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_chunk = max(decode_chunk, 1)
        self._bucketed = _bucketed_prefill_ok(cfg)
        # per-request default only: requests may carry their own SamplerConfig
        self.default_sampler: Optional[SamplerConfig] = sampler
        if admission not in ("edf", "fifo"):
            raise ValueError(f"admission must be 'edf' or 'fifo' (got {admission!r})")
        self.admission = admission
        # registry first: the metric_attr counter initialisations below (and
        # the KVPoolManager, which shares this registry) write through to it
        self.metrics = MetricsRegistry()
        for _k in ("cancel_lag_tokens", "server_slo_misses", "deadline_reorders"):
            self.metrics.counter(_k)
        self.metrics.view("admission", lambda: self.admission)
        self.tracer = NULL_TRACER
        if paged is None:
            self.paged = supports_paged(cfg)
        elif paged and not supports_paged(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV needs a causal attention-only token model"
            )
        else:
            self.paged = bool(paged)

        if self.paged:
            self.block_size = _check_block_size(block_size)
            self.max_blocks_per_row = -(-max_len // self.block_size)
            if num_blocks is None:
                num_blocks = max_slots * self.max_blocks_per_row + 1
            # a lone request must always fit, else an empty server could
            # deadlock on an unadmittable head-of-queue
            num_blocks = max(int(num_blocks), self.max_blocks_per_row + 1)
            self.kv = KVPoolManager(
                num_blocks, self.block_size, max_slots, self.max_blocks_per_row,
                prefix_cache=prefix_cache, metrics=self.metrics,
            )
            self.metrics.view("prefill_compute_per_admitted_token", lambda: (
                self.prefill_tokens_computed / self.prefill_tokens_admitted
                if self.prefill_tokens_admitted else 0.0
            ))
            self.pages = init_paged_pages(cfg, num_blocks, self.block_size)
            self.block_tables = np.zeros(
                (max_slots, self.max_blocks_per_row), np.int32
            )
            if use_kernel is None:
                use_kernel = on_tpu() and not _paged_windowed(cfg)
            self.use_kernel = bool(use_kernel)
            (self._prefill_row_paged, self._suffix_row_paged,
             self._piece_row_paged, self._decode_chunk_paged,
             self._draft_row_paged, self._verify_row_paged) = (
                _make_paged_step_fns(cfg, max_len, self.use_kernel)
            )
        elif prefix_cache:
            raise ValueError("prefix_cache requires a paged server")
        elif prefill_chunk:
            raise ValueError(
                "prefill_chunk (chunked prefill) requires a paged server: "
                "pieces append K/V into already-reserved pool blocks"
            )
        else:
            @functools.partial(jax.jit, donate_argnums=(1,))
            def _prefill_row(params, batched_cache, tokens, lengths, row, keys,
                             ops):
                """Prefill (1, S) and write its cache into row ``row``. The
                batched cache is donated: the row write happens in place."""
                logits, cache = prefill(params, cfg, tokens, max_len, lengths=lengths)
                new = {}
                for k, v in batched_cache.items():
                    if k == "lengths":
                        new[k] = v.at[row].set(cache[k][0])
                    else:
                        new[k] = v.at[:, row].set(cache[k][:, 0])
                return sample_tokens(ops, logits, keys, lengths)[0], new

            @functools.partial(
                jax.jit, donate_argnums=(1,), static_argnames=("num_steps",)
            )
            def _decode_chunk(params, cache, tokens, active, keys, ops, num_steps):
                """Fused multi-token batched decode; inactive/saturated rows
                keep their cache untouched."""
                return decode_n(
                    params, cfg, cache, tokens, num_steps,
                    max_len=max_len, active=active, sampler=ops, keys=keys,
                )

            self._prefill_row = _prefill_row
            self._decode_chunk = _decode_chunk
            self.cache = init_cache(cfg, max_slots, max_len)
            self._free_rows = list(range(max_slots))
        # chunked prefill (Sarathi-style): long-prompt admissions split into
        # token-budget-bounded pieces interleaved with decode ticks, so one
        # admission stalls running rows by ONE piece, not one prompt.
        # ``"auto"`` sizes the budget at decode_chunk tokens per batch row —
        # a piece costs roughly what the decode chunk it displaces costs.
        if not prefill_chunk:
            self.prefill_chunk = 0
        else:
            if prefill_chunk == "auto":
                prefill_chunk = max(
                    self.decode_chunk * max_slots, self.block_size
                )
            self.prefill_chunk = _check_prefill_chunk(
                prefill_chunk, self.block_size
            )
        self._partial: dict[int, _Partial] = {}   # rid -> half-prefilled state
        self._piece_turn = False      # alternation: piece next (vs decode)?
        self._piece_ewma: Optional[float] = None  # smoothed piece seconds
        # decode-interference ledger: seconds decodable rows spent stalled
        # behind prefill dispatches (count = stall events, max = worst
        # single stall — the quantity chunking bounds)
        self.metrics.histogram("decode_stall_s")
        self.metrics.view("prefill_chunk", lambda: self.prefill_chunk)
        self._warm = False
        self.clock = 0.0                    # virtual seconds
        self.queue: list[_Queued] = []      # admission-ordered by _pick()
        self.slots: dict[int, _Slot] = {}
        self.rows: dict[int, int] = {}
        self.row_len = [0] * max_slots      # host-side mirror of cache lengths
        self.next_id = 0
        self.completed: dict[int, list[int]] = {}
        self.cancelled: set[int] = set()
        self.submit_time: dict[int, float] = {}     # virtual arrival
        self.first_token_time: dict[int, float] = {}  # virtual, admitted rids only
        self.events: dict[int, deque] = {}  # rid -> deque[(token, virtual_t)]
        self.decode_dispatches: dict[int, int] = {}  # chunks the rid was active in
        self.generated: dict[int, int] = {}          # tokens emitted per rid
        self.admit_seq: dict[int, int] = {}          # admission order (preemption)
        self._admit_counter = 0
        self._cancel_due: dict[int, float] = {}      # in-flight cancels (uplink RTT)
        # disaggregated hand-off hold: rids in ``kv_hold`` keep their KV
        # blocks referenced past retirement (detached into ``held_tables``)
        # until the cross-pool transfer completes — see cluster.py
        self.kv_hold: set[int] = set()
        self.held_tables: dict[int, tuple] = {}      # rid -> (PageTable, cache_tokens)
        self.cancel_lag_tokens = 0   # tokens generated after their cancel was issued
        self.slo_misses = 0          # first tokens that landed past their deadline
        self.deadline_reorders = 0   # EDF picks that differed from FIFO order
        # prefill-compute trajectory: device tokens actually computed by
        # admission prefills (suffix only on a prefix hit, bucket-padded)
        # vs. true prompt+replay tokens admitted — the per-admitted-token
        # prefill cost the benchmark tracks
        self.prefill_tokens_computed = 0
        self.prefill_tokens_admitted = 0
        # speculative verify (server half of draft/verify): verify rids stop
        # decoding autonomously — their tokens land through verify_step
        # rounds, which score k draft positions in one fused dispatch and
        # charge block demand for accepted tokens only (shrink-on-reject)
        if speculative and not self.paged:
            raise ValueError(
                "speculative verify requires a paged server (the rejected "
                "tail is rewound by trimming the page table)"
            )
        self.speculative = bool(speculative)
        self._verify_requested: set[int] = set()  # rids submitted verify=True
        self.verify_rids: set[int] = set()        # admitted + still verifying
        self.verify_positions: dict[int, int] = {}  # scored positions per rid
        self.verify_rounds: dict[int, int] = {}
        self.accepted_tokens: dict[int, int] = {}   # accepted drafts per rid
        if self.speculative:
            def _rounds():
                return sum(self.verify_rounds.values())

            def _scored():
                return sum(self.verify_positions.values()) - _rounds()

            def _accepted():
                return sum(self.accepted_tokens.values())

            self.metrics.view("verify_rounds", lambda: int(_rounds()))
            self.metrics.view("drafts_scored", lambda: int(_scored()))
            self.metrics.view("accepted_draft_tokens", lambda: int(_accepted()))
            self.metrics.view("acceptance_rate", lambda: (
                _accepted() / _scored() if _scored() else 0.0
            ))
        self.set_tracer(tracer)

    def set_tracer(self, tracer) -> None:
        """Attach (or detach, with None) a telemetry tracer; the paged KV
        manager shares it and stamps its events on this server's virtual
        clock."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.paged:
            self.kv.set_telemetry(self.tracer, lambda: self.clock)

    @property
    def free_rows(self) -> list:
        """Free batch rows (paged mode mirrors the pool manager's rows)."""
        return list(self.kv._free_rows) if self.paged else self._free_rows

    def warmup(self, prompt_len: int = 8, prompt_lens: tuple = ()) -> None:
        """Precompile the row prefill bucket(s) and every tail scan length
        step() can dispatch, so live scheduler ticks — and the virtual-time
        TTFTs measured through them — never include an XLA compile. Pass the
        workload's prompt lengths via ``prompt_lens``; skipping one only
        means the first tick at that shape pays the compile."""
        if self._warm:
            return
        buckets = sorted({
            _bucket_len(s, self.max_len) if self._bucketed else s
            for s in (prompt_len, *prompt_lens)
        })
        if self.paged:
            self.pages = _warmup_paged_pool(
                self._prefill_row_paged, self._decode_chunk_paged, self.params,
                self.cfg, self.pages, buckets=buckets,
                block_size=self.block_size, rows=self.max_slots,
                max_blocks_per_row=self.max_blocks_per_row,
                decode_chunk=self.decode_chunk,
                num_blocks=self.kv.pool.num_blocks,
                suffix_fn=(
                    self._suffix_row_paged if self.kv.prefix is not None else None
                ),
                piece_fn=self._piece_row_paged if self.prefill_chunk else None,
                prefill_chunk=self.prefill_chunk,
            )
            if self.speculative:
                self._warmup_verify()
            self._warm = True
            return
        tok = None
        for s in buckets:
            prompt = np.zeros((s,), np.int32)
            padded, lengths = _pad_to_bucket(
                prompt[None, :], self.max_len, self._bucketed
            )
            tok, self.cache = self._prefill_row(
                self.params, self.cache, jnp.asarray(padded), jnp.asarray(lengths),
                0, _zero_keys(1), _greedy_ops(1),
            )
        tokens = np.zeros((self.max_slots,), np.int32)
        keys = _zero_keys(self.max_slots)
        ops = _greedy_ops(self.max_slots)
        inactive = jnp.zeros((self.max_slots,), bool)  # rows stay frozen
        for n in _tail_sizes(self.decode_chunk):
            toks, self.cache = self._decode_chunk(
                self.params, self.cache, jnp.asarray(tokens), inactive, keys,
                ops, n
            )
        jax.block_until_ready(toks)
        # reset to a pristine cache: warmup must not leave row 0 populated
        self.cache = init_cache(self.cfg, self.max_slots, self.max_len)
        self._warm = True

    def _warmup_verify(self) -> None:
        """Precompile every verify scan length (k+1 for each warm k) so no
        XLA compile lands inside a virtual-timed verify round. Inactive rows
        write the trash block and keep their lengths frozen, so running on
        the live pool leaves it pristine."""
        R = self.max_slots
        V = self.cfg.vocab
        bt = jnp.zeros((R, self.max_blocks_per_row), jnp.int32)
        lengths = jnp.zeros((R,), jnp.int32)
        tokens = jnp.zeros((R,), jnp.int32)
        inactive = jnp.zeros((R,), bool)
        keys = jnp.asarray(_zero_keys(R))
        ops = _greedy_ops(R)
        last = None
        for k in _spec_k_sizes():
            out = self._verify_row_paged(
                self.params, self.pages, bt, lengths, tokens,
                jnp.zeros((k, R), jnp.int32),
                jnp.full((k, R, V), 1.0 / V, jnp.float32),
                inactive, keys, ops,
            )
            self.pages = out[5]
            last = out[0]
        if last is not None:
            jax.block_until_ready(last)

    # -- request lifecycle -------------------------------------------------

    def submit(self, req: Request, at: Optional[float] = None,
               verify: bool = False) -> int:
        """Enqueue one :class:`~repro.serving.request.Request`, arriving at
        virtual time ``at`` (defaults to ``max(clock, req.arrival)``).
        Admission order is deadline-aware (see class docstring); the
        request's ``slo.ttft_deadline`` anchors at the arrival time.

        ``verify=True`` (speculative servers only) admits the request in
        VERIFY mode: after its admission prefill it does not decode
        autonomously — its tokens land through :meth:`verify_step` rounds
        driven by a device draft stream. A verify rid preempted for memory
        silently reverts to plain decode on re-admission (``verify_step``
        returns ``None``; the driver falls back).

        The request's ``seed`` keys its sampling stream (defaults to the
        server-local rid) and its ``sampler`` (server default when None)
        becomes this row's runtime operands; both survive recompute
        preemption, so a preempted-then-replayed row regenerates exactly its
        pre-preemption continuation. Returns the server-local rid."""
        req = _require_request(req, "BatchedServer.submit")
        if verify and not self.speculative:
            raise ValueError("verify=True requires a speculative server")
        rid = self.next_id
        self.next_id += 1
        if verify:
            self._verify_requested.add(rid)
        arrive = max(self.clock, req.arrival) if at is None else float(at)
        # the TTFT deadline anchors at the CLIENT-side arrival: an explicit
        # network-adjusted ``at`` (the endpoint path: at = arrival + uplink)
        # means the user's deadline clock started at ``req.arrival``, not
        # when the submit landed — anchoring at ``arrive`` would inflate
        # every deadline (and EDF slack) by the uplink
        anchor = req.arrival if at is not None else arrive
        self.queue.append(_Queued(
            rid, req.prompt, req.max_new,
            seed=rid if req.seed is None else int(req.seed),
            sampler=req.sampler if req.sampler is not None else self.default_sampler,
            priority=req.priority,
            deadline=anchor + req.slo.ttft_deadline,
        ))
        self.submit_time[rid] = arrive
        self.events[rid] = deque()
        self.generated[rid] = 0
        if self.tracer.enabled:
            self.tracer.begin_request(
                rid, arrive, cat="server_request",
                args={"prompt_tokens": int(np.asarray(req.prompt).shape[0]),
                      "max_new": int(req.max_new), "verify": bool(verify)},
            )
            self.tracer.instant(
                "server/queue", "enqueue", arrive, cat="server",
                args={"rid": rid},
            )
        return rid

    def cancel(self, rid: int, at: Optional[float] = None) -> None:
        """Stop a request. With ``at=None`` the cancel is immediate: a queued
        request is dropped before admission; an active one frees its row —
        and, paged, its blocks — within the same tick (no drain, the cache
        just becomes garbage). With ``at=t`` the cancel models propagation
        latency: it takes effect only once the virtual clock reaches ``t``
        (one uplink RTT after the driver issued it), so a queued race loser
        can slip into prefill and waste blocks in the window — every token it
        generates meanwhile is counted in ``cancel_lag_tokens``."""
        if rid in self.completed or rid in self.cancelled:
            self._cancel_due.pop(rid, None)
            return
        if at is not None and at > self.clock:
            self._cancel_due[rid] = min(float(at), self._cancel_due.get(rid, math.inf))
            if self.tracer.enabled:
                self.tracer.request_instant(
                    rid, "cancel_scheduled", self.clock, cat="server_request",
                    args={"due": float(at)},
                )
            return
        self._cancel_due.pop(rid, None)
        self.cancelled.add(rid)
        self.verify_rids.discard(rid)
        self._verify_requested.discard(rid)
        self.kv_hold.discard(rid)         # cancelled: nothing left to hand off
        if rid in self.slots:
            slot = self.slots.pop(rid)
            row = self.rows.pop(rid)
            if self.paged:
                self.kv.release(
                    rid, cache_tokens=self._slot_cache_tokens(slot, row)
                )
            else:
                self._free_rows.append(row)
            self.completed[rid] = slot.tokens
            if self.tracer.enabled:
                self.tracer.end_request(
                    rid, self.clock, cat="server_request",
                    args={"outcome": "cancelled",
                          "generated": self.generated.get(rid, 0)},
                )
            return
        if rid in self._partial:
            # cancelled mid-prefill: no token was emitted yet, so the
            # delivered stream is just the input echo; the half-written
            # blocks are NOT registered in the prefix cache (their content
            # covers only the computed pieces)
            p = self._partial.pop(rid)
            self.rows.pop(rid)
            self.kv.release(rid)
            self.completed[rid] = list(p.item.tokens)
            if self.tracer.enabled:
                self.tracer.end_request(
                    rid, self.clock, cat="server_request",
                    args={"outcome": "cancelled",
                          "generated": self.generated.get(rid, 0)},
                )
            return
        for item in self.queue:
            if item.rid == rid:
                self.queue.remove(item)
                self.completed[rid] = list(item.tokens)
                if self.tracer.enabled:
                    self.tracer.end_request(
                        rid, self.clock, cat="server_request",
                        args={"outcome": "cancelled",
                              "generated": self.generated.get(rid, 0)},
                    )
                return

    def _apply_due_cancels(self) -> None:
        for rid, t in list(self._cancel_due.items()):
            if rid in self.completed or rid in self.cancelled:
                del self._cancel_due[rid]    # finished first: nothing to stop
            elif t <= self.clock:
                del self._cancel_due[rid]
                self.cancel(rid)

    def is_finished(self, rid: int) -> bool:
        """True once the rid can emit no further events."""
        if rid not in self.submit_time:
            raise ValueError(f"unknown request id {rid}")
        return rid in self.completed and not self.events[rid]

    def pop_events(self, rid: int) -> list:
        """Drain this request's undelivered ``(token, virtual_time)`` events."""
        q = self.events[rid]
        out = list(q)
        q.clear()
        return out

    # -- scheduler ticks ---------------------------------------------------

    def _slot_cache_tokens(self, slot: _Slot, row: int):
        """Token ids covering ``slot``'s written cache rows — what
        ``KVPoolManager.release`` registers in the prefix index. None when
        the cache is off (registration skipped)."""
        if not self.paged or self.kv.prefix is None:
            return None
        return np.concatenate(
            [slot.prompt, np.asarray(slot.tokens, np.int32)]
        )[:self.row_len[row]]

    def _retire_done(self) -> None:
        done = [
            rid
            for rid, slot in self.slots.items()
            if slot.remaining <= 0
            or self.row_len[self.rows[rid]] >= self.max_len - 1
        ]
        for rid in done:
            slot = self.slots.pop(rid)
            self.completed[rid] = slot.tokens
            self.verify_rids.discard(rid)
            self._verify_requested.discard(rid)
            row = self.rows.pop(rid)
            if self.paged:
                if rid in self.kv_hold:
                    # hand-off hold: the row frees for the next admission,
                    # but the blocks stay referenced until release_held —
                    # their contents are still crossing the interconnect
                    self.kv_hold.discard(rid)
                    self.held_tables[rid] = (
                        self.kv.detach(rid),
                        self._slot_cache_tokens(slot, row),
                    )
                else:
                    # blocks back to the pool; sealed blocks stay warm for
                    # the next shared-prefix admission
                    self.kv.release(
                        rid, cache_tokens=self._slot_cache_tokens(slot, row)
                    )
            else:
                self._free_rows.append(row)
            # an in-flight cancel for a finished request is moot: expunge it
            # so cancel_pending() cannot wedge the driver's finalize wait
            self._cancel_due.pop(rid, None)
            if self.tracer.enabled:
                self.tracer.end_request(
                    rid, self.clock, cat="server_request",
                    args={"outcome": "finished",
                          "generated": self.generated.get(rid, 0)},
                )

    def _queued_tokens(self, item: _Queued) -> np.ndarray:
        """The token sequence an admission of ``item`` prefills: the original
        prompt, plus already-emitted tokens for a preemption resume."""
        if item.tokens:
            return np.concatenate([item.prompt, np.asarray(item.tokens, np.int32)])
        return item.prompt

    def _head_arrival(self) -> Optional[float]:
        """Earliest virtual arrival among queued entries (idle-gap jumps)."""
        if not self.queue:
            return None
        return min(self.submit_time[q.rid] for q in self.queue)

    def _fifo_key(self, q: _Queued):
        # resumes outrank fresh admissions (they already held a row — the
        # old requeue-at-head semantics), then strict arrival order
        return (not q.resume, self.submit_time[q.rid], q.rid)

    def _edf_key(self, q: _Queued):
        # priority-tiered EDF by TTFT deadline: resume > tier > earliest
        # absolute deadline (== max slack at any common estimate) > FIFO.
        # An EXPIRED deadline is demoted to "no deadline" (inf): that first
        # token can no longer land in time, so urgency-ordering it would
        # sacrifice salvageable requests to a lost cause — the classic EDF
        # overload domino. Demotion makes overloaded EDF degrade toward
        # FIFO instead of below it.
        deadline = q.deadline if q.deadline >= self.clock else math.inf
        return (not q.resume, q.priority, deadline,
                self.submit_time[q.rid], q.rid)

    def _pick(self) -> tuple[Optional[_Queued], bool]:
        """(entry, reordered): the queue entry the next admission tick would
        take — the deadline-aware (or FIFO) minimum over entries that have
        ARRIVED (un-arrived entries never jump the clock) — and whether the
        deadline-aware pick differs from strict FIFO order. One queue scan;
        the two min() passes run on the (short) arrived slice only."""
        arrived = [q for q in self.queue if self.submit_time[q.rid] <= self.clock]
        if not arrived:
            return None, False
        fifo_first = min(arrived, key=self._fifo_key)
        if self.admission == "fifo":
            return fifo_first, False
        item = min(arrived, key=self._edf_key)
        return item, item is not fifo_first

    def _admissible(self) -> bool:
        """Admission test for the deadline-aware head: a free row AND —
        paged — the prefill's block demand fitting the free pool. A head
        blocked on memory alone is recorded in ``kv.memory_waits`` (the
        benchmark's queued-on-memory signal). Only the selected head is
        tested: admission keeps head-of-line blocking semantics, so memory
        pressure still queues requests rather than being skipped around."""
        item, _ = self._pick()
        if item is None:
            return False
        if not self.paged:
            return bool(self._free_rows)
        if not self.kv.has_free_row:
            return False
        full = self._queued_tokens(item)
        full_len = int(full.shape[0])
        padded_len = _bucket_len(full_len, self.max_len) if self._bucketed else full_len
        # a cached-prefix hit shrinks the demand to the unmatched suffix:
        # shared blocks are counted once (no phantom queued_on_memory).
        # Side-effect-free probe here; _admit_one re-queries with recording.
        matched = self.kv.prefix_match(full, record=False)
        demand = self.kv.prefill_demand(padded_len, full_len) - len(matched)
        return self.kv.can_admit(demand, item.rid, prefix_blocks=matched)

    def _admit_one(self) -> None:
        """Admission tick: prefill ONE queued request into a free row (and,
        paged, into freshly allocated blocks). The measured prefill
        wall-clock advances the virtual clock; the prompt's first token lands
        at the new clock. A preemption-resume entry re-prefills
        prompt + emitted tokens and continues where it left off."""
        item, reordered = self._pick()
        assert item is not None               # guarded by _admissible
        if reordered:
            self.deadline_reorders += 1
            if self.tracer.enabled:
                self.tracer.instant(
                    "server/queue", "deadline_reorder", self.clock,
                    cat="server", args={"rid": item.rid},
                )
        self.queue.remove(item)
        rid = item.rid
        full = self._queued_tokens(item)
        s = int(full.shape[0])
        padded, lengths = _pad_to_bucket(
            full[None, :], self.max_len, self._bucketed
        )
        key = _request_keys([item.seed])      # derived, not timed compute
        ops = sampler_operands([item.sampler])
        first_admission = rid not in self.first_token_time
        t_admit = self.clock                  # admission start (queue wait end)
        n_hit = 0
        stalled = bool(self._decodable())     # rows this prefill will stall
        if self.paged and self.prefill_chunk:
            sb = int(padded.shape[1])
            # prefix-hit admissions keep the monolithic suffix path (the hit
            # already shrinks the work and its suffix length is not piece-
            # aligned); cold long prompts go piecewise
            if sb > self.prefill_chunk and not self.kv.prefix_match(
                full, record=False
            ):
                table = self.kv.admit(
                    rid, self.kv.prefill_demand(sb, s), num_tokens=s,
                    prefix_blocks=[],
                )
                assert table is not None      # guarded by _admissible
                self.block_tables[table.row] = table.padded(
                    self.max_blocks_per_row
                )
                self.rows[rid] = table.row
                self.admit_seq[rid] = self._admit_counter
                self._admit_counter += 1
                self._partial[rid] = _Partial(
                    item=item, row=table.row, table=table, padded=padded,
                    lengths=lengths, s=s, sb=sb, key=key, ops=ops,
                    t_admit=t_admit,
                )
                self._piece_tick(rid)         # first piece, same tick
                return
        t0 = time.perf_counter()
        if self.paged:
            sb = int(padded.shape[1])
            matched = self.kv.prefix_match(full)   # [] when cache disabled
            n_hit = len(matched)
            table = self.kv.admit(
                rid, self.kv.prefill_demand(sb, s) - n_hit, num_tokens=s,
                prefix_blocks=matched,
            )
            assert table is not None          # guarded by _admissible
            row = table.row
            nb = sb // self.block_size
            if n_hit:
                # suffix-only prefill over the unmatched tail; the matched
                # blocks ride into the page table as read-only aliases
                tok, self.pages = self._suffix_row_paged(
                    self.params, self.pages,
                    jnp.asarray(padded[:, n_hit * self.block_size:], jnp.int32),
                    jnp.asarray(lengths), jnp.asarray([matched], jnp.int32),
                    jnp.asarray(table.blocks[n_hit:nb], jnp.int32),
                    jnp.asarray(key), ops,
                )
            else:
                tok, self.pages = self._prefill_row_paged(
                    self.params, self.pages, jnp.asarray(padded, jnp.int32),
                    jnp.asarray(lengths), jnp.asarray(table.blocks[:nb], jnp.int32),
                    jnp.asarray(key), ops,
                )
            # np conversion: jax-indexing tok[0] would jit-compile tiny
            # slice/squeeze executables on first use — a one-time ~tens-of-ms
            # cost that would land INSIDE this measured admission region and
            # inflate the first-admitted request's TTFT
            tok = int(np.asarray(jax.block_until_ready(tok))[0])
            self.block_tables[row] = table.padded(self.max_blocks_per_row)
            self.prefill_tokens_computed += sb - n_hit * self.block_size
            self.prefill_tokens_admitted += s
        else:
            row = self._free_rows.pop()
            tok, self.cache = self._prefill_row(
                self.params, self.cache, jnp.asarray(padded),
                jnp.asarray(lengths), row, jnp.asarray(key), ops,
            )
            tok = int(jax.block_until_ready(tok))
        dur = time.perf_counter() - t0
        self.clock += dur
        if stalled:
            # decode-ready rows sat frozen for the whole monolithic prefill:
            # this is exactly the interference chunked prefill bounds
            self.metrics.histogram("decode_stall_s").observe(dur)
        self.first_token_time.setdefault(rid, self.clock)  # resume keeps TTFT
        if first_admission and self.clock > item.deadline:
            self.slo_misses += 1              # first token past its deadline
            if self.tracer.enabled:
                self.tracer.instant(
                    "server/queue", "slo_miss", self.clock, cat="server",
                    args={"rid": rid},
                )
        self.events[rid].append((tok, self.clock))
        self.generated[rid] += 1
        if rid in self._cancel_due:
            self.cancel_lag_tokens += 1       # loser slipped into prefill
            if self.tracer.enabled:
                self.tracer.instant(
                    "server/queue", "cancel_lag", self.clock, cat="server",
                    args={"rid": rid, "n": 1},
                )
        self.metrics.histogram("queue_wait_s").observe(
            t_admit - self.submit_time[rid]
        )
        if self.tracer.enabled:
            self.tracer.span(
                f"server/row{row}", "prefill", t_admit, self.clock,
                cat="server",
                args={
                    "rid": rid,
                    "resume": item.resume,
                    "tokens_admitted": s,
                    "tokens_computed": int(padded.shape[1]) - n_hit * (
                        self.block_size if self.paged else 0
                    ),
                    "prefix_hit_blocks": n_hit,
                    "queue_wait_s": t_admit - self.submit_time[rid],
                    "decode_stall_s": dur if stalled else 0.0,
                },
            )
            self.tracer.request_instant(
                rid, "admitted", self.clock, cat="server_request",
                args={"row": row, "resume": item.resume},
            )
        self.admit_seq[rid] = self._admit_counter
        self._admit_counter += 1
        self.slots[rid] = _Slot(
            rid, item.max_new - 1, list(item.tokens) + [tok], prompt=item.prompt,
            seed=item.seed, key=key[0], sampler=item.sampler,
            deadline=item.deadline,
        )
        self.rows[rid] = row
        self.row_len[row] = s
        if rid in self._verify_requested:
            self.verify_rids.add(rid)

    # -- chunked prefill (piece ticks between decode chunks) ---------------

    def _piece_pick(self) -> int:
        """EDF over half-prefilled prompts: earliest unexpired TTFT deadline
        first (expired deadlines demote to inf — same overload rule as
        ``_edf_key``), admission order as the tie-break."""
        def key(rid):
            d = self._partial[rid].item.deadline
            return (d if d >= self.clock else math.inf, self.admit_seq[rid])
        return min(self._partial, key=key)

    def _partial_urgent(self) -> bool:
        """Starvation bound for interleaved pieces: True when the most
        urgent partial could miss its TTFT deadline unless its remaining
        pieces run consecutively from now on. Estimated with the running
        piece-duration EWMA plus one piece of slack; chunking then degrades
        to back-to-back pieces — exactly the monolithic schedule — so EDF
        admission never loses a deadline it would have met unchunked."""
        if not self._partial:
            return False
        p = self._partial[self._piece_pick()]
        d = p.item.deadline
        if not (self.clock <= d < math.inf):
            return False
        remaining = -(-(p.s - p.n_done) // self.prefill_chunk)
        return self.clock + (remaining + 1) * (self._piece_ewma or 0.0) >= d

    def _piece_due(self) -> bool:
        """A piece runs next when the last tick was a decode chunk (strict
        1:1 interleave keeps decode TBT bounded by ONE piece) or a partial
        is about to miss its deadline."""
        return self._piece_turn or self._partial_urgent()

    def _piece_tick(self, rid: Optional[int] = None) -> None:
        """Run ONE prefill piece for a half-prefilled prompt: an
        incremental dispatch at absolute positions ``n_done ..
        n_done + prefill_chunk`` appending K/V into the prompt's reserved
        blocks (``paged_piece_prefill``). The final piece samples the first
        token — logits are bitwise-identical to a monolithic prefill, so
        chunking is invisible to the stream — and promotes the partial to a
        decode slot."""
        if rid is None:
            rid = self._piece_pick()
        p = self._partial[rid]
        piece = self.prefill_chunk
        n_pre = p.n_done
        idx = n_pre // piece
        stalled = bool(self._decodable())     # rows frozen for this piece
        nb = p.sb // self.block_size
        t_start = self.clock
        t0 = time.perf_counter()
        tok, self.pages = self._piece_row_paged(
            self.params, self.pages,
            jnp.asarray(p.padded[:, n_pre:n_pre + piece], jnp.int32),
            jnp.asarray(p.lengths),
            jnp.asarray([p.table.blocks[:nb]], jnp.int32),
            jnp.asarray(n_pre, jnp.int32),
            jnp.asarray(
                p.table.blocks[
                    n_pre // self.block_size:(n_pre + piece) // self.block_size
                ],
                jnp.int32,
            ),
            jnp.asarray(p.key), p.ops,
        )
        tok = int(np.asarray(jax.block_until_ready(tok))[0])
        dur = time.perf_counter() - t0
        self.clock = t_start + dur
        self._piece_turn = False
        self._piece_ewma = (
            dur if self._piece_ewma is None
            else 0.5 * (self._piece_ewma + dur)
        )
        p.n_done += piece
        # stop at the piece containing the true last position s-1: bucket
        # padding beyond it is never attended (the decode write path
        # overwrites those positions before any query can reach them), so
        # pure-padding pieces are skipped — chunked prefill computes
        # ceil(s/piece)*piece tokens where monolithic computes the bucket
        final = p.n_done >= p.s
        self.prefill_tokens_computed += piece
        if stalled:
            self.metrics.histogram("decode_stall_s").observe(dur)
        if idx == 0:
            self.metrics.histogram("queue_wait_s").observe(
                p.t_admit - self.submit_time[rid]
            )
        if self.tracer.enabled:
            args = {
                "rid": rid,
                "resume": p.item.resume,
                "piece": idx,
                "n_pieces": -(-p.s // piece),
                "tokens_admitted": p.s if final else 0,
                "tokens_computed": piece,
                "prefix_hit_blocks": 0,
                "decode_stall_s": dur if stalled else 0.0,
            }
            if idx == 0:
                args["queue_wait_s"] = p.t_admit - self.submit_time[rid]
            self.tracer.span(
                f"server/row{p.row}", "prefill", t_start, self.clock,
                cat="server", args=args,
            )
        if not final:
            return
        # final piece: first token lands now — promote to a decode slot
        del self._partial[rid]
        self.prefill_tokens_admitted += p.s
        first_admission = rid not in self.first_token_time
        self.first_token_time.setdefault(rid, self.clock)
        if first_admission and self.clock > p.item.deadline:
            self.slo_misses += 1
            if self.tracer.enabled:
                self.tracer.instant(
                    "server/queue", "slo_miss", self.clock, cat="server",
                    args={"rid": rid},
                )
        self.events[rid].append((tok, self.clock))
        self.generated[rid] += 1
        if rid in self._cancel_due:
            self.cancel_lag_tokens += 1
            if self.tracer.enabled:
                self.tracer.instant(
                    "server/queue", "cancel_lag", self.clock, cat="server",
                    args={"rid": rid, "n": 1},
                )
        if self.tracer.enabled:
            self.tracer.request_instant(
                rid, "admitted", self.clock, cat="server_request",
                args={"row": p.row, "resume": p.item.resume},
            )
        self.slots[rid] = _Slot(
            rid, p.item.max_new - 1, list(p.item.tokens) + [tok],
            prompt=p.item.prompt, seed=p.item.seed, key=p.key[0],
            sampler=p.item.sampler, deadline=p.item.deadline,
        )
        self.row_len[p.row] = p.s
        if rid in self._verify_requested:
            self.verify_rids.add(rid)

    def _preempt_partial(self, rid: int) -> None:
        """Recompute preemption of a half-prefilled prompt: free its blocks
        and requeue it as a resume entry. Lossless by construction — no
        token was sampled yet, so the requeued item is the original request
        and re-admission simply prefills from scratch (possibly hitting the
        prefix cache on other requests' sealed blocks)."""
        p = self._partial.pop(rid)
        self.rows.pop(rid)
        self.kv.release(rid)              # partial content: never registered
        self.kv.preemptions += 1
        if self.tracer.enabled:
            self.tracer.instant(
                "server/queue", "preempt", self.clock, cat="server",
                args={"rid": rid, "generated": self.generated.get(rid, 0)},
            )
            self.tracer.request_instant(
                rid, "preempted", self.clock, cat="server_request",
                args={"generated": self.generated.get(rid, 0)},
            )
        p.item.resume = True
        self.queue.insert(0, p.item)

    # -- paged capacity (extend-on-decode + recompute preemption) ----------

    def _preempt(self, rid: int) -> None:
        """vLLM-style recompute preemption: free the victim's blocks and row
        and requeue it as a ``resume`` entry (resumes outrank every fresh
        admission in both admission modes) with its emitted tokens AND its
        deadline (the SLO contract survives preemption); re-admission
        replays prompt + tokens (lossless for greedy argmax AND for the
        position-keyed sampler, which reuses the request's seed and sampler
        config on resume — and, with the prefix cache on, usually a prefix
        HIT on its own just-registered blocks, so the recompute shrinks to
        the unsealed tail). Its TTFT and delivered events are unaffected."""
        slot = self.slots.pop(rid)
        row = self.rows.pop(rid)
        # a preempted verify rid reverts to plain decode on re-admission:
        # its driver sees verify_step -> None and falls back losslessly
        # (replayable sampling makes the resumed continuation identical)
        self.verify_rids.discard(rid)
        self._verify_requested.discard(rid)
        self.kv.release(rid, cache_tokens=self._slot_cache_tokens(slot, row))
        self.kv.preemptions += 1
        if self.tracer.enabled:
            self.tracer.instant(
                "server/queue", "preempt", self.clock, cat="server",
                args={"rid": rid, "generated": self.generated.get(rid, 0)},
            )
            self.tracer.request_instant(
                rid, "preempted", self.clock, cat="server_request",
                args={"generated": self.generated.get(rid, 0)},
            )
        self.queue.insert(0, _Queued(
            rid, slot.prompt, slot.remaining, list(slot.tokens),
            seed=slot.seed, sampler=slot.sampler, deadline=slot.deadline,
            resume=True,
        ))

    def _preempt_victim(self) -> int:
        """SLO-aware victim selection: evict the most RELAXED row — latest
        absolute TTFT deadline first (inf for un-SLO'd requests), newest
        admission as the tie-break. With no deadlines in play every row ties
        at inf and this degrades exactly to the old newest-admitted-first
        policy; with deadlines, a tight-deadline row survives pool pressure
        that evicts a relaxed one. Half-prefilled prompts compete under the
        same key (their preemption is the cheapest of all: no sampled token
        to replay)."""
        def key(r):
            if r in self.slots:
                return (self.slots[r].deadline, self.admit_seq[r])
            return (self._partial[r].item.deadline, self.admit_seq[r])
        return max(list(self.slots) + list(self._partial), key=key)

    def _ensure_block_capacity(self, need: dict) -> None:
        """Extend every active row's page table to cover its share of the
        coming chunk, oldest admission first; when the pool runs dry (after
        LRU-evicting cached prefixes), preempt the most relaxed-deadline
        request and retry."""
        for rid in sorted(need, key=lambda r: self.admit_seq[r]):
            if rid not in self.slots:
                continue                      # preempted by an older row
            row = self.rows[rid]
            while not self.kv.extend(rid, self.row_len[row] + need[rid]):
                victim = self._preempt_victim()
                if victim != rid:
                    if victim in self._partial:
                        self._preempt_partial(victim)
                    else:
                        self._preempt(victim)
                    continue
                if len(self.slots) > 1:
                    self._preempt(rid)        # rid itself is the most relaxed
                else:
                    # unreachable with num_blocks >= max_blocks_per_row + 1
                    # (ctor-enforced); cap defensively instead of looping
                    cap = self.kv.tables[rid].capacity * self.block_size
                    need[rid] = max(0, min(need[rid], cap - self.row_len[row]))
                break

    def _decodable(self) -> list[int]:
        """Active rids the decode tick drives: verify rids are excluded —
        their tokens land through ``verify_step`` rounds, and letting them
        spin zero-work decode ticks would inflate the virtual clock."""
        return [rid for rid in self.slots if rid not in self.verify_rids]

    def _decode_tick(self) -> None:
        """Decode tick: one fused chunk for all active rows (single dispatch
        + host sync). Per-token virtual times are interpolated across the
        measured chunk interval. Paged mode first secures block capacity for
        the chunk (possibly preempting the newest rows)."""
        need = {
            rid: min(
                self.decode_chunk,
                self.slots[rid].remaining,
                max(0, (self.max_len - 1) - self.row_len[self.rows[rid]]),
            )
            for rid in self._decodable()
        }
        if not need:
            return
        if self.paged:
            self._ensure_block_capacity(need)
            if not self.slots:
                return
            need = {rid: n for rid, n in need.items() if rid in self.slots}
            if not need:
                return
            for rid in self.slots:        # tables may have grown (or moved)
                self.block_tables[self.rows[rid]] = self.kv.tables[rid].padded(
                    self.max_blocks_per_row
                )
        tokens = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        keys = np.zeros((self.max_slots, 2), np.uint32)
        row_samplers = [None] * self.max_slots
        for rid in need:
            slot = self.slots[rid]
            row = self.rows[rid]
            tokens[row] = slot.tokens[-1]
            active[row] = True
            if slot.key is not None:
                keys[row] = slot.key
            row_samplers[row] = slot.sampler
        # per-row sampler operands: heterogeneous request configs share the
        # one fused dispatch (free rows stay greedy-frozen)
        ops = sampler_operands(row_samplers)
        # cap the scan at the largest per-row need (rounded to a warm tail
        # size) so request tails don't pay for discarded decode steps
        num_steps = _tail_steps(max(need.values()), self.decode_chunk)
        t_start = self.clock
        t0 = time.perf_counter()
        if self.paged:
            toks, self.pages, _ = self._decode_chunk_paged(
                self.params, self.pages, jnp.asarray(self.block_tables),
                jnp.asarray(np.asarray(self.row_len, np.int32)),
                jnp.asarray(tokens), jnp.asarray(active), jnp.asarray(keys),
                ops, num_steps,
            )
        else:
            toks, self.cache = self._decode_chunk(
                self.params, self.cache, jnp.asarray(tokens), jnp.asarray(active),
                jnp.asarray(keys), ops, num_steps,
            )
        toks = np.asarray(jax.block_until_ready(toks))   # (num_steps, max_slots)
        dur = time.perf_counter() - t0
        self.clock = t_start + dur
        self._piece_turn = True          # 1:1 interleave with prefill pieces
        for rid in need:
            slot = self.slots[rid]
            row = self.rows[rid]
            n_valid = need[rid]
            for i in range(n_valid):
                tok = int(toks[i, row])
                slot.tokens.append(tok)
                self.events[rid].append(
                    (tok, t_start + (i + 1) * dur / num_steps)
                )
            slot.remaining -= n_valid
            self.row_len[row] += n_valid
            self.generated[rid] += n_valid
            if n_valid and rid in self._cancel_due:
                self.cancel_lag_tokens += n_valid
                if self.tracer.enabled:
                    self.tracer.instant(
                        "server/queue", "cancel_lag", self.clock, cat="server",
                        args={"rid": rid, "n": n_valid},
                    )
            self.decode_dispatches[rid] = self.decode_dispatches.get(rid, 0) + 1
            if self.tracer.enabled and n_valid:
                self.tracer.span(
                    f"server/row{row}", "decode", t_start, self.clock,
                    cat="server", args={"rid": rid, "tokens": n_valid},
                )

    # -- speculative verify rounds (server half of draft/verify) -----------

    def verify_step(self, rid: int, drafts, device_probs,
                    at: Optional[float] = None):
        """One draft→verify round for a verify rid: score the drafts (plus
        one bonus position) in a single fused dispatch, accept a lossless
        prefix by rejection sampling (``models.sampling.speculative_accept``)
        and deliver ``accepted + 1`` tokens — the accepted drafts and either
        the residual correction (on a rejection) or the server's own bonus
        sample (on a full accept). The rejected KV tail is rewound within the
        same tick (``kv.shrink``): block demand is charged for accepted
        tokens only.

        ``drafts``: list of k draft token ids; ``device_probs``: (k, vocab)
        device sampling distributions for them. k is floored to a warm power
        of two (extra drafts are ignored, not scored). Returns a dict with
        ``accepted`` (drafts kept), ``k`` (drafts scored), ``tokens`` (the
        committed tokens, ``accepted + 1`` of them), and ``t_start``/
        ``t_end`` virtual bounds — or ``None`` when the round cannot run
        (rid finished, cancelled, preempted, saturated, or out of blocks):
        the driver must ``end_verify`` and fall back to plain decode.

        ``at`` is the virtual arrival time of the drafts (the device's
        draft-completion time plus the uplink): the round starts no
        earlier, mirroring ``submit(at=...)``."""
        if at is not None:
            self.clock = max(self.clock, float(at))
        self._apply_due_cancels()
        self._retire_done()
        if rid not in self.slots or rid not in self.verify_rids:
            return None
        slot = self.slots[rid]
        row = self.rows[rid]
        L = self.row_len[row]
        # the scan writes k+1 entries from L (forced last token + k drafts)
        # and must stay under max_len - 1; committing up to k+1 tokens must
        # fit the request's remaining budget
        k = _spec_k_floor(min(len(drafts), (self.max_len - 2) - L,
                              slot.remaining - 1))
        if k < 1:
            return None
        self._ensure_block_capacity({rid: k + 1})
        if rid not in self.slots:
            return None                   # rid itself was the preempt victim
        self.block_tables[row] = self.kv.tables[rid].padded(
            self.max_blocks_per_row
        )
        V = self.cfg.vocab
        tokens = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        keys = np.zeros((self.max_slots, 2), np.uint32)
        row_samplers = [None] * self.max_slots
        tokens[row] = slot.tokens[-1]
        active[row] = True
        if slot.key is not None:
            keys[row] = slot.key
        row_samplers[row] = slot.sampler
        ops = sampler_operands(row_samplers)
        drafts_arr = np.zeros((k, self.max_slots), np.int32)
        drafts_arr[:, row] = np.asarray(drafts[:k], np.int32)
        # frozen rows still flow through the acceptance math: a uniform fill
        # keeps their (discarded) verdicts finite
        dev_probs = np.full((k, self.max_slots, V), 1.0 / V, np.float32)
        dev_probs[:, row, :] = np.asarray(device_probs[:k], np.float32)
        t_start = self.clock
        t0 = time.perf_counter()
        n_acc, _, corrections, srv_toks, _, self.pages, _ = (
            self._verify_row_paged(
                self.params, self.pages, jnp.asarray(self.block_tables),
                jnp.asarray(np.asarray(self.row_len, np.int32)),
                jnp.asarray(tokens), jnp.asarray(drafts_arr),
                jnp.asarray(dev_probs), jnp.asarray(active),
                jnp.asarray(keys), ops,
            )
        )
        a = int(np.asarray(jax.block_until_ready(n_acc))[row])
        dur = time.perf_counter() - t0
        self.clock = t_start + dur
        if a < k:
            out = [int(t) for t in drafts[:a]]
            out.append(int(np.asarray(corrections)[row, a]))
        else:
            out = [int(t) for t in drafts[:k]]
            out.append(int(np.asarray(srv_toks)[k, row]))  # bonus sample
        # rewind: keep the forced token + accepted drafts, free the rest
        new_len = L + a + 1
        self.kv.shrink(rid, new_len)
        self.kv.tables[rid].num_tokens = new_len
        self.block_tables[row] = self.kv.tables[rid].padded(
            self.max_blocks_per_row
        )
        self.row_len[row] = new_len
        n_out = len(out)                  # a + 1
        slot.tokens.extend(out)
        slot.remaining -= n_out
        # all k+1 scored positions count as generated: the rejected tail is
        # server compute the race would also have wasted — wasted_ratio =
        # (generated - delivered) / generated keeps its meaning
        self.generated[rid] += k + 1
        self.verify_positions[rid] = self.verify_positions.get(rid, 0) + k + 1
        self.verify_rounds[rid] = self.verify_rounds.get(rid, 0) + 1
        self.accepted_tokens[rid] = self.accepted_tokens.get(rid, 0) + a
        self.decode_dispatches[rid] = self.decode_dispatches.get(rid, 0) + 1
        for i, tok in enumerate(out):
            self.events[rid].append((tok, t_start + (i + 1) * dur / n_out))
        if rid in self._cancel_due:
            self.cancel_lag_tokens += n_out
            if self.tracer.enabled:
                self.tracer.instant(
                    "server/queue", "cancel_lag", self.clock, cat="server",
                    args={"rid": rid, "n": n_out},
                )
        if self.tracer.enabled:
            self.tracer.span(
                f"server/row{row}", "verify", t_start, self.clock,
                cat="server",
                args={"rid": rid, "k": k, "accepted": a, "tokens": n_out},
            )
        self._retire_done()
        return {"accepted": a, "k": k, "tokens": out,
                "t_start": t_start, "t_end": self.clock}

    def end_verify(self, rid: int) -> None:
        """Convert a verify rid into a normal autonomous decode slot (driver
        fallback on acceptance collapse, device loss, or saturation): the
        next scheduler tick simply resumes fused decode from the committed
        state. No-op for unknown / finished rids."""
        self.verify_rids.discard(rid)
        self._verify_requested.discard(rid)

    def run_until(self, t_limit: float = math.inf) -> None:
        """Process ticks until the virtual clock passes ``t_limit`` or there
        is no work. The final tick may overshoot ``t_limit``: its chunk was
        already in flight when the horizon passed (cancellations land after
        it, which is exactly the paper's one-chunk cancellation latency)."""
        while self.clock < t_limit:
            self._apply_due_cancels()
            self._retire_done()
            head = self._head_arrival()
            if head is not None and head <= self.clock and self._admissible():
                self._admit_one()        # one row per tick, between chunks
                continue
            if self._partial and (self._piece_due() or not self._decodable()):
                self._piece_tick()       # one prefill piece between chunks
                continue
            if self._decodable():
                self._decode_tick()
                continue
            if head is None or head > t_limit:
                break                    # idle, or next arrival beyond horizon
            if head <= self.clock:
                # arrived head blocked on capacity with nothing decodable
                # (verify rids hold the rows): only driver-driven verify
                # rounds / end_verify can unblock it — don't spin
                break
            self.clock = head            # idle gap: jump to the next arrival
        self._apply_due_cancels()
        self._retire_done()

    def step(self) -> bool:
        """One scheduler tick (admission or decode chunk). Returns False when
        fully idle. Compatibility wrapper over the event-driven core; the
        clock only jumps over idle gaps, never past in-flight decode work."""
        self._apply_due_cancels()
        self._retire_done()
        head = self._head_arrival()
        if not self.slots and not self._partial and head is not None:
            self.clock = max(self.clock, head)   # idle gap: jump to arrival
            self._apply_due_cancels()
            head = self._head_arrival()          # a due cancel may drop the head
        if head is not None and head <= self.clock and self._admissible():
            self._admit_one()
        elif self._partial and (self._piece_due() or not self._decodable()):
            self._piece_tick()
        elif self._decodable():
            self._decode_tick()
        self._retire_done()
        return bool(self.slots or self.queue or self._partial)

    def run_to_completion(self) -> dict[int, list[int]]:
        self.run_until(math.inf)
        return self.completed

    # -- bookkeeping -------------------------------------------------------

    def cancel_pending(self, rid: int) -> bool:
        """True while an issued cancel for ``rid`` is still crossing the
        uplink (the request may still generate — and waste — tokens)."""
        return rid in self._cancel_due

    def release_held(self, rid: int, register_prefix: bool = True) -> None:
        """Drop a hand-off hold taken via ``kv_hold``: the detached table's
        blocks return to the pool (transfer landed, or the hand-off was
        cancelled mid-flight). ``register_prefix`` keeps the transferred
        prompt's sealed blocks warm in this worker's prefix index so sticky
        routing of shared-prefix requests keeps hitting."""
        held = self.held_tables.pop(rid, None)
        if held is not None:
            table, cache_tokens = held
            self.kv.release_detached(
                table, cache_tokens=cache_tokens if register_prefix else None
            )

    def adopt(self, prompt, tokens, max_new: int, *, seed: int,
              sampler: Optional[SamplerConfig] = None, priority: int = 0,
              deadline: float = math.inf,
              first_token_at: Optional[float] = None,
              at: Optional[float] = None,
              src_pages=None, src_table=None,
              num_tokens: Optional[int] = None) -> tuple[int, bool]:
        """Hand-off entry point for disaggregated prefill/decode serving:
        take over a request whose prefill (and first token, already
        delivered) ran on ANOTHER server, continuing its decode here.

        With ``src_pages``/``src_table`` from the prefill worker, the KV
        state is received into this pool (``KVPoolManager.receive``) and
        device-copied block-by-block; the request gets a live slot with NO
        compute — the next decode chunk continues bitwise-identically to a
        monolithic run, because sampling is position-keyed on ``seed`` and
        the copied cache covers exactly the prompt positions. When the pool
        cannot receive (rows or blocks exhausted), the request falls back to
        a lossless recompute: it queues as a replay-resume entry whose
        re-prefill of prompt + delivered tokens regenerates the identical
        continuation.

        ``tokens`` are the already-delivered tokens (not re-emitted here);
        ``max_new`` counts the tokens still to emit on this server;
        ``first_token_at`` back-fills ``first_token_time`` so TTFT/SLO
        accounting stays with the real first token. Returns
        ``(rid, adopted)`` — ``adopted`` False means the fallback path
        queued the request instead."""
        if not self.paged:
            raise ValueError("adopt requires a paged server")
        prompt = np.asarray(prompt, np.int32)
        rid = self.next_id
        self.next_id += 1
        arrive = self.clock if at is None else float(at)
        self.submit_time[rid] = arrive
        self.events[rid] = deque()
        self.generated[rid] = 0
        if first_token_at is not None:
            self.first_token_time[rid] = float(first_token_at)
        sampler = sampler if sampler is not None else self.default_sampler
        if self.tracer.enabled:
            self.tracer.begin_request(
                rid, arrive, cat="server_request",
                args={"prompt_tokens": int(prompt.shape[0]),
                      "max_new": int(max_new), "handoff": True},
            )
        got = None
        if src_table is not None and src_pages is not None:
            got = self.kv.receive(rid, src_table, num_tokens=num_tokens)
        if got is not None:
            table, pairs = got
            if pairs:
                src_ids, dst_ids = _pad_copy_pairs(pairs)
                self.pages = _xfer_pool_blocks(
                    src_pages, self.pages, src_ids, dst_ids
                )
                # sync here so the copy's host wall-clock is NOT absorbed
                # into the next decode chunk's measured time: on the
                # virtual timeline the transfer costs the modeled
                # interconnect delay (already paid by the caller), not the
                # simulator's gather/scatter time
                jax.block_until_ready(self.pages)
            self.clock = max(self.clock, arrive)
            row = table.row
            self.block_tables[row] = table.padded(self.max_blocks_per_row)
            key = _request_keys([seed])
            self.slots[rid] = _Slot(
                rid, max_new, list(tokens), prompt=prompt, seed=int(seed),
                key=key[0], sampler=sampler, deadline=deadline,
            )
            self.rows[rid] = row
            self.row_len[row] = table.num_tokens
            self.admit_seq[rid] = self._admit_counter
            self._admit_counter += 1
            if self.tracer.enabled:
                self.tracer.request_instant(
                    rid, "adopted", self.clock, cat="server_request",
                    args={"row": row, "blocks": len(pairs)},
                )
            return rid, True
        # recompute fallback: a replay-resume admission regenerates the
        # identical continuation from prompt + delivered tokens
        self.queue.append(_Queued(
            rid, prompt, int(max_new), tokens=list(tokens), seed=int(seed),
            sampler=sampler, priority=priority, deadline=deadline,
            resume=True,
        ))
        if self.tracer.enabled:
            self.tracer.request_instant(
                rid, "handoff_fallback", self.clock, cat="server_request",
                args={"tokens": len(tokens)},
            )
        return rid, False

    def load_snapshot(self) -> dict:
        """Router-facing load signals (cluster dispatch): queue depth
        (including half-prefilled prompts), active slots, free rows/blocks,
        and EDF headroom — the tightest unexpired TTFT-deadline slack among
        queued requests (``inf`` when nothing urgent is waiting)."""
        headroom = math.inf
        for q in self.queue:
            if q.deadline >= self.clock:
                headroom = min(headroom, q.deadline - self.clock)
        free_rows = len(self.kv._free_rows) if self.paged else len(self._free_rows)
        return {
            "queue_depth": len(self.queue) + len(self._partial),
            "active": len(self.slots),
            "free_rows": free_rows,
            "free_blocks": self.kv.pool.num_free if self.paged else free_rows,
            "total_blocks": (
                self.kv.pool.num_blocks - 1 if self.paged else self.max_slots
            ),
            "edf_headroom": headroom,
        }

    def prefix_probe(self, tokens) -> int:
        """Cached-prefix tokens this server could skip for ``tokens`` — the
        cluster router's sticky-placement signal. Side-effect free (no
        counters, no LRU touch); 0 when the prefix cache is off."""
        if not self.paged or self.kv.prefix is None:
            return 0
        full = np.asarray(tokens, np.int32)
        return len(self.kv.prefix_match(full, record=False)) * self.block_size

    def pool_stats(self) -> dict:
        """Memory-pressure + SLO accounting for the serving benchmark: peak
        blocks in use, how many rids ever queued on memory, recompute
        preemptions, tokens generated after their cancel was issued
        (propagation lag), first tokens that missed their TTFT deadline
        (``server_slo_misses``), and admissions where the deadline-aware
        order differed from FIFO (``deadline_reorders``). Dense servers
        report the non-paged subset.

        Implementation: one :class:`~repro.serving.telemetry.MetricsRegistry`
        snapshot — every number here is registry-backed (counters written at
        the event sites, derived values as views), so no stat is computed
        twice and trace-derived sums can be reconciled against this dict
        exactly (``telemetry.reconcile_trace``)."""
        return self.metrics.snapshot()

    def ttft(self, rid: int) -> Optional[float]:
        """Virtual-time TTFT. ``None`` for a request that was never admitted
        (still queued, or cancelled while queued); raises ``ValueError`` for
        an unknown rid instead of leaking a bare ``KeyError``."""
        if rid not in self.submit_time:
            raise ValueError(
                f"unknown request id {rid}: never submitted to this server"
            )
        if rid not in self.first_token_time:
            return None
        return self.first_token_time[rid] - self.submit_time[rid]
