"""Inference engine: jitted prefill / decode step functions + a
continuous-batching scheduler for batched request serving.

The engine is endpoint-agnostic: DiSCo's device and server endpoints each
wrap one ``InferenceEngine`` (different model sizes / latency envelopes).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig

__all__ = ["InferenceEngine", "GenerationResult", "BatchedServer"]


@dataclasses.dataclass
class GenerationResult:
    tokens: list[int]
    ttft: float                  # seconds (compute only; network added by endpoint)
    token_times: list[float]     # wall-clock time of each token, relative to start
    prefill_s: float
    decode_s_per_token: float


class InferenceEngine:
    """Single-model engine with jitted prefill/decode and greedy sampling."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len

        @jax.jit
        def _prefill(params, tokens):
            logits, cache = prefill(params, cfg, tokens, max_len)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        @jax.jit
        def _decode(params, cache, token):
            logits, cache = decode_step(params, cfg, cache, token)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._prefill = _prefill
        self._decode = _decode

    def warmup(self, batch: int = 1, prompt_len: int = 8) -> None:
        tok = jnp.zeros((batch, prompt_len), jnp.int32)
        t, cache = self._prefill(self.params, tok)
        self._decode(self.params, cache, t)

    def prefill(self, tokens: np.ndarray):
        """tokens: (B, S) int32. Returns (first_token (B,), cache)."""
        t, cache = self._prefill(self.params, jnp.asarray(tokens, jnp.int32))
        return np.asarray(jax.block_until_ready(t)), cache

    def decode(self, cache, token: np.ndarray):
        t, cache = self._decode(self.params, cache, jnp.asarray(token, jnp.int32))
        return np.asarray(jax.block_until_ready(t)), cache

    def generate(self, prompt: np.ndarray, max_new: int, replay: bool = False) -> GenerationResult:
        """Greedy generation for one prompt (1, S). Wall-clock timed."""
        t0 = time.perf_counter()
        tok, cache = self.prefill(prompt[None, :])
        t_first = time.perf_counter()
        tokens, times = [int(tok[0])], [t_first - t0]
        for _ in range(max_new - 1):
            if cache["lengths"][0] >= self.max_len - 1:
                break
            tok, cache = self.decode(cache, tok)
            tokens.append(int(tok[0]))
            times.append(time.perf_counter() - t0)
        n_dec = max(len(tokens) - 1, 1)
        return GenerationResult(
            tokens=tokens,
            ttft=t_first - t0,
            token_times=times,
            prefill_s=t_first - t0,
            decode_s_per_token=(times[-1] - times[0]) / n_dec,
        )

    def replay_then_continue(
        self, prompt: np.ndarray, generated: list[int], max_new: int
    ) -> tuple[float, "Iterator[int]"]:
        """Migration target path (§4.3): re-prefill prompt + received token IDs
        (no KV transfer), then continue decoding. Returns (replay_seconds,
        iterator of continuation tokens)."""
        t0 = time.perf_counter()
        full = np.concatenate([prompt, np.asarray(generated, np.int32)])
        tok, cache = self.prefill(full[None, :])
        replay_s = time.perf_counter() - t0

        def continuation():
            nonlocal tok, cache
            yield int(tok[0])
            for _ in range(max_new - 1):
                if cache["lengths"][0] >= self.max_len - 1:
                    return
                tok, cache2 = self.decode(cache, tok)
                cache = cache2
                yield int(tok[0])

        return replay_s, continuation()


# ---------------------------------------------------------------------------
# Continuous batching (server-side request batching, §2.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Slot:
    request_id: int
    remaining: int
    tokens: list


class BatchedServer:
    """Continuous-batching scheduler: one *batched* KV cache with per-row
    lengths; requests join free rows after prefill and all active rows share
    a single batched decode step.

    This models the server-side request batching the paper identifies as the
    source of TTFT tail latency (§2.3): arrivals beyond ``max_slots`` queue.
    """

    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len

        @jax.jit
        def _prefill_row(params, batched_cache, tokens, row):
            """Prefill (1, S) and write its cache into row ``row``."""
            logits, cache = prefill(params, cfg, tokens, max_len)
            new = {}
            for k, v in batched_cache.items():
                if k == "lengths":
                    new[k] = v.at[row].set(cache[k][0])
                else:
                    new[k] = v.at[:, row].set(cache[k][:, 0])
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[0], new

        @jax.jit
        def _decode_batch(params, cache, tokens, active):
            """Batched decode; inactive rows keep their cache untouched."""
            logits, new_cache = decode_step(params, cfg, cache, tokens)
            merged = {}
            for k, v in new_cache.items():
                old = cache[k]
                if k == "lengths":
                    merged[k] = jnp.where(active, v, old)
                else:  # cache arrays are (L, B, ...): broadcast over L and tails
                    mask = active.reshape((1, -1) + (1,) * (v.ndim - 2))
                    merged[k] = jnp.where(mask, v, old)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), merged

        self._prefill_row = _prefill_row
        self._decode_batch = _decode_batch
        self.cache = init_cache(cfg, max_slots, max_len)
        self.queue: deque = deque()
        self.slots: dict[int, _Slot] = {}
        self.rows: dict[int, int] = {}
        self.free_rows = list(range(max_slots))
        self.next_id = 0
        self.completed: dict[int, list[int]] = {}
        self.submit_time: dict[int, float] = {}
        self.first_token_time: dict[int, float] = {}

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        rid = self.next_id
        self.next_id += 1
        self.queue.append((rid, prompt, max_new))
        self.submit_time[rid] = time.perf_counter()
        return rid

    def _admit(self) -> None:
        while self.queue and self.free_rows:
            rid, prompt, max_new = self.queue.popleft()
            row = self.free_rows.pop()
            tok, self.cache = self._prefill_row(
                self.params, self.cache, jnp.asarray(prompt[None, :], jnp.int32),
                row,
            )
            jax.block_until_ready(tok)
            self.first_token_time[rid] = time.perf_counter()
            self.slots[rid] = _Slot(rid, max_new - 1, [int(tok)])
            self.rows[rid] = row

    def step(self) -> bool:
        """One scheduler tick: admit, batched-decode all active rows.
        Returns False when fully idle."""
        self._admit()
        if not self.slots:
            return False
        done = [
            rid
            for rid, slot in self.slots.items()
            if slot.remaining <= 0
            or int(self.cache["lengths"][self.rows[rid]]) >= self.max_len - 1
        ]
        for rid in done:
            self.completed[rid] = self.slots.pop(rid).tokens
            self.free_rows.append(self.rows.pop(rid))
        if not self.slots:
            return bool(self.queue)
        tokens = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        for rid, slot in self.slots.items():
            tokens[self.rows[rid]] = slot.tokens[-1]
            active[self.rows[rid]] = True
        toks, self.cache = self._decode_batch(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(active)
        )
        toks = np.asarray(jax.block_until_ready(toks))
        for rid, slot in self.slots.items():
            slot.tokens.append(int(toks[self.rows[rid]]))
            slot.remaining -= 1
        return True

    def run_to_completion(self) -> dict[int, list[int]]:
        while self.step() or self.queue:
            pass
        return self.completed

    def ttft(self, rid: int) -> float:
        return self.first_token_time[rid] - self.submit_time[rid]
