"""DiSCo endpoints over real JAX engines, composed with a virtual network.

Timing model (honest for a single-process CPU testbed): *compute* times are
real wall-clock measurements of the JAX engines; network RTT is sampled from
a configurable distribution and added to the timeline. Server queueing is NOT
sampled — it emerges from slot contention inside the shared
:class:`~repro.serving.engine.BatchedServer` (the §2.3 "high-load period"
tail). The scheduler only ever sees timestamps, exactly as in deployment.

Endpoints no longer materialize whole token lists. They open *incremental
token-event sources* that the DiSCo event loop pulls chunk-by-chunk on a
shared virtual timeline:

* ``DeviceTokenStream`` — a per-request dedicated engine (each user's own
  hardware): compute is dispatched lazily one fused chunk per pull, and the
  stream is *activated* (prefill dispatched) only once the event loop's
  virtual frontier reaches its start time, so a request resolved before the
  device would have started spends nothing on-device.
* ``ServerTokenStream`` — a handle onto one request id inside the shared
  contended ``BatchedServer``; token events carry the server's virtual
  timestamps plus the sampled downlink latency.

Both support ``cancel()``: the race loser stops after at most one in-flight
decode chunk instead of generating all ``max_new`` tokens — the source of
the paper's up-to-84% cost saving (§4.2).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.cost import Endpoint

from .engine import BatchedServer, EngineStream, InferenceEngine
from .request import Request
from .telemetry import NULL_TRACER

__all__ = [
    "NetworkModel",
    "TokenEvent",
    "DeviceTokenStream",
    "DeviceDraftSession",
    "ServerTokenStream",
    "DeviceEndpoint",
    "ServerEndpoint",
]


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    token: int
    t: float          # absolute virtual-timeline seconds
    endpoint: Endpoint


@dataclasses.dataclass
class NetworkModel:
    """Link model: round-trip time only. Queueing delay is no longer sampled
    here — it emerges from ``BatchedServer`` slot contention."""

    rtt_mean: float = 0.04
    rtt_jitter: float = 0.01

    def sample_rtt(self, rng: np.random.Generator) -> float:
        return max(self.rtt_mean + rng.normal(0.0, self.rtt_jitter), 0.001)


class DeviceTokenStream:
    """Incremental token-event source over a dedicated (per-user) engine.

    Pull-driven: ``peek``/``pop`` dispatch at most one fused decode chunk
    beyond the last consumed event, so the stream never runs ahead of the
    event loop's virtual frontier by more than one chunk. ``candidate_time``
    returns the stream's next known event time without dispatching anything
    before activation: an un-activated stream's candidate is its virtual
    start time (prefill begins only when the frontier reaches it).
    """

    pull_driven = True

    def __init__(self, source: EngineStream, start_at: float, kind: Endpoint,
                 tracer=NULL_TRACER, track: str = "device/req?",
                 rid: Optional[int] = None):
        self._src = source
        self.start_at = float(start_at)
        self.kind = kind
        self._buf: deque[TokenEvent] = deque()
        self.tracer = tracer
        self._track = track               # e.g. device/req3, device/req3:replay
        self._rid = rid                   # driver-level rid (trace join key)
        self._t_mark = 0.0                # last traced chunk end (relative)

    # -- lifecycle ---------------------------------------------------------

    @property
    def activated(self) -> bool:
        return self._src.prefilled or self._src.cancelled

    def activate(self) -> None:
        """Dispatch the prefill (the first pull). Idempotent."""
        self._fill()

    @property
    def done(self) -> bool:
        return not self._buf and self._src.done

    def cancel(self, at: Optional[float] = None) -> None:
        """Local cancellation is instantaneous (no network hop): ``at`` is
        accepted for interface symmetry with the server stream and ignored."""
        self._src.cancel()
        self._buf.clear()

    # -- event access ------------------------------------------------------

    def _fill(self) -> None:
        while not self._buf and not self._src.done:
            was_prefilled = self._src.prefilled
            nxt = self._src.next_chunk()
            if nxt is None:
                return
            tokens, times = nxt
            if self.tracer.enabled and len(times):
                self.tracer.span(
                    self._track,
                    "decode" if was_prefilled else "prefill",
                    self.start_at + self._t_mark, self.start_at + times[-1],
                    cat="device", args={"rid": self._rid, "tokens": len(tokens)},
                )
                self._t_mark = times[-1]
            for tok, t in zip(tokens, times):
                self._buf.append(TokenEvent(tok, self.start_at + t, self.kind))

    def candidate_time(self) -> Optional[float]:
        if self._buf:
            return self._buf[0].t
        if self._src.done:
            return None
        if not self.activated:
            return self.start_at          # activation event: nothing dispatched
        self._fill()
        return self._buf[0].t if self._buf else None

    def peek(self) -> Optional[TokenEvent]:
        self._fill()
        return self._buf[0] if self._buf else None

    def pop(self) -> TokenEvent:
        ev = self.peek()
        if ev is None:
            raise RuntimeError("pop() on an exhausted stream")
        self._buf.popleft()
        return ev

    # -- accounting --------------------------------------------------------

    @property
    def prefilled(self) -> bool:
        return self._src.prefilled

    @property
    def prefill_tokens(self) -> int:
        return int(self._src._prompt.shape[0])

    @property
    def tokens_generated(self) -> int:
        return self._src.tokens_emitted

    @property
    def decode_dispatches(self) -> int:
        return self._src.decode_dispatches


class DeviceDraftSession:
    """Device half of speculative decoding (draft/verify mode): fused draft
    windows on the user's dedicated engine, with a device-local virtual
    clock.

    Unlike :class:`DeviceTokenStream`, this session delivers nothing itself
    — every committed token reaches the user through the server's verify
    stream (one delivery path, one QoE series). The session's virtual
    frontier ``t`` advances by each window's measured compute and by the
    driver's ``not_before`` round-trip bounds (a window cannot start before
    the previous verdict crossed the downlink)."""

    kind = Endpoint.DEVICE

    def __init__(self, source: EngineStream, start_at: float,
                 tracer=NULL_TRACER, rid: Optional[int] = None):
        self._src = source
        self.t = float(start_at)          # device-local virtual frontier
        self.prefill_s: Optional[float] = None
        self.tracer = tracer
        self._rid = rid
        self._track = f"device/req{rid}" if rid is not None else "device/draft"

    def prefill(self) -> tuple[int, float]:
        """Dispatch the draft-mode prefill. Returns ``(token, t_done)`` —
        the device's own position-S draw (normally resynced away via
        :meth:`force_pending`) and the virtual completion time."""
        tok0, dur = self._src.draft_prefill()
        self.prefill_s = dur
        t0 = self.t
        self.t += dur
        if self.tracer.enabled:
            self.tracer.span(
                self._track, "draft_prefill", t0, self.t, cat="device",
                args={"rid": self._rid},
            )
        return tok0, self.t

    def force_pending(self, tok: int) -> None:
        """Resync the pending chain onto the server's committed token."""
        self._src.force_pending(tok)

    def draft_window(self, k: int, not_before: Optional[float] = None):
        """Dispatch one draft window. Returns ``(drafts, device_probs,
        t_done)`` — the draft tokens, their device sampling distributions,
        and the virtual time the window's compute finishes — or ``None``
        when the device cannot draft (saturated / pool exhausted)."""
        if not_before is not None:
            self.wait_until(float(not_before))
        w = self._src.draft_window(k)
        if w is None:
            return None
        drafts, probs, dur = w
        t0 = self.t
        self.t += dur
        if self.tracer.enabled:
            self.tracer.span(
                self._track, "draft", t0, self.t, cat="device",
                args={"rid": self._rid, "k": len(drafts)},
            )
        return drafts, probs, self.t

    def wait_until(self, t: float) -> None:
        """Advance the device frontier to ``t`` (the driver's round-trip
        bound: the previous verdict's downlink arrival). The idle gap is the
        draft-stall component of TTFT attribution."""
        if t > self.t:
            if self.tracer.enabled:
                self.tracer.span(
                    self._track, "await_verdict", self.t, t, cat="device",
                    args={"rid": self._rid},
                )
            self.t = t

    def draft_rewind(self, accepted: int, token: int) -> list:
        """Apply the server verdict (instant host bookkeeping)."""
        return self._src.draft_rewind(accepted, token)

    def cancel(self, at: Optional[float] = None) -> None:
        """Local cancellation is instantaneous (no network hop)."""
        self._src.cancel()

    @property
    def done(self) -> bool:
        return self._src.done

    @property
    def prefill_tokens(self) -> int:
        return int(self._src._prompt.shape[0])

    @property
    def prefilled(self) -> bool:
        return self.prefill_s is not None

    @property
    def tokens_drafted(self) -> int:
        """Draft tokens the device computed — rejected ones included (they
        are the device's wasted decode compute)."""
        return self._src.tokens_emitted

    @property
    def tokens_generated(self) -> int:
        """Driver-accounting alias: drafts are the device's generated (and,
        when rejected, wasted) tokens."""
        return self.tokens_drafted

    @property
    def decode_dispatches(self) -> int:
        return self._src.decode_dispatches


class ServerTokenStream:
    """Handle onto one request id inside the shared ``BatchedServer``.

    Clock-driven: the server generates autonomously as the event loop
    advances it with ``run_until``; this stream only drains the request's
    incremental events and adds the downlink latency.

    ``cancel(at=t)`` models cancel-propagation latency (§4.2 wasted-compute
    accounting): the driver's cancel crosses the uplink, reaching the server
    at ``t + uplink`` — until then the request keeps its place, so a queued
    race loser can slip into prefill and burn pool blocks before the cancel
    lands. Delivery to this client still stops instantly (tokens arriving
    after a local cancel are discarded, and counted as waste).
    """

    pull_driven = False
    kind = Endpoint.SERVER

    def __init__(self, server: BatchedServer, rid: int, start_at: float,
                 downlink: float, prefill_tokens: int, uplink: float = 0.0,
                 tracer=NULL_TRACER, req_rid: Optional[int] = None):
        self.server = server
        self.rid = rid
        self.start_at = float(start_at)
        self.downlink = float(downlink)
        self.uplink = float(uplink)
        self._prefill_tokens = int(prefill_tokens)
        self._buf: deque[TokenEvent] = deque()
        self._cancelled = False
        self._emitted_seen = 0
        self.tracer = tracer
        self._req_rid = req_rid           # driver-level rid (trace join key)
        self._first_drained = False

    # -- lifecycle ---------------------------------------------------------

    @property
    def activated(self) -> bool:
        return True                       # submission already happened

    def activate(self) -> None:
        pass

    @property
    def done(self) -> bool:
        return not self._buf and (
            self._cancelled or self.server.is_finished(self.rid)
        )

    def cancel(self, at: Optional[float] = None) -> None:
        """Stop delivery now; stop the server-side request either now
        (``at=None`` — e.g. end-of-request cleanup) or one uplink RTT after
        the virtual issue time ``at``."""
        if self._cancelled:
            return                       # the earlier cancel is already in flight
        self._cancelled = True
        self.server.cancel(
            self.rid, at=None if at is None else float(at) + self.uplink
        )
        self._buf.clear()

    # -- event access ------------------------------------------------------

    def _drain(self) -> None:
        if self._cancelled:
            return
        for tok, t in self.server.pop_events(self.rid):
            if self.tracer.enabled and not self._first_drained:
                # one downlink span for the first token: the network leg of
                # this request's TTFT (later tokens pipeline behind it)
                self._first_drained = True
                rid = self._req_rid if self._req_rid is not None else self.rid
                # lane is per server stream: a migration re-open's transfer
                # legitimately overlaps the original stream's in-flight leg
                self.tracer.span(
                    f"network/req{rid}.s{self.rid}", "downlink",
                    t, t + self.downlink,
                    cat="network", args={"rid": rid, "srv_rid": self.rid},
                )
            self._buf.append(TokenEvent(tok, t + self.downlink, Endpoint.SERVER))

    def candidate_time(self) -> Optional[float]:
        self._drain()
        return self._buf[0].t if self._buf else None

    def peek(self) -> Optional[TokenEvent]:
        self._drain()
        return self._buf[0] if self._buf else None

    def pop(self) -> TokenEvent:
        ev = self.peek()
        if ev is None:
            raise RuntimeError("pop() on an exhausted stream")
        self._buf.popleft()
        return ev

    # -- accounting --------------------------------------------------------

    @property
    def cancel_in_flight(self) -> bool:
        """True while our cancel is still crossing the uplink: the server
        keeps generating (wasting) tokens until it lands, so final waste
        accounting must wait for it."""
        return self._cancelled and self.server.cancel_pending(self.rid)

    @property
    def prefilled(self) -> bool:
        return self.rid in self.server.first_token_time

    @property
    def first_token_at(self) -> Optional[float]:
        """Virtual arrival time of the first token at the client (TTFT
        profiling source), known even if the stream was cancelled after its
        prefill ran."""
        t = self.server.first_token_time.get(self.rid)
        return None if t is None else t + self.downlink

    @property
    def prefill_tokens(self) -> int:
        return self._prefill_tokens

    @property
    def tokens_generated(self) -> int:
        return self.server.generated.get(self.rid, 0)

    @property
    def decode_dispatches(self) -> int:
        return self.server.decode_dispatches.get(self.rid, 0)


class DeviceEndpoint:
    """Per-user device: a dedicated engine, no network hop. TTFT grows
    linearly with prompt length (§3) because prefill is compute-bound on
    dedicated hardware. Concurrent requests get independent streams (each
    user owns their device), so there is no cross-request contention here.

    Both endpoints expose the SAME stream-opening signature —
    ``open_stream(req, rng, start_at)`` / ``open_replay_stream(req,
    generated, rng, start_at)`` — so the DiSCo driver never special-cases
    argument lists per endpoint. ``rng`` is the shared trace RNG that
    network-attached endpoints draw their link samples from; the device has
    no stochastic link, so it accepts and ignores it (the parameter is part
    of the endpoint protocol, not this endpoint's behavior)."""

    kind = Endpoint.DEVICE

    def __init__(self, engine: InferenceEngine, energy_per_prefill_token: float = 1.0,
                 energy_per_decode_token: float = 1.0, tracer=None):
        self.engine = engine
        self.energy_per_prefill_token = energy_per_prefill_token
        self.energy_per_decode_token = energy_per_decode_token
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._auto_seed = 0    # distinct default stream per request, matching
                               # the server endpoint's rid-derived default

    def _track(self, req: Request, suffix: str = "") -> tuple:
        rid = getattr(req, "rid", None)
        lane = f"req{rid}" if rid is not None else "req?"
        return f"device/{lane}{suffix}", rid

    def _resolve(self, req: Request) -> Request:
        """Default sampling seed: distinct per opened stream. Callers racing
        this endpoint against another for ONE request (the DiSCo driver)
        must resolve the request's seed themselves — endpoint-local defaults
        cannot agree across endpoints."""
        if req.seed is not None:
            return req
        self._auto_seed += 1
        return dataclasses.replace(req, seed=self._auto_seed - 1)

    def open_stream(self, req: Request,
                    rng: Optional[np.random.Generator] = None,
                    start_at: float = 0.0) -> DeviceTokenStream:
        track, rid = self._track(req)
        return DeviceTokenStream(
            self.engine.open_stream(self._resolve(req)), start_at, self.kind,
            tracer=self.tracer, track=track, rid=rid,
        )

    def open_replay_stream(self, req: Request, generated,
                           rng: Optional[np.random.Generator] = None,
                           start_at: float = 0.0) -> DeviceTokenStream:
        """Migration-target path: re-prefill prompt + token IDs, then
        continue (the stream's budget is the request's remaining
        ``req.max_new - len(generated)``). Per-token times are interpolated
        across each measured decode chunk (same as a fresh stream — no
        host-buffered bursts). ``req`` must carry the source's seed and
        sampler so a temperature > 0 replay resumes the source's
        per-position sampling stream bit-identically."""
        track, rid = self._track(req, suffix=":replay")
        return DeviceTokenStream(
            self.engine.open_replay(self._resolve(req), generated),
            start_at, self.kind, tracer=self.tracer, track=track, rid=rid,
        )

    @property
    def supports_draft(self) -> bool:
        """True when this device can serve speculative draft windows (a
        rewindable pure-attention cache)."""
        return self.engine.supports_draft

    def open_draft_session(self, req: Request,
                           rng: Optional[np.random.Generator] = None,
                           start_at: float = 0.0) -> DeviceDraftSession:
        """Open the device half of a draft/verify session. The caller (the
        DiSCo driver) must resolve the request's seed so device drafts and
        server verification share one sampling stream."""
        return DeviceDraftSession(
            self.engine.open_stream(self._resolve(req)), start_at,
            tracer=self.tracer, rid=getattr(req, "rid", None),
        )


class ServerEndpoint:
    """Shared server: requests from ALL live DiSCo sessions land in one
    contended ``BatchedServer`` — queueing delay and the TTFT tail are
    emergent, not sampled. The network contributes sampled RTT only (half on
    the uplink before the request queues, half on each token's downlink).
    Same ``open_stream(req, rng, start_at)`` signature as the device
    endpoint; the request's SLO/priority ride to the server's
    deadline-aware admission queue."""

    kind = Endpoint.SERVER

    def __init__(self, server: BatchedServer, network: Optional[NetworkModel] = None,
                 tracer=None):
        self.server = server
        # one NetworkModel per endpoint instance: a shared default instance
        # would alias link parameters across every endpoint in the process
        self.network = network if network is not None else NetworkModel()
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def _open(self, req: Request, rng: np.random.Generator,
              start_at: float, verify: bool = False) -> ServerTokenStream:
        rtt = self.network.sample_rtt(rng)
        rid = self.server.submit(req, at=start_at + rtt / 2.0, verify=verify)
        req_rid = getattr(req, "rid", None)
        if self.tracer.enabled:
            lane = req_rid if req_rid is not None else rid
            # one lane per server stream (not per driver request): a
            # migration re-open's uplink can overlap the race stream's
            self.tracer.span(
                f"network/req{lane}.s{rid}", "uplink",
                start_at, start_at + rtt / 2.0,
                cat="network", args={"rid": lane, "srv_rid": rid},
            )
        return ServerTokenStream(
            self.server, rid, start_at, downlink=rtt / 2.0,
            prefill_tokens=req.prompt_len, uplink=rtt / 2.0,
            tracer=self.tracer, req_rid=req_rid,
        )

    def open_stream(self, req: Request, rng: np.random.Generator,
                    start_at: float = 0.0) -> ServerTokenStream:
        return self._open(req, rng, start_at)

    @property
    def supports_verify(self) -> bool:
        """True when the backing server scores draft windows
        (``BatchedServer(speculative=True)``)."""
        return getattr(self.server, "speculative", False)

    def open_verify_stream(self, req: Request, rng: np.random.Generator,
                           start_at: float = 0.0) -> ServerTokenStream:
        """Submit ``req`` in VERIFY mode: after its admission prefill the
        request decodes only through driver-fed ``verify_step`` rounds, yet
        delivery, cancellation, and waste accounting ride this same stream
        — the one delivery path both speculative and race modes share."""
        return self._open(req, rng, start_at, verify=True)

    def open_replay_stream(self, req: Request, generated,
                           rng: np.random.Generator,
                           start_at: float = 0.0) -> ServerTokenStream:
        """Migration-target path: the re-prefill is submitted to the SAME
        deadline-aware batched scheduler as live traffic — a migration
        competes for admission like any other request (keeping the original
        SLO and priority). ``req`` must carry the migrating request's seed
        and sampler so a temperature > 0 continuation is bit-identical to
        what the source would have produced."""
        generated = np.asarray(generated, np.int32)
        full = np.concatenate([req.prompt, generated])
        replay = dataclasses.replace(
            req, prompt=full,
            max_new=max(req.max_new - int(generated.shape[0]), 1),
        )
        return self._open(replay, rng, start_at)
