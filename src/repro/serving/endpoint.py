"""DiSCo endpoints over real JAX engines, composed with a virtual network.

Timing model (honest for a single-process CPU testbed): *compute* times are
real wall-clock measurements of the JAX engines; *network/queue* latencies
are sampled from configurable distributions and added to the timeline. The
scheduler only ever sees timestamps, exactly as it would in deployment.

DeviceEndpoint: local engine, no network; TTFT grows linearly with prompt
length (§3) because prefill is compute-bound on dedicated hardware.
ServerEndpoint: engine + network RTT + a queueing-delay process (the §2.3
"high-load period" spikes).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Optional

import numpy as np

from repro.core.cost import Endpoint

from .engine import GenerationResult, InferenceEngine

__all__ = ["NetworkModel", "DeviceEndpoint", "ServerEndpoint", "TokenEvent"]


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    token: int
    t: float          # virtual timeline, seconds since request arrival
    endpoint: Endpoint


@dataclasses.dataclass
class NetworkModel:
    rtt_mean: float = 0.04
    rtt_jitter: float = 0.01
    queue_spike_prob: float = 0.06
    queue_spike_scale: float = 1.5   # seconds added during a high-load episode

    def sample_rtt(self, rng: np.random.Generator) -> float:
        return max(self.rtt_mean + rng.normal(0.0, self.rtt_jitter), 0.001)

    def sample_queue_delay(self, rng: np.random.Generator) -> float:
        if rng.random() < self.queue_spike_prob:
            return self.queue_spike_scale * (1.0 + rng.random())
        return rng.exponential(0.02)


class DeviceEndpoint:
    kind = Endpoint.DEVICE

    def __init__(self, engine: InferenceEngine, energy_per_prefill_token: float = 1.0,
                 energy_per_decode_token: float = 1.0):
        self.engine = engine
        self.energy_per_prefill_token = energy_per_prefill_token
        self.energy_per_decode_token = energy_per_decode_token

    def stream(self, prompt: np.ndarray, max_new: int, rng, start_at: float = 0.0
               ) -> list[TokenEvent]:
        res = self.engine.generate(prompt, max_new)
        return [
            TokenEvent(tok, start_at + t, Endpoint.DEVICE)
            for tok, t in zip(res.tokens, res.token_times)
        ]

    def replay_stream(self, prompt, generated, max_new, rng, start_at: float = 0.0):
        """Migration-target path: re-prefill prompt + token IDs, then continue."""
        replay_s, cont = self.engine.replay_then_continue(prompt, generated, max_new)
        events = []
        t0 = time.perf_counter()
        for tok in cont:
            now = time.perf_counter() - t0
            events.append(TokenEvent(tok, start_at + replay_s + now, Endpoint.DEVICE))
        return events


class ServerEndpoint:
    kind = Endpoint.SERVER

    def __init__(self, engine: InferenceEngine, network: NetworkModel = NetworkModel()):
        self.engine = engine
        self.network = network

    def stream(self, prompt: np.ndarray, max_new: int, rng: np.random.Generator,
               start_at: float = 0.0) -> list[TokenEvent]:
        delay = self.network.sample_rtt(rng) + self.network.sample_queue_delay(rng)
        res = self.engine.generate(prompt, max_new)
        return [
            TokenEvent(tok, start_at + delay + t, Endpoint.SERVER)
            for tok, t in zip(res.tokens, res.token_times)
        ]

    def replay_stream(self, prompt, generated, max_new, rng, start_at: float = 0.0):
        delay = self.network.sample_rtt(rng) + self.network.sample_queue_delay(rng)
        replay_s, cont = self.engine.replay_then_continue(prompt, generated, max_new)
        t0 = time.perf_counter()
        events = []
        for tok in cont:
            now = time.perf_counter() - t0
            events.append(
                TokenEvent(tok, start_at + delay + replay_s + now, Endpoint.SERVER)
            )
        return events
