"""Disaggregated prefill/decode serving and the device-vs-fleet cluster router.

Three layers, bottom-up:

- :class:`DisaggregatedServer` — one logical server built from TWO
  :class:`~repro.serving.engine.BatchedServer` workers: a *prefill worker*
  that runs admission + (chunked) prefill and emits exactly the first token,
  and a *decode worker* that continues the stream.  The finished KV state
  crosses between their pools via the cross-pool extension of
  ``KVPoolManager.clone`` (``detach`` → ``receive`` → ``release_detached``):
  the device half is a real gather/scatter block copy between page arrays,
  the time cost is a modeled :class:`InterconnectModel` delay on the virtual
  timeline.  When the decode-side pool cannot take the blocks, the hand-off
  falls back LOSSLESSLY to recompute-on-decode-worker (a replay-resume
  admission regenerates the identical continuation), so the delivered stream
  is bitwise-identical to a monolithic ``BatchedServer`` run either way.

- :class:`ClusterServer` / :class:`ClusterEndpoint` — N server replicas
  (monolithic or disaggregated) behind the existing
  :class:`~repro.serving.endpoint.ServerEndpoint` surface, so
  ``DiSCoServer`` races device-vs-fleet unchanged.  Routing consults
  per-replica load snapshots (queue depth, free blocks, EDF headroom) and
  per-replica radix prefix indexes: a replica holding a warm shared prefix
  gets a sticky bonus proportional to the matched fraction.  Sampling seeds
  are pinned BEFORE routing, so delivered content never depends on
  placement — the bitwise gate survives any routing policy.

- Observability — every worker/replica traces into its own scoped lane
  group (``r0.prefill.server/…``), hand-off spans carry bytes moved and
  decode-side stall time on per-request ``xfer`` lanes, and the hand-off
  counters reconcile against ``pool_stats()`` via ``reconcile_trace``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from .endpoint import ServerEndpoint
from .engine import BatchedServer
from .kv_pool import blocks_for_tokens
from .telemetry import NULL_TRACER, MetricsRegistry

__all__ = [
    "ClusterEndpoint",
    "ClusterServer",
    "DisaggregatedServer",
    "InterconnectModel",
]

# rid-collision guard for trace scoping: an unmapped worker-local rid is
# offset by its worker's stride so async trace ids never collide with the
# stream-global ids (or another worker's)
_RID_STRIDE = 1_000_000


@dataclasses.dataclass
class InterconnectModel:
    """Modeled prefill→decode KV link on the virtual timeline.

    ``delay(nbytes) = latency_s + nbytes / bytes_per_s`` — a fixed hop
    latency plus a bandwidth term, the same modeled-network convention as
    :class:`~repro.serving.endpoint.NetworkModel` (compute is measured,
    wires are modeled).  Defaults approximate a commodity datacenter NIC
    (~2 ms hop, 16 GB/s effective)."""

    latency_s: float = 0.002
    bytes_per_s: float = 16e9

    def delay(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bytes_per_s


class _ScopedTracer:
    """Scoping shim over a shared :class:`~repro.serving.telemetry.Tracer`.

    Workers and replicas all trace into ONE tracer; this wrapper keeps their
    lanes and request ids from colliding:

    - track names gain a ``scope.`` prefix (``server/row0`` →
      ``prefill.server/row0``), giving each worker/replica its own process
      group in the Perfetto view; wrappers nest (``r0.prefill.server/…``);
    - async request ids rewrite through ``rid_map`` (worker-local rid →
      stream-global rid), so one request's prefill-worker span and
      decode-worker span land on the SAME async id, and ``args["rid"]``
      rewrites with it — ``ttft_attribution``'s dispatch↔prefill join keeps
      working across workers; unmapped rids offset by ``base``;
    - a ``replica`` arg is stamped on spans/instants (outer scopes prefix
      inner ones), which ``trace_report`` uses for per-replica attribution.
    """

    __slots__ = ("inner", "scope", "base", "rid_map")

    def __init__(self, inner, scope: str, base: int = 0, rid_map=None):
        self.inner = inner
        self.scope = scope
        self.base = int(base)
        self.rid_map = {} if rid_map is None else rid_map

    @property
    def enabled(self) -> bool:
        return self.inner.enabled

    def _rid(self, rid: int) -> int:
        return self.rid_map.get(rid, rid + self.base)

    def _args(self, args):
        out = dict(args) if args else {}
        rid = out.get("rid")
        if isinstance(rid, (int, np.integer)):
            out["rid"] = self.rid_map.get(int(rid), int(rid) + self.base)
        prev = out.get("replica")
        out["replica"] = self.scope if prev is None else f"{self.scope}.{prev}"
        return out

    def span(self, track, name, t0, t1, cat="span", args=None):
        self.inner.span(f"{self.scope}.{track}", name, t0, t1, cat=cat,
                        args=self._args(args))

    def instant(self, track, name, t, cat="instant", args=None):
        self.inner.instant(f"{self.scope}.{track}", name, t, cat=cat,
                           args=self._args(args))

    def value(self, track, name, t, v):
        self.inner.value(f"{self.scope}.{track}", name, t, v)

    def begin_request(self, rid, t, cat="request", name=None, args=None):
        self.inner.begin_request(self._rid(rid), t, cat=cat, name=name,
                                 args=self._args(args))

    def request_instant(self, rid, name, t, cat="request", args=None):
        self.inner.request_instant(self._rid(rid), name, t, cat=cat,
                                   args=self._args(args))

    def end_request(self, rid, t, cat="request", args=None):
        self.inner.end_request(self._rid(rid), t, cat=cat,
                               args=self._args(args))


def _merge_hist(a: dict, b: dict) -> dict:
    count = a["count"] + b["count"]
    total = a["total"] + b["total"]
    return {
        "count": count,
        "total": total,
        "mean": total / count if count else 0.0,
        "min": min(a["min"], b["min"]) if count else 0.0,
        "max": max(a["max"], b["max"]) if count else 0.0,
    }


# pool_stats() merge rule: trace instants from every worker/replica land in
# ONE tracer, so reconcile_trace compares them against the SUM of the
# per-worker counters; config echoes keep the first value, booleans OR
_CONFIG_KEYS = frozenset({"block_size", "admission", "prefill_chunk"})


def _merge_stats(snaps: Sequence[dict]) -> dict:
    out: dict = {}
    for snap in snaps:
        for k, v in snap.items():
            if k not in out:
                out[k] = v
            elif k in _CONFIG_KEYS or isinstance(v, str):
                pass
            elif isinstance(v, bool):
                out[k] = bool(out[k]) or v
            elif isinstance(v, dict) and "count" in v and "total" in v:
                out[k] = _merge_hist(out[k], v)
            elif isinstance(v, (int, float)):
                out[k] = out[k] + v
    return out


@dataclasses.dataclass
class _Handoff:
    """Per-request hand-off plan: the façade's state machine entry.

    ``prefill`` → (prefill worker owns the request, first token pending)
    ``transfer`` → (KV crossing the interconnect, arrives at ``arrive``)
    ``decode`` → (decode worker owns the continuation as ``d_rid``)
    ``done`` → (no decode phase: finished, cancelled, or max_new == 1)
    """

    gid: int
    prompt: np.ndarray
    max_new: int                      # original request total
    seed: int
    sampler: object
    priority: int
    deadline: float
    state: str = "prefill"
    tokens: list = dataclasses.field(default_factory=list)
    d_rid: Optional[int] = None
    t_sent: float = 0.0               # transfer departure (first-token time)
    arrive: float = 0.0               # transfer arrival on the decode worker
    nbytes: int = 0
    cancel_at: Optional[float] = None


class _MergedCounts:
    """dict-like view summing a per-request value across the two workers."""

    __slots__ = ("srv", "attr")

    def __init__(self, srv: "DisaggregatedServer", attr: str):
        self.srv = srv
        self.attr = attr

    def get(self, gid, default=None):
        plan = self.srv._plans.get(gid)
        if plan is None:
            return default
        total = getattr(self.srv.prefill, self.attr).get(gid, 0)
        if plan.d_rid is not None:
            total += getattr(self.srv.decode, self.attr).get(plan.d_rid, 0)
        return total

    def __contains__(self, gid) -> bool:
        return gid in self.srv._plans

    def __getitem__(self, gid):
        got = self.get(gid)
        if got is None:
            raise KeyError(gid)
        return got


class DisaggregatedServer:
    """Prefill worker + decode worker behind one ``BatchedServer`` surface.

    The prefill worker admits every request with ``max_new=1`` — admission
    policy, chunked prefill, preemption and the prefix cache all run there
    unchanged — and holds the finished KV blocks (``kv_hold``) past
    retirement while they cross the :class:`InterconnectModel`.  On arrival
    the decode worker ``adopt``-s the stream: blocks device-copy into its
    pool (``KVPoolManager.receive``) and decoding continues at the exact
    sampling position the prefill worker stopped at.  If the decode pool
    cannot take the blocks, adoption falls back to a replay-resume
    admission — same tokens, later.  Either way the delivered stream is
    bitwise-identical to a monolithic run, because token content depends
    only on (seed, sampler, position, logits), never on which worker runs
    the math.

    Speculative verify mode is not supported (the draft/verify loop needs
    one worker owning the whole stream); ``submit(verify=True)`` raises.
    """

    speculative = False

    def __init__(self, cfg, params, *, max_slots: int = 4, max_len: int = 256,
                 decode_chunk: int = 4, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefill_blocks: Optional[int] = None,
                 decode_blocks: Optional[int] = None,
                 prefill_slots: Optional[int] = None,
                 decode_slots: Optional[int] = None,
                 use_kernel: Optional[bool] = None, sampler=None,
                 admission: str = "edf", prefix_cache: bool = False,
                 prefill_chunk: Optional[int] = None,
                 interconnect: Optional[InterconnectModel] = None,
                 tracer=None):
        # workers size independently: the decode worker typically wants the
        # wider batch (it carries EVERY stream), the prefill worker only
        # bounds admission concurrency
        prefill_slots = max_slots if prefill_slots is None else prefill_slots
        decode_slots = max_slots if decode_slots is None else decode_slots
        self.prefill = BatchedServer(
            cfg, params, max_slots=prefill_slots, max_len=max_len,
            decode_chunk=decode_chunk, paged=True, block_size=block_size,
            num_blocks=prefill_blocks if prefill_blocks is not None else num_blocks,
            use_kernel=use_kernel, sampler=sampler, admission=admission,
            prefix_cache=prefix_cache, prefill_chunk=prefill_chunk,
        )
        self.decode = BatchedServer(
            cfg, params, max_slots=decode_slots, max_len=max_len,
            decode_chunk=decode_chunk, paged=True, block_size=block_size,
            num_blocks=decode_blocks if decode_blocks is not None else num_blocks,
            use_kernel=use_kernel, sampler=sampler, admission=admission,
        )
        self.interconnect = interconnect if interconnect is not None else InterconnectModel()
        self.default_sampler = sampler
        self.block_size = self.prefill.block_size
        # payload of one transferred block: its slice of every page array
        # (k and v, all layers) — shape (L, N, H, bs, D) contributes
        # size/N bytes per block
        self._block_bytes = int(sum(
            (np.prod(a.shape) // a.shape[1]) * a.dtype.itemsize
            for a in self.decode.pages.values()
        ))
        self.metrics = MetricsRegistry()
        for k in ("handoff_bytes", "handoffs_cancelled"):
            self.metrics.counter(k)
        for k in ("handoff_delay_s", "handoff_stall_s"):
            self.metrics.histogram(k)
        self._plans: dict[int, _Handoff] = {}
        self.next_id = 0              # == prefill.next_id (lockstep)
        # worker-local rid → stream-global rid, shared with the scoped
        # tracers so both workers' trace records join on one async id
        self._p_map: dict[int, int] = {}
        self._d_map: dict[int, int] = {}
        self.first_token_time = self.prefill.first_token_time   # gid == p_rid
        self.generated = _MergedCounts(self, "generated")
        self.decode_dispatches = _MergedCounts(self, "decode_dispatches")
        self.tracer = NULL_TRACER
        self.set_tracer(tracer)

    # -- plumbing ----------------------------------------------------------

    def set_tracer(self, tracer) -> None:
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is None:
            self.prefill.set_tracer(None)
            self.decode.set_tracer(None)
            return
        self.prefill.set_tracer(_ScopedTracer(
            tracer, "prefill", base=_RID_STRIDE, rid_map=self._p_map))
        self.decode.set_tracer(_ScopedTracer(
            tracer, "decode", base=2 * _RID_STRIDE, rid_map=self._d_map))

    def warmup(self, prompt_len: int = 8, prompt_lens: tuple = ()) -> None:
        self.prefill.warmup(prompt_len=prompt_len, prompt_lens=prompt_lens)
        self.decode.warmup(prompt_len=prompt_len, prompt_lens=prompt_lens)

    @property
    def clock(self) -> float:
        return max(self.prefill.clock, self.decode.clock)

    # -- request surface ---------------------------------------------------

    def submit(self, req, at: Optional[float] = None,
               verify: bool = False) -> int:
        """Admit a request to the prefill worker; returns the stream-global
        rid.  The request's seed pins before the split, so the decode-worker
        continuation (and any recompute fallback) replays the exact same
        sampling stream."""
        if verify:
            raise ValueError(
                "disaggregated servers do not support verify mode")
        gid = self.next_id
        if req.seed is None:
            req = dataclasses.replace(req, seed=gid)
        self._p_map[self.prefill.next_id] = gid
        p_rid = self.prefill.submit(
            dataclasses.replace(req, max_new=1), at=at)
        assert p_rid == gid, "prefill worker rid out of lockstep"
        self.next_id = self.prefill.next_id
        if req.max_new > 1:
            # hold the finished KV past retirement: the blocks must stay
            # referenced while the transfer is in flight
            self.prefill.kv_hold.add(p_rid)
        item = self.prefill.queue[-1]     # the entry submit just appended
        self._plans[gid] = _Handoff(
            gid=gid, prompt=np.asarray(req.prompt, np.int32),
            max_new=int(req.max_new), seed=int(item.seed),
            sampler=item.sampler, priority=int(item.priority),
            deadline=float(item.deadline),
        )
        return gid

    def run_until(self, t_limit: float = math.inf) -> None:
        """Advance the virtual timeline: transfers are delivered to the
        decode worker strictly in arrival order, ONE at a time — each
        delivery releases held source blocks, which can unblock a
        capacity-stalled prefill whose hand-off arrives EARLIER than the
        next already-harvested transfer.  Running the prefill worker and
        re-harvesting between deliveries keeps the decode worker's clock
        causally behind every undelivered arrival; only when no transfer
        can land inside the window does the decode worker run to the
        horizon."""
        while True:
            self.prefill.run_until(t_limit)
            self._harvest()
            pending = [p for p in self._plans.values()
                       if p.state == "transfer" and p.arrive <= t_limit]
            if not pending:
                break
            plan = min(pending, key=lambda p: (p.arrive, p.gid))
            self.decode.run_until(plan.arrive)
            self._deliver(plan)
        self.decode.run_until(t_limit)

    def run_to_completion(self) -> dict[int, list[int]]:
        for _ in range(1 + len(self._plans)):
            self.run_until(math.inf)
            if all(p.state in ("decode", "done") for p in self._plans.values()):
                break
        return self.completed

    def _harvest(self) -> None:
        """Turn freshly finished prefills into in-flight transfers."""
        p = self.prefill
        for plan in self._plans.values():
            if plan.state != "prefill" or plan.gid not in p.completed:
                continue
            if plan.gid in p.cancelled or plan.max_new <= 1:
                # no decode phase: cancelled while prefilling, or the one
                # prefill token was the whole request
                p.release_held(plan.gid)
                plan.state = "done"
                continue
            plan.tokens = list(p.completed[plan.gid])
            if not plan.tokens:
                p.release_held(plan.gid)
                plan.state = "done"
                continue
            held = p.held_tables.get(plan.gid)
            blocks = 0
            if held is not None:
                table = held[0]
                blocks = min(
                    blocks_for_tokens(table.num_tokens, p.block_size),
                    len(table.blocks),
                )
            plan.nbytes = blocks * self._block_bytes
            plan.t_sent = p.first_token_time.get(plan.gid, p.clock)
            plan.arrive = plan.t_sent + self.interconnect.delay(plan.nbytes)
            plan.state = "transfer"

    def _deliver(self, plan: _Handoff) -> None:
        """One transfer arrival: adopt on the decode worker (device block
        copy into its pool, or lossless recompute fallback), free the held
        source blocks, trace the hand-off span."""
        p, d = self.prefill, self.decode
        if plan.cancel_at is not None and plan.cancel_at <= plan.arrive:
            # cancelled mid-transfer: drop the payload; the delivered stream
            # is exactly what the prefill worker emitted
            p.release_held(plan.gid)
            plan.state = "done"
            self.metrics.counter("handoffs_cancelled").inc()
            if self.tracer.enabled:
                self.tracer.instant(
                    f"xfer/req{plan.gid}", "handoff_cancelled",
                    max(plan.cancel_at, plan.t_sent), cat="server",
                    args={"rid": plan.gid},
                )
            return
        held = p.held_tables.get(plan.gid)
        src_table = held[0] if held is not None else None
        self._d_map[d.next_id] = plan.gid
        d_rid, adopted = d.adopt(
            plan.prompt, plan.tokens, plan.max_new - len(plan.tokens),
            seed=plan.seed, sampler=plan.sampler, priority=plan.priority,
            deadline=plan.deadline,
            first_token_at=p.first_token_time.get(plan.gid),
            at=plan.arrive,
            src_pages=p.pages if src_table is not None else None,
            src_table=src_table,
            num_tokens=src_table.num_tokens if src_table is not None else None,
        )
        plan.d_rid = d_rid
        plan.state = "decode"
        # the held source blocks free on BOTH outcomes: adopted means the
        # copy landed, fallback means the decode worker recomputes
        p.release_held(plan.gid)
        stall = max(0.0, d.clock - plan.arrive) if adopted else 0.0
        self.metrics.counter("handoff_bytes").inc(plan.nbytes)
        self.metrics.histogram("handoff_delay_s").observe(
            plan.arrive - plan.t_sent)
        self.metrics.histogram("handoff_stall_s").observe(stall)
        if self.tracer.enabled:
            self.tracer.span(
                f"xfer/req{plan.gid}", "handoff", plan.t_sent, plan.arrive,
                cat="server",
                args={"rid": plan.gid,
                      "bytes": plan.nbytes,
                      "blocks": plan.nbytes // max(1, self._block_bytes),
                      "stall_s": stall, "adopted": bool(adopted)},
            )
        if plan.cancel_at is not None:
            d.cancel(d_rid, at=plan.cancel_at)

    def cancel(self, gid: int, at: Optional[float] = None) -> None:
        plan = self._plans.get(gid)
        if plan is None:
            raise ValueError(f"unknown request id {gid}")
        if plan.state == "decode":
            self.decode.cancel(plan.d_rid, at=at)
            return
        if plan.state == "done":
            return
        # still prefilling or mid-transfer: stop the prefill side (no-op if
        # it already finished) and remember the due time for delivery
        self.prefill.cancel(gid, at=at)
        t = float(at) if at is not None else max(
            self.prefill.clock, self.decode.clock)
        plan.cancel_at = t if plan.cancel_at is None else min(plan.cancel_at, t)

    def cancel_pending(self, gid: int) -> bool:
        plan = self._plans[gid]
        if plan.state == "decode":
            return self.decode.cancel_pending(plan.d_rid)
        if plan.state == "done":
            return False
        return self.prefill.cancel_pending(gid) or plan.cancel_at is not None

    def pop_events(self, gid: int) -> list:
        out = self.prefill.pop_events(gid)
        plan = self._plans[gid]
        if plan.d_rid is not None:
            out += self.decode.pop_events(plan.d_rid)
        return out

    def is_finished(self, gid: int) -> bool:
        plan = self._plans.get(gid)
        if plan is None:
            raise ValueError(f"unknown request id {gid}")
        if plan.state in ("prefill", "transfer"):
            return False
        if plan.state == "decode":
            return (self.decode.is_finished(plan.d_rid)
                    and not self.prefill.events[gid])
        return self.prefill.is_finished(gid)

    def ttft(self, gid: int) -> Optional[float]:
        return self.prefill.ttft(gid)

    @property
    def completed(self) -> dict[int, list[int]]:
        """Stream-global view of finished requests (prefill + decode halves
        concatenated) — same shape as ``BatchedServer.completed``."""
        out: dict[int, list[int]] = {}
        for gid, plan in self._plans.items():
            if gid not in self.prefill.completed:
                continue
            if plan.state == "done":
                out[gid] = list(self.prefill.completed[gid])
            elif plan.d_rid is not None and plan.d_rid in self.decode.completed:
                # the decode worker's token list re-carries the handed-off
                # tokens (its slot seeds from them) — drop that prefix
                out[gid] = (list(self.prefill.completed[gid])
                            + list(self.decode.completed[plan.d_rid])[
                                len(plan.tokens):])
        return out

    # -- router signals ----------------------------------------------------

    def load_snapshot(self) -> dict:
        p = self.prefill.load_snapshot()
        d = self.decode.load_snapshot()
        return {
            "queue_depth": p["queue_depth"] + d["queue_depth"],
            "active": p["active"] + d["active"],
            "free_rows": min(p["free_rows"], d["free_rows"]),
            "free_blocks": min(p["free_blocks"], d["free_blocks"]),
            "total_blocks": min(p["total_blocks"], d["total_blocks"]),
            "edf_headroom": min(p["edf_headroom"], d["edf_headroom"]),
        }

    def prefix_probe(self, tokens) -> int:
        return self.prefill.prefix_probe(tokens)

    def pool_stats(self) -> dict:
        return _merge_stats([
            self.prefill.pool_stats(),
            self.decode.pool_stats(),
            self.metrics.snapshot(),
        ])


class _ClusterView:
    """dict-like view translating cluster-global rids to replica-local."""

    __slots__ = ("srv", "attr")

    def __init__(self, srv: "ClusterServer", attr: str):
        self.srv = srv
        self.attr = attr

    def _map(self, gid):
        where = self.srv._where.get(gid)
        if where is None:
            return None
        idx, local = where
        return getattr(self.srv.replicas[idx], self.attr), local

    def get(self, gid, default=None):
        got = self._map(gid)
        if got is None:
            return default
        d, local = got
        return d.get(local, default)

    def __contains__(self, gid) -> bool:
        got = self._map(gid)
        return got is not None and got[1] in got[0]

    def __getitem__(self, gid):
        got = self._map(gid)
        if got is None:
            raise KeyError(gid)
        return got[0][got[1]]


class ClusterServer:
    """N server replicas behind one ``BatchedServer`` surface.

    Replicas are :class:`~repro.serving.engine.BatchedServer` or
    :class:`DisaggregatedServer` instances (anything speaking the submit /
    run_until / pop_events protocol plus ``load_snapshot`` /
    ``prefix_probe``).  Routing is a per-request argmin over replica
    pressure — queue depth + active slots, minus a free-block credit, plus
    an urgency penalty when a replica already has deadline-tight work —
    less a sticky bonus for replicas whose radix prefix index already holds
    a warm prefix of the prompt (cross-replica prefix placement).  Ties
    break to the lowest replica index, so routing is deterministic."""

    speculative = False

    def __init__(self, replicas: Sequence, *, sticky_weight: float = 2.0,
                 tracer=None):
        if not replicas:
            raise ValueError("ClusterServer needs at least one replica")
        self.replicas = list(replicas)
        self.sticky_weight = float(sticky_weight)
        self.next_id = 0
        self._where: dict[int, tuple[int, int]] = {}
        self._rid_maps: list[dict] = [dict() for _ in self.replicas]
        self.metrics = MetricsRegistry()
        for k in ("cluster_requests", "sticky_routes"):
            self.metrics.counter(k)
        self.routed = [0] * len(self.replicas)
        self.metrics.view("routed_per_replica", lambda: list(self.routed))
        self.metrics.view("cluster_replicas", lambda: len(self.replicas))
        self.first_token_time = _ClusterView(self, "first_token_time")
        self.generated = _ClusterView(self, "generated")
        self.decode_dispatches = _ClusterView(self, "decode_dispatches")
        self.tracer = NULL_TRACER
        self.set_tracer(tracer)

    def set_tracer(self, tracer) -> None:
        self.tracer = tracer if tracer is not None else NULL_TRACER
        for i, r in enumerate(self.replicas):
            r.set_tracer(None if tracer is None else _ScopedTracer(
                tracer, f"r{i}", base=(i + 1) * 10 * _RID_STRIDE,
                rid_map=self._rid_maps[i],
            ))

    def warmup(self, prompt_len: int = 8, prompt_lens: tuple = ()) -> None:
        for r in self.replicas:
            r.warmup(prompt_len=prompt_len, prompt_lens=prompt_lens)

    @property
    def clock(self) -> float:
        return max(r.clock for r in self.replicas)

    def _route(self, req) -> int:
        prompt = np.asarray(req.prompt)
        n_tok = max(1, int(prompt.shape[0]))
        best_score = best_pressure = math.inf
        best_i = base_i = 0
        for i, r in enumerate(self.replicas):
            snap = r.load_snapshot()
            pressure = (
                snap["queue_depth"] + snap["active"]
                - snap["free_blocks"] / max(1, snap["total_blocks"])
            )
            if math.isfinite(snap["edf_headroom"]):
                # deadline-tight work already waits here: deprioritize
                pressure += 0.5
            hit = r.prefix_probe(prompt) / n_tok
            score = pressure - self.sticky_weight * hit
            if score < best_score:
                best_score, best_i = score, i
            if pressure < best_pressure:
                best_pressure, base_i = pressure, i
        if best_i != base_i:
            self.metrics.counter("sticky_routes").inc()
        return best_i

    def submit(self, req, at: Optional[float] = None,
               verify: bool = False) -> int:
        if verify:
            raise ValueError("cluster servers do not support verify mode")
        gid = self.next_id
        self.next_id += 1
        if req.seed is None:
            # pin the sampling seed BEFORE routing: replica-local default
            # seeds would make delivered content depend on placement
            req = dataclasses.replace(req, seed=gid)
        idx = self._route(req)
        replica = self.replicas[idx]
        self._rid_maps[idx][replica.next_id] = gid
        local = replica.submit(req, at=at)
        self._where[gid] = (idx, local)
        self.routed[idx] += 1
        self.metrics.counter("cluster_requests").inc()
        if self.tracer.enabled:
            self.tracer.instant(
                "cluster/router", "route",
                float(at) if at is not None else replica.clock,
                cat="server", args={"rid": gid, "replica": idx},
            )
        return gid

    def run_until(self, t_limit: float = math.inf) -> None:
        for r in self.replicas:
            r.run_until(t_limit)

    def run_to_completion(self) -> dict[int, list[int]]:
        for r in self.replicas:
            r.run_to_completion()
        return self.completed

    def _local(self, gid: int):
        where = self._where.get(gid)
        if where is None:
            raise ValueError(f"unknown request id {gid}")
        return self.replicas[where[0]], where[1]

    def cancel(self, gid: int, at: Optional[float] = None) -> None:
        r, local = self._local(gid)
        r.cancel(local, at=at)

    def cancel_pending(self, gid: int) -> bool:
        r, local = self._local(gid)
        return r.cancel_pending(local)

    def pop_events(self, gid: int) -> list:
        r, local = self._local(gid)
        return r.pop_events(local)

    def is_finished(self, gid: int) -> bool:
        r, local = self._local(gid)
        return r.is_finished(local)

    def ttft(self, gid: int) -> Optional[float]:
        r, local = self._local(gid)
        return r.ttft(local)

    @property
    def completed(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {}
        for gid, (idx, local) in self._where.items():
            done = self.replicas[idx].completed
            if local in done:
                out[gid] = list(done[local])
        return out

    def load_snapshot(self) -> dict:
        snaps = [r.load_snapshot() for r in self.replicas]
        return {
            "queue_depth": sum(s["queue_depth"] for s in snaps),
            "active": sum(s["active"] for s in snaps),
            "free_rows": sum(s["free_rows"] for s in snaps),
            "free_blocks": sum(s["free_blocks"] for s in snaps),
            "total_blocks": sum(s["total_blocks"] for s in snaps),
            "edf_headroom": min(s["edf_headroom"] for s in snaps),
        }

    def pool_stats(self) -> dict:
        return _merge_stats(
            [r.pool_stats() for r in self.replicas] + [self.metrics.snapshot()]
        )


class ClusterEndpoint(ServerEndpoint):
    """N replicas behind the :class:`ServerEndpoint` surface.

    ``DiSCoServer`` races device-vs-fleet unchanged: it sees one endpoint
    whose ``server`` happens to be a :class:`ClusterServer`, and every
    submit routes to the least-pressured (or prefix-warm) replica."""

    def __init__(self, replicas: Sequence, network=None, tracer=None, *,
                 sticky_weight: float = 2.0):
        super().__init__(
            ClusterServer(replicas, sticky_weight=sticky_weight,
                          tracer=tracer),
            network=network, tracer=tracer,
        )
