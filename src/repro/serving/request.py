"""First-class request / QoE contract for the serving stack.

DiSCo's premise is optimizing *per-request* QoE (TTFT/TBT deadlines) under
cost constraints, so the request itself — not a bare ``(arrival, prompt,
max_new)`` tuple with kwargs sprawled across layers — is the unit every
serving API passes around:

* :class:`Request` — the ONE argument threaded end-to-end:
  ``DiSCoServer.serve_many(list[Request])``,
  ``DeviceEndpoint/ServerEndpoint.open_stream(req, rng, start_at)``,
  ``BatchedServer.submit(req, at=)``, ``InferenceEngine.open_stream(req)``.
  It carries the prompt, the token budget, the per-request
  :class:`~repro.models.sampling.SamplerConfig` (heterogeneous configs
  coexist in one batch — the sampler rides through the jitted step
  functions as per-row runtime operands, not a closed-over constant), the
  sampling ``seed`` (replay/migration bit-identity), the :class:`SLO`
  contract, an admission ``priority`` tier, and a ``cost_weight``.
* :class:`SLO` — the deadline contract admission and dispatch consult:
  ``ttft_deadline`` (seconds from arrival to the first token) and
  ``tbt_target`` (seconds between subsequent tokens — the smooth-delivery
  pace the user experiences).
* :class:`QoEReport` — Andes-style scoring of the *delivered token
  timeline* against the SLO's expected timeline, attached to every
  :class:`RequestResult`.

Migration note (old tuple API -> Request)::

    # before                                # now
    disco.serve_many([(t, prompt, n)])      disco.serve_many([Request(prompt, n, arrival=t)])
    server.submit(prompt, n, at=t, seed=s)  server.submit(Request(prompt, n, seed=s), at=t)
    engine.open_stream(prompt, n, seed=s)   engine.open_stream(Request(prompt, n, seed=s))

``DiSCoServer.serve(prompt, max_new)`` remains as the one thin deprecated
shim (it builds the ``Request`` internally, preserving the monotonic-frontier
arrival semantics).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.models.sampling import SamplerConfig

__all__ = ["SLO", "NO_SLO", "Request", "QoEReport", "RequestResult"]

_EPS = 1e-9    # float-noise guard on deadline comparisons


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request QoE contract (Andes: QoE must be scoreable per request).

    ``ttft_deadline``: seconds from arrival within which the first token
    must be delivered. ``tbt_target``: the expected delivery pace after the
    first token — token *i* (0-indexed) is expected no later than
    ``ttft_deadline + i * tbt_target`` after arrival. ``inf`` (the default)
    disables the respective constraint.
    """

    ttft_deadline: float = math.inf
    tbt_target: float = math.inf

    def __post_init__(self):
        if not self.ttft_deadline > 0.0:
            raise ValueError(
                f"ttft_deadline must be > 0 (got {self.ttft_deadline})"
            )
        if not self.tbt_target > 0.0:
            raise ValueError(f"tbt_target must be > 0 (got {self.tbt_target})")

    @property
    def constrained(self) -> bool:
        """True when any deadline is finite (the request has an SLO at all)."""
        return math.isfinite(self.ttft_deadline) or math.isfinite(self.tbt_target)

    def expected_time(self, i: int, ttft_anchor: Optional[float] = None) -> float:
        """Expected delivery time of token ``i`` (0-indexed), relative to
        arrival: the first token by the TTFT deadline, then one token per
        ``tbt_target``. ``ttft_anchor`` substitutes the pace baseline when
        the TTFT deadline is infinite (a TBT-only contract paces from the
        ACTUAL first token, so it is not silently inert)."""
        if i <= 0:
            return self.ttft_deadline
        base = self.ttft_deadline
        if not math.isfinite(base) and ttft_anchor is not None:
            base = ttft_anchor
        return base + i * self.tbt_target


NO_SLO = SLO()


@dataclasses.dataclass
class Request:
    """One serving request — the single argument threaded through every
    layer of the stack.

    ``sampler=None`` inherits the engine/server default (greedy unless the
    engine was built with one); ``seed=None`` lets the runtime assign one
    (the DiSCo driver uses its rid, so the device/server race and any
    migration replay share the stream). ``priority`` is an admission tier
    (LOWER value admits first); within a tier the deadline-aware server
    orders by earliest TTFT deadline. ``cost_weight`` scales the request's
    unified cost in accounting (paying more for tighter contracts).
    ``rid`` is a caller-visible label; runtimes keep their own ids.
    """

    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0
    sampler: Optional[SamplerConfig] = None
    seed: Optional[int] = None
    slo: SLO = NO_SLO
    priority: int = 0
    cost_weight: float = 1.0
    rid: Optional[int] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.shape[0] < 1:
            raise ValueError(
                f"prompt must be a 1-D non-empty token array (shape {self.prompt.shape})"
            )
        self.max_new = int(self.max_new)
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1 (got {self.max_new})")
        self.arrival = float(self.arrival)
        if not math.isfinite(self.arrival) or self.arrival < 0.0:
            raise ValueError(f"arrival must be finite and >= 0 (got {self.arrival})")
        if self.cost_weight <= 0.0:
            raise ValueError(f"cost_weight must be > 0 (got {self.cost_weight})")
        self.priority = int(self.priority)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass(frozen=True)
class QoEReport:
    """Andes-style QoE scoring of one request's delivered token timeline.

    The SLO defines an *expected* delivery timeline (first token by the
    TTFT deadline, then one token per ``tbt_target``); the report compares
    the actual delivery times against it:

    * ``qoe_score`` — mean per-token delivery credit ``min(1, expected_i /
      actual_i)`` over delivered tokens: 1.0 when every token met its
      expected time, degrading smoothly (a token k x late earns 1/k).
      A request that delivered nothing scores 0.
    * ``ttft_attained`` — the first token met ``ttft_deadline``.
    * ``late_tokens`` — tokens delivered after their expected time.
    * ``slo_attained`` — the whole contract held: TTFT attained and no
      late token.
    """

    rid: int
    tokens_delivered: int
    ttft: float                  # seconds from arrival (inf if none delivered)
    ttft_deadline: float
    ttft_attained: bool
    tbt_mean: float              # mean delivered inter-token gap
    late_tokens: int
    qoe_score: float
    slo_attained: bool

    @classmethod
    def from_timeline(cls, arrival: float, delivery_times, slo: SLO,
                      rid: int = -1) -> "QoEReport":
        """Score an absolute delivered-token timeline against ``slo``.

        ``delivery_times``: absolute virtual-timeline seconds at which each
        token reached the user, in order.
        """
        rel = [float(t) - float(arrival) for t in delivery_times]
        n = len(rel)
        if n == 0:
            return cls(
                rid=rid, tokens_delivered=0, ttft=math.inf,
                ttft_deadline=slo.ttft_deadline, ttft_attained=False,
                tbt_mean=0.0, late_tokens=0, qoe_score=0.0, slo_attained=False,
            )
        late = 0
        credit = 0.0
        for i, a in enumerate(rel):
            # TBT-only contracts pace from the actual first token: an
            # infinite TTFT deadline must not make every later token's
            # expectation infinite too
            e = slo.expected_time(i, ttft_anchor=rel[0])
            if a > e + _EPS:
                late += 1
            if math.isinf(e) or a <= _EPS:
                credit += 1.0
            else:
                credit += min(1.0, e / a)
        ttft = rel[0]
        ttft_attained = ttft <= slo.ttft_deadline + _EPS
        gaps = [b - a for a, b in zip(rel, rel[1:])]
        return cls(
            rid=rid, tokens_delivered=n, ttft=ttft,
            ttft_deadline=slo.ttft_deadline, ttft_attained=ttft_attained,
            tbt_mean=(sum(gaps) / len(gaps)) if gaps else 0.0,
            late_tokens=late, qoe_score=credit / n,
            slo_attained=ttft_attained and late == 0,
        )


@dataclasses.dataclass
class RequestResult:
    """Everything the runtime knows about one served request: the delivered
    stream, QoE accounting against the request's SLO, and the cost/waste
    ledger. ``ServedRequest`` is the deprecated alias kept for imports."""

    request: Request
    tokens: list[int]
    ttft: float                  # seconds from arrival (inf: never answered)
    tbt_series: list[float]
    cost: float                  # unified cost, scaled by request.cost_weight
    winner: object               # Endpoint that delivered the first token
    migrated: bool
    delayed_tokens: int
    generated_tokens: int        # computed across all streams of the request
    wasted_tokens: int           # generated but never delivered
    qoe: QoEReport

    @property
    def arrival(self) -> float:
        return self.request.arrival

    @property
    def rid(self):
        return self.request.rid

    @property
    def slo_attained(self) -> bool:
        return self.qoe.slo_attained
