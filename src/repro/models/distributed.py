"""Distributed flash-decode over a sequence-sharded KV cache (shard_map).

Why: when kv_heads < model-axis size (nemotron: kv=8 < 16), the decode cache
shards its *sequence* dimension over "model". GSPMD's default lowering of
one-token attention against a seq-sharded cache materializes full-length
gathers per layer (~GBs/step). The roofline-correct schedule is the
distributed flash-decode of Pope et al.: each shard computes a partial
online-softmax over its KV slice, then the shards combine (max-rescaled)
partial sums with two tiny psums of (B,H) statistics and one psum of the
(B,H,D) partial outputs.

The new token's K/V insertion also happens shard-locally (the shard owning
position ``lengths-1`` updates; others no-op) — no cross-shard writes.

Enabled per-run via ``decode_context`` (the §Perf variant path); the
baseline keeps GSPMD's default for comparison.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

# shard_map moved to the jax namespace (and check_rep -> check_vma) across
# jax releases; resolve whichever this container ships.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"

__all__ = [
    "decode_context",
    "active_decode_context",
    "distributed_attn_decode",
    "distributed_mla_decode_absorbed",
]


@dataclasses.dataclass(frozen=True)
class _DecodeCtx:
    mesh: Mesh
    seq_axis: str
    batch_axes: tuple


_ACTIVE: list[_DecodeCtx] = []


@contextlib.contextmanager
def decode_context(mesh: Mesh, seq_axis: str = "model", batch_axes: tuple = ("data",)):
    _ACTIVE.append(_DecodeCtx(mesh, seq_axis, tuple(batch_axes)))
    try:
        yield
    finally:
        _ACTIVE.pop()


def active_decode_context() -> Optional[_DecodeCtx]:
    return _ACTIVE[-1] if _ACTIVE else None


def distributed_attn_decode(
    q: jnp.ndarray,        # (B, H, D) — replicated over the seq axis
    k_new: jnp.ndarray,    # (B, K, 1, D) — head-major, like the cache
    v_new: jnp.ndarray,
    k_cache: jnp.ndarray,  # (B, K, S, D) — S sharded over ctx.seq_axis
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) — count INCLUDING the new token
    window,
    ctx: _DecodeCtx,
):
    """Returns (out (B,H,D), k_cache, v_cache) with shard-local insertion and
    a max-rescaled cross-shard softmax combine."""
    mesh = ctx.mesh
    ax = ctx.seq_axis
    bx = ctx.batch_axes if len(ctx.batch_axes) > 1 else (
        ctx.batch_axes[0] if ctx.batch_axes else None
    )

    def local(q, k_new, v_new, kc, vc, lengths):
        b, kh, s_local, d = kc.shape
        h = q.shape[1]
        n_rep = h // kh
        shard = jax.lax.axis_index(ax)
        start = shard * s_local

        # --- shard-local insertion of the new token's K/V -------------------
        idx = lengths - 1 - start
        in_range = (idx >= 0) & (idx < s_local)
        safe = jnp.clip(idx, 0, s_local - 1)
        upd = lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (0, i, 0))
        kc2 = jax.vmap(upd)(kc, k_new, safe)
        vc2 = jax.vmap(upd)(vc, v_new, safe)
        sel = in_range[:, None, None, None]
        kc = jnp.where(sel, kc2, kc)
        vc = jnp.where(sel, vc2, vc)

        # --- local partial flash-decode (grouped heads, no repeat_kv) -------
        qg = q.reshape(b, kh, n_rep, d).astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
        logits = jnp.einsum(
            "bgrd,bgsd->bgrs", qg, kc.astype(jnp.float32)
        ) * scale                                            # (B,K,n_rep,S)
        pos = start + jnp.arange(s_local)[None, :]
        valid = pos < lengths[:, None]
        w = jnp.asarray(window)
        valid &= jnp.where(w > 0, (lengths[:, None] - 1 - pos) < w, True)
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)

        m = logits.max(axis=-1)                              # (B,K,n_rep)
        p = jnp.exp(logits - m[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        l = p.sum(axis=-1)                                   # (B,K,n_rep)
        o = jnp.einsum("bgrs,bgsd->bgrd", p, vc.astype(jnp.float32))

        # --- cross-shard combine (2 scalar-field psums + 1 output psum) -----
        m_glob = jax.lax.pmax(m, ax)
        alpha = jnp.exp(m - m_glob)
        l_tot = jax.lax.psum(l * alpha, ax)
        o_tot = jax.lax.psum(o * alpha[..., None], ax)
        out = (o_tot / jnp.maximum(l_tot, 1e-30)[..., None]).astype(q.dtype)
        return out.reshape(b, h, d), kc, vc

    out, kc, vc = _shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bx, None, None),
            P(bx, None, None, None),
            P(bx, None, None, None),
            P(bx, None, ax, None),
            P(bx, None, ax, None),
            P(bx),
        ),
        out_specs=(P(bx, None, None), P(bx, None, ax, None), P(bx, None, ax, None)),
        **{_CHECK_KW: False},
    )(q, k_new, v_new, k_cache, v_cache, lengths)
    return out, kc, vc


def distributed_mla_decode_absorbed(
    q_abs: jnp.ndarray,        # (B, H, r)  — absorbed no-pe query, replicated
    q_rope: jnp.ndarray,       # (B, H, dr)
    ckv_new: jnp.ndarray,      # (B, 1, r)
    krope_new: jnp.ndarray,    # (B, 1, dr)
    ckv_cache: jnp.ndarray,    # (B, S, r)  — S sharded over ctx.seq_axis
    krope_cache: jnp.ndarray,  # (B, S, dr)
    lengths: jnp.ndarray,
    window,
    scale: float,
    ctx: _DecodeCtx,
):
    """Distributed flash-decode in the COMPRESSED MLA space: each seq shard
    scores q against its c_kv slice and returns a partial (B,H,r) context;
    the cross-shard combine psums tiny (B,H)/(B,H,r) tensors instead of the
    baseline's per-layer (B,H,S) score all-reduce.

    Returns (ctx_out (B,H,r) f32, ckv_cache, krope_cache).
    """
    mesh, ax = ctx.mesh, ctx.seq_axis
    bx = ctx.batch_axes if len(ctx.batch_axes) > 1 else (
        ctx.batch_axes[0] if ctx.batch_axes else None
    )

    def local(q_abs, q_rope, ckv_new, krope_new, cc, kr, lengths):
        b, s_local, r = cc.shape
        shard = jax.lax.axis_index(ax)
        start = shard * s_local
        idx = lengths - 1 - start
        in_range = (idx >= 0) & (idx < s_local)
        safe = jnp.clip(idx, 0, s_local - 1)
        upd = lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0)
        cc2 = jax.vmap(upd)(cc, ckv_new, safe)
        kr2 = jax.vmap(upd)(kr, krope_new, safe)
        sel = in_range[:, None, None]
        cc = jnp.where(sel, cc2, cc)
        kr = jnp.where(sel, kr2, kr)

        f32 = jnp.float32
        scores = (
            jnp.einsum("bhr,bsr->bhs", q_abs.astype(f32), cc.astype(f32))
            + jnp.einsum("bhd,bsd->bhs", q_rope.astype(f32), kr.astype(f32))
        ) * scale
        pos = start + jnp.arange(s_local)[None, :]
        valid = pos < lengths[:, None]
        w = jnp.asarray(window)
        valid &= jnp.where(w > 0, (lengths[:, None] - 1 - pos) < w, True)
        scores = jnp.where(valid[:, None, :], scores, -1e30)
        m = scores.max(axis=-1)
        p = jnp.exp(scores - m[..., None])
        p = jnp.where(valid[:, None, :], p, 0.0)
        l = p.sum(axis=-1)
        ctx_part = jnp.einsum("bhs,bsr->bhr", p, cc.astype(f32))

        m_glob = jax.lax.pmax(m, ax)
        alpha = jnp.exp(m - m_glob)
        l_tot = jax.lax.psum(l * alpha, ax)
        c_tot = jax.lax.psum(ctx_part * alpha[..., None], ax)
        out = c_tot / jnp.maximum(l_tot, 1e-30)[..., None]
        return out, cc, kr

    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bx, None, None), P(bx, None, None),
            P(bx, None, None), P(bx, None, None),
            P(bx, ax, None), P(bx, ax, None),
            P(bx),
        ),
        out_specs=(P(bx, None, None), P(bx, ax, None), P(bx, ax, None)),
        **{_CHECK_KW: False},
    )(q_abs, q_rope, ckv_new, krope_new, ckv_cache, krope_cache, lengths)
