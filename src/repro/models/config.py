"""Composable model configuration covering all assigned architecture families:
dense (GQA / MLA / sliding-window / squared-ReLU), MoE (top-k, optional dense
residual), SSM (Mamba2 SSD), hybrid (parallel attention+SSM heads), and
encoder-only (HuBERT-style masked prediction).

A single ``ModelConfig`` drives parameter init, the train/prefill/decode step
functions, the sharding rules and the dry-run input specs.
"""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    # ---- attention ----
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // n_heads
    attention: str = "full"     # full | window | pattern (local:global mix)
    window: int = 0             # sliding-window size (attention != full)
    global_interval: int = 0    # pattern: every Nth layer is global (gemma3: 6)
    qk_norm: bool = False       # chameleon-style QK-norm
    rope_theta: float = 10000.0
    # ---- MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style) ----
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False   # decode in compressed space (weight absorption:
                               # fold W^UK/W^UV into the query/output paths so
                               # the c_kv cache is never expanded per step)
    # ---- FFN ----
    d_ff: int = 0
    act: str = "swiglu"         # swiglu | squared_relu | gelu
    # ---- MoE ----
    n_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False   # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01      # load-balance loss weight (train)
    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 64               # SSD chunk length
    # ---- hybrid (Hymba): parallel attention + SSM heads per layer ----
    hybrid: bool = False
    # ---- encoder-only (HuBERT) ----
    is_encoder: bool = False          # bidirectional, no decode phase
    embed_inputs: bool = True         # False: inputs are frontend embeddings
    # ---- numerics ----
    embed_onehot: bool = False  # vocab-sharded-friendly lookup: one-hot @ table
                                # (decode-scale token counts only)
    dtype: str = "bfloat16"
    # ---- training-time knobs (per-arch defaults; launch may override) ----
    remat: bool = True
    num_microbatches: int = 1

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm" and self.n_heads <= 0:
            raise ValueError(f"{self.name}: attention families need n_heads")
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError(f"{self.name}: n_heads % n_kv_heads != 0")
        if self.family in ("moe",) and (self.n_experts <= 0 or self.experts_per_token <= 0):
            raise ValueError(f"{self.name}: moe needs experts")

    # ---- derived ------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.use_mla:
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def resolved_v_head_dim(self) -> int:
        if self.use_mla:
            return self.v_head_dim
        return self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family == "ssm" or self.hybrid

    @property
    def has_ffn(self) -> bool:
        return self.family != "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def causal(self) -> bool:
        return not self.is_encoder

    def layer_is_global(self, i: int) -> bool:
        """Pattern attention (gemma3 5:1 local:global): every
        ``global_interval``-th layer attends globally; others use the window."""
        if self.attention == "full":
            return True
        if self.attention == "window":
            return False
        return (i + 1) % self.global_interval == 0

    # ---- accounting ----------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D MODEL_FLOPS and memory
        sanity checks in the roofline report)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d  # input embedding
        if not self.is_encoder:
            n += self.vocab * d  # untied lm head
        else:
            n += self.vocab * d  # encoder prediction head over cluster codes
        per_layer = 0
        if self.has_attention:
            if self.use_mla:
                hd = self.qk_nope_head_dim + self.qk_rope_head_dim
                q_in = self.q_lora_rank if self.q_lora_rank else d
                per_layer += (d * self.q_lora_rank if self.q_lora_rank else 0)
                per_layer += q_in * self.n_heads * hd
                per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                per_layer += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.v_head_dim
                )
                per_layer += self.n_heads * self.v_head_dim * d
            else:
                hd = self.resolved_head_dim
                per_layer += d * self.n_heads * hd
                per_layer += 2 * d * self.n_kv_heads * hd
                per_layer += self.n_heads * hd * d
        if self.has_ssm:
            di = self.d_inner
            conv_dim = di + 2 * self.ssm_groups * self.ssm_state
            per_layer += d * (2 * di + 2 * self.ssm_groups * self.ssm_state + self.ssm_heads)
            per_layer += conv_dim * self.conv_width
            per_layer += self.ssm_heads * 2  # A_log, D
            per_layer += di * d              # out proj
        if self.has_ffn:
            ffn = 0
            mult = 3 if self.act == "swiglu" else 2
            if self.is_moe:
                ffn += self.n_experts * mult * d * self.d_ff
                ffn += d * self.n_experts  # router
                if self.moe_dense_residual:
                    ffn += mult * d * self.d_ff
            else:
                ffn += mult * d * self.d_ff
            per_layer += ffn
        per_layer += 2 * d  # norms
        return n + L * per_layer

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        mult = 3 if self.act == "swiglu" else 2
        inactive = L * (self.n_experts - self.experts_per_token) * mult * d * self.d_ff
        return self.param_count() - inactive
