"""Mamba2 SSD (state-space duality) — chunked training/prefill form and the
single-token recurrent decode form [arXiv:2405.21060].

Shapes follow the Mamba2 convention:
  x  : (B, T, H, P)   — inputs split into H heads of dim P
  dt : (B, T, H)      — softplus-ed step sizes
  A  : (H,)           — negative real decay per head
  Bm : (B, T, G, N)   — input matrix (G groups broadcast over heads)
  Cm : (B, T, G, N)   — output matrix
  state: (B, H, P, N)

The chunked form (``ssd_chunked``) computes, per chunk of length Q, the
quadratic intra-chunk "attention-like" term and carries inter-chunk states
with a linear scan — O(T·Q) work and O(T/Q) sequential steps. The recurrent
form (``ssd_decode_step``) advances one token in O(1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_chunked", "ssd_decode_step", "causal_conv1d", "conv1d_step"]


def _broadcast_groups(m: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, T, G, N) -> (B, T, H, N) by repeating groups."""
    b, t, g, n = m.shape
    rep = n_heads // g
    return jnp.broadcast_to(m[:, :, :, None, :], (b, t, g, rep, n)).reshape(
        b, t, n_heads, n
    )


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    *,
    chunk: int = 64,
    initial_state: jnp.ndarray | None = None,
):
    """Returns (y: (B,T,H,P), final_state: (B,H,P,N)). T % chunk == 0."""
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    Bm = _broadcast_groups(Bm, h)
    Cm = _broadcast_groups(Cm, h)

    f32 = jnp.float32
    xdt = x.astype(f32) * dt.astype(f32)[..., None]            # (B,T,H,P)
    dA = dt.astype(f32) * A.astype(f32)[None, None, :]          # (B,T,H) <= 0

    # chunked views
    xc = xdt.reshape(b, nc, chunk, h, p)
    Bc = Bm.reshape(b, nc, chunk, h, n).astype(f32)
    Cc = Cm.reshape(b, nc, chunk, h, n).astype(f32)
    dAc = dA.reshape(b, nc, chunk, h)
    seg = jnp.cumsum(dAc, axis=2)                               # (B,nc,Q,H)

    # --- intra-chunk (quadratic, "dual" attention form) -------------------
    # L[i,j] = exp(seg_i - seg_j) for i >= j else 0
    li = seg[:, :, :, None, :]                                  # (B,nc,Q,1,H)
    lj = seg[:, :, None, :, :]                                  # (B,nc,1,Q,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)           # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * L, xc)

    # --- chunk states ------------------------------------------------------
    seg_last = seg[:, :, -1:, :]                                # (B,nc,1,H)
    decay_out = jnp.exp(seg_last - seg)                         # (B,nc,Q,H)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", Bc, decay_out, xc)

    # --- inter-chunk linear recurrence -------------------------------------
    chunk_decay = jnp.exp(seg_last[:, :, 0, :])                 # (B,nc,H)

    def step(h_carry, inp):
        st, dec = inp                                           # (B,H,P,N), (B,H)
        h_prev = h_carry
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev

    init = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), f32)
    )
    final_state, h_prevs = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,P,N)

    # --- inter-chunk contribution to outputs --------------------------------
    in_decay = jnp.exp(seg)                                     # (B,nc,Q,H)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Cc, h_prevs) * in_decay[..., None]

    y = (y_intra + y_inter).reshape(b, t, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    state: jnp.ndarray,   # (B, H, P, N) float32
    x: jnp.ndarray,       # (B, H, P)
    dt: jnp.ndarray,      # (B, H)
    A: jnp.ndarray,       # (H,)
    Bm: jnp.ndarray,      # (B, G, N)
    Cm: jnp.ndarray,      # (B, G, N)
):
    """One recurrent step. Returns (y: (B,H,P), new_state)."""
    b, h, p = x.shape
    g, n = Bm.shape[1], Bm.shape[2]
    rep = h // g
    Bh = jnp.broadcast_to(Bm[:, :, None, :], (b, g, rep, n)).reshape(b, h, n)
    Ch = jnp.broadcast_to(Cm[:, :, None, :], (b, g, rep, n)).reshape(b, h, n)
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])       # (B,H)
    xdt = x.astype(f32) * dt.astype(f32)[..., None]             # (B,H,P)
    new_state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xdt, Bh.astype(f32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(f32))
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Depthwise causal conv (the short conv in Mamba blocks)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, C), w: (C, W), b: (C,). Causal depthwise conv + silu."""
    width = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[:, i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + b[None, None, :])


def conv1d_step(
    conv_state: jnp.ndarray,  # (B, W-1, C) — previous inputs
    x_new: jnp.ndarray,       # (B, C)
    w: jnp.ndarray,           # (C, W)
    b: jnp.ndarray,           # (C,)
):
    """One causal-conv step. Returns (y: (B,C), new_conv_state)."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,cw->bc", window, w) + b[None, :]
    new_state = window[:, 1:, :]
    return jax.nn.silu(y), new_state
