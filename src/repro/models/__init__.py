from .config import ModelConfig
from .model import (
    decode_n,
    decode_step,
    draft_n,
    forward,
    init_cache,
    init_params,
    param_shapes,
    prefill,
    verify_n,
    window_vector,
)
from .paged import (
    init_paged_pages,
    paged_decode_n,
    paged_decode_step,
    paged_draft_n,
    paged_piece_prefill,
    paged_prefill,
    paged_suffix_prefill,
    paged_verify_n,
    supports_paged,
)

# Replayable stochastic sampling (``models.sampling``): ``SamplerConfig``
# (greedy/temperature/top-k/top-p) is the per-REQUEST spec; engines stack a
# batch of them into ``SamplerOperands`` — (B,) runtime arrays threaded
# through the jitted step functions as traced arguments (``sampler_operands``)
# so heterogeneous configs coexist in one batch. ``request_key(seed)`` derives
# the per-request base key and ``sample_tokens`` draws each token via
# ``fold_in(key, position)`` — pure in (config, key, position, logits), so
# migration/preemption/fork replay is bit-identical under temperature > 0.
# ``GREEDY`` is the argmax default (the temperature == 0 branch per row).
# The speculative-decoding surface (``sampling_probs``, ``speculative_accept``,
# ``first_rejection``; ``draft_n``/``verify_n`` and their paged twins) exposes
# the same draws as explicit distributions for device-draft / server-verify.
from .sampling import (
    GREEDY,
    SamplerConfig,
    SamplerOperands,
    first_rejection,
    request_key,
    sample_tokens,
    sampler_operands,
    sampling_probs,
    speculative_accept,
)

__all__ = [
    "ModelConfig", "decode_n", "decode_step", "draft_n", "forward",
    "init_cache", "init_params", "param_shapes", "prefill", "verify_n",
    "window_vector",
    "init_paged_pages", "paged_decode_n", "paged_decode_step",
    "paged_draft_n", "paged_piece_prefill", "paged_prefill",
    "paged_suffix_prefill", "paged_verify_n", "supports_paged",
    "GREEDY", "SamplerConfig", "SamplerOperands", "first_rejection",
    "request_key", "sample_tokens", "sampler_operands", "sampling_probs",
    "speculative_accept",
]
