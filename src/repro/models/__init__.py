from .config import ModelConfig
from .model import (
    decode_n,
    decode_step,
    forward,
    init_cache,
    init_params,
    param_shapes,
    prefill,
    window_vector,
)
from .paged import (
    init_paged_pages,
    paged_decode_n,
    paged_decode_step,
    paged_prefill,
    supports_paged,
)

__all__ = [
    "ModelConfig", "decode_n", "decode_step", "forward", "init_cache",
    "init_params", "param_shapes", "prefill", "window_vector",
    "init_paged_pages", "paged_decode_n", "paged_decode_step",
    "paged_prefill", "supports_paged",
]
