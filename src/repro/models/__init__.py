from .config import ModelConfig
from .model import (
    decode_n,
    decode_step,
    forward,
    init_cache,
    init_params,
    param_shapes,
    prefill,
    window_vector,
)
from .paged import (
    init_paged_pages,
    paged_decode_n,
    paged_decode_step,
    paged_prefill,
    supports_paged,
)

# Replayable stochastic sampling (``models.sampling``): ``SamplerConfig``
# (greedy/temperature/top-k/top-p) is closed over by the jitted step
# functions; ``request_key(seed)`` derives the per-request base key and
# ``sample_tokens`` draws each token via ``fold_in(key, position)`` — pure in
# (key, position, logits), so migration/preemption/fork replay is
# bit-identical under temperature > 0. ``GREEDY`` is the argmax default.
from .sampling import GREEDY, SamplerConfig, request_key, sample_tokens

__all__ = [
    "ModelConfig", "decode_n", "decode_step", "forward", "init_cache",
    "init_params", "param_shapes", "prefill", "window_vector",
    "init_paged_pages", "paged_decode_n", "paged_decode_step",
    "paged_prefill", "supports_paged",
    "GREEDY", "SamplerConfig", "request_key", "sample_tokens",
]
