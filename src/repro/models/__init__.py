from .config import ModelConfig
from .model import (
    decode_n,
    decode_step,
    forward,
    init_cache,
    init_params,
    param_shapes,
    prefill,
    window_vector,
)

__all__ = [
    "ModelConfig", "decode_n", "decode_step", "forward", "init_cache",
    "init_params", "param_shapes", "prefill", "window_vector",
]
