"""Top-k Mixture-of-Experts FFN with capacity-based scatter dispatch.

Implements the expert-parallel pattern used by Arctic (128e top-2 + dense
residual) and OLMoE (64e top-8): tokens are routed to their top-k experts,
packed into per-expert capacity buffers (scatter), processed as batched
einsums over the expert dimension (which shards over the "model"/"expert"
mesh axis -> all-to-all under GSPMD), and combined back weighted by the
router probabilities. Overflowing tokens are dropped (standard capacity
semantics); the router aux loss (load balancing, Switch-style) is returned
for the train step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["moe_ffn", "MoEOutput"]


@dataclasses.dataclass
class MoEOutput:
    y: jnp.ndarray          # (T, d)
    aux_loss: jnp.ndarray   # scalar load-balance loss
    router_entropy: jnp.ndarray


def _expert_ffn(h: jnp.ndarray, w_gate, w_up, w_down, act: str) -> jnp.ndarray:
    """h: (E, C, d); weights: (E, d, f) / (E, f, d)."""
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", h, w_gate)
        u = jnp.einsum("ecd,edf->ecf", h, w_up)
        z = jax.nn.silu(g) * u
    elif act == "squared_relu":
        u = jnp.einsum("ecd,edf->ecf", h, w_up)
        z = jnp.square(jax.nn.relu(u))
    else:
        u = jnp.einsum("ecd,edf->ecf", h, w_up)
        z = jax.nn.gelu(u)
    return jnp.einsum("ecf,efd->ecd", z, w_down)


def moe_ffn(
    x: jnp.ndarray,            # (T, d) flattened tokens
    router_w: jnp.ndarray,     # (d, E)
    w_gate: jnp.ndarray | None,  # (E, d, f) — None for non-swiglu acts
    w_up: jnp.ndarray,         # (E, d, f)
    w_down: jnp.ndarray,       # (E, f, d)
    *,
    k: int,
    capacity_factor: float,
    act: str = "swiglu",
) -> MoEOutput:
    t, d = x.shape
    e = router_w.shape[-1]
    capacity = max(int(t * k / e * capacity_factor), 1)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- position of each (token, slot) inside its expert's buffer ----------
    flat_e = top_e.reshape(-1)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                        # running count
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < capacity                                  # drop overflow

    # --- scatter tokens into (E*C, d) buffers --------------------------------
    buf_idx = jnp.where(keep, flat_e * capacity + flat_pos, e * capacity)
    x_rep = jnp.repeat(x, k, axis=0)                            # (T*k, d)
    buffers = jnp.zeros((e * capacity + 1, d), x.dtype).at[buf_idx].add(x_rep)
    h = buffers[:-1].reshape(e, capacity, d)

    out = _expert_ffn(h, w_gate, w_up, w_down, act)             # (E, C, d)

    # --- gather back and combine ---------------------------------------------
    flat_out = out.reshape(e * capacity, d)
    gathered = jnp.where(
        keep[:, None], flat_out[jnp.minimum(buf_idx, e * capacity - 1)], 0.0
    )
    weights = top_p.reshape(-1)[:, None].astype(x.dtype)        # (T*k, 1)
    y = (gathered * weights).reshape(t, k, d).sum(axis=1)

    # --- Switch-style load-balance aux loss ----------------------------------
    me = probs.mean(axis=0)                                     # mean router prob
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    entropy = -jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1).mean()
    return MoEOutput(y=y, aux_loss=aux, router_entropy=entropy)
